//! End-to-end driver: train the transformer through the full three-layer
//! stack — Rust coordinator → PJRT-compiled train step (JAX manual-bwd
//! model + Pallas fake-quant kernels) — on the synthetic corpus, with
//! the paper-default MoR recipe, logging the loss curve and the MoR
//! decision statistics.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example e2e_train -- \
//!       [--model small] [--steps 300] [--artifact train_mor_tensor_block]
//!
//! The EXPERIMENTS.md headline run uses `--model small --steps 300`.

use mor::coordinator::logging::ascii_chart;
use mor::coordinator::trainer::{Trainer, TrainerOptions};
use mor::model::config::{ModelConfig, TrainConfig};
use mor::runtime::Runtime;
use mor::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = ModelConfig::preset(args.get_or("model", "small")).expect("unknown preset");
    let steps = args.u64("steps", 300);
    let artifact = args.get_or("artifact", "train_mor_tensor_block").to_string();
    let artifacts_dir = PathBuf::from(args.get_or("artifacts", "")).into_os_string();
    let artifacts_dir = if artifacts_dir.is_empty() {
        PathBuf::from("artifacts").join(model.name)
    } else {
        PathBuf::from(artifacts_dir)
    };

    println!(
        "e2e: model {} ({:.1}M params), artifact {}, {} steps",
        model.name,
        model.num_params() as f64 / 1e6,
        artifact,
        steps
    );
    let runtime = Runtime::load(&artifacts_dir, model)?;
    let trainer = Trainer::new(&runtime, TrainConfig::config1(steps));
    let mut opts = TrainerOptions::new(&artifact, steps, PathBuf::from("runs/e2e"));
    opts.val_every = (steps / 20).max(1);
    opts.suite_every = (steps / 6).max(1);
    opts.ckpt_every = steps / 2;
    opts.per_channel = artifact.contains("channel");
    let outcome = trainer.run(&opts)?;

    // Loss curve (the Figure-5-style panel for this single run).
    let series = vec![
        (
            "train".to_string(),
            outcome
                .records
                .iter()
                .map(|r| (r.step as f64, r.train_loss as f64))
                .collect::<Vec<_>>(),
        ),
        (
            "val".to_string(),
            outcome
                .records
                .iter()
                .filter(|r| r.val_loss.is_finite())
                .map(|r| (r.step as f64, r.val_loss as f64))
                .collect(),
        ),
    ];
    println!("\n{}", ascii_chart("e2e loss curve", &series, 100, 18));

    println!("final train loss: {:.4}", outcome.final_train_loss);
    println!("final val loss:   {:.4}", outcome.final_val_loss);
    println!("mean step time:   {:.0} ms", outcome.mean_step_ms);
    println!(
        "tokens/sec:       {:.0}",
        (runtime.manifest.get(&artifact)?.usize_field("batch")? * model.seq_len) as f32
            / (outcome.mean_step_ms / 1e3)
    );
    println!(
        "BF16 fallback:    {:.2}% of tensor decisions",
        outcome.stats.overall_fallback_pct()
    );
    if let Some((step, scores)) = outcome.suite_history.last() {
        println!("eval suite at step {step}:");
        for (name, loss, acc) in &scores.per_task {
            println!("  {name:<8} loss {loss:.3} acc {acc:.1}%");
        }
        println!("  mean accuracy {:.2}%", scores.mean_accuracy());
    }
    println!("\nmetrics CSV: {}", outcome.metrics_path.display());
    Ok(())
}
