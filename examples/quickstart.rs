//! Quickstart: the MoR decision engine on the host, no artifacts needed.
//!
//! Demonstrates the paper's three key mechanisms on synthetic tensors:
//! GAM scaling (Alg. 1), the tensor-level recipe (§3.1) accepting a
//! well-conditioned tensor and rejecting a wide-dynamic-range one, and
//! the sub-tensor recipes (§3.2) mixing formats inside one tensor.
//!
//! Run: `cargo run --release --example quickstart`

use mor::formats::ReprType;
use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::quant::fake_quant::fake_quantize;
use mor::quant::partition::Partition;
use mor::scaling::{compute_scales, ScalingAlgo};
use mor::tensor::Tensor;

fn main() {
    println!("=== MoR quickstart ===\n");

    // 1. GAM scaling: one 23-bit mantissa for the tensor, one 8-bit
    //    exponent per block (Section 2).
    let x = Tensor::normal(&[256, 256], 2.0, 42);
    let blocks = Partition::BLOCK128.blocks(256, 256);
    let amaxes: Vec<f32> = blocks
        .iter()
        .map(|b| b.indices(256).map(|i| x.data()[i].abs()).fold(0.0f32, f32::max))
        .collect();
    let scales = compute_scales(ScalingAlgo::Gam, 448.0, x.amax(), &amaxes);
    println!("GAM: group mantissa m_g = {:.6}", scales.group_mantissa);
    for (i, b) in scales.blocks.iter().enumerate() {
        println!(
            "  block {i}: stored E8M0 exp {:>3}, reconstructed scale {:.4}, amax*scale = {:.2} (<= 448)",
            b.stored_exp.exponent(),
            b.scale,
            amaxes[i] * b.scale
        );
    }
    println!("  metadata: {} bits total\n", scales.metadata_bits());

    // 2. Tensor-level MoR (th = 4.5%): accepts a Gaussian tensor...
    let recipe = Recipe::paper_default();
    let good = recipe.apply(&x);
    println!(
        "tensor-level MoR on N(0,2) tensor: relerr {:.3}% → {}",
        good.e4m3_relerr * 100.0,
        if good.bf16_fraction == 0.0 { "E4M3 accepted" } else { "BF16 fallback" }
    );

    // ...and rejects a tensor spanning 12 decades.
    let mut wild = Tensor::normal(&[256, 256], 1.0, 7);
    for (i, v) in wild.data_mut().iter_mut().enumerate() {
        *v *= (10.0f32).powi((i % 13) as i32 - 6);
    }
    let bad = Recipe {
        kind: RecipeKind::TensorLevel { threshold: 0.045 },
        partition: Partition::Tensor,
        scaling: ScalingAlgo::Gam,
    }
    .apply(&wild);
    println!(
        "tensor-level MoR on wide-range tensor (per-tensor scale): relerr {:.1}% → {}",
        bad.e4m3_relerr * 100.0,
        if bad.bf16_fraction == 1.0 { "BF16 fallback" } else { "E4M3 accepted" }
    );

    // 3. Sub-tensor MoR: per-block decisions mixing E4M3/E5M2/BF16.
    let mut mixed = Tensor::normal(&[256, 256], 1.0, 9);
    for (i, v) in mixed.data_mut().iter_mut().enumerate() {
        *v *= (10.0f32).powi((i % 7) as i32 - 3);
    }
    for mode in [SubTensorMode::TwoWay, SubTensorMode::ThreeWay] {
        let r = Recipe {
            kind: RecipeKind::SubTensor { mode },
            partition: Partition::Block { r: 64, c: 64 },
            scaling: ScalingAlgo::Gam,
        }
        .apply(&mixed);
        let f = r.type_fractions();
        println!(
            "sub-tensor {:?}: blocks → {:.0}% E4M3, {:.0}% E5M2, {:.0}% BF16",
            mode,
            f[0] * 100.0,
            f[1] * 100.0,
            f[2] * 100.0
        );
    }

    // 4. The three scaling algorithms compared on the same tensor.
    println!("\nscaling-algorithm ablation (relerr of E4M3 quantization):");
    for algo in [ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0] {
        let fq = fake_quantize(&x, ReprType::E4M3, Partition::BLOCK128, algo);
        println!(
            "  {:<5}: relerr {:.4}%, metadata {} bits",
            algo.name(),
            fq.global_err.mean() * 100.0,
            fq.scales.metadata_bits()
        );
    }
}
