//! NVFP4 extension study — the paper's §5 future-work direction: can the
//! relative-error invariance drive a `[NVFP4, E4M3, BF16]` type list?
//!
//! Sweeps tensors of increasing dynamic range through the extended
//! recipe and reports where each format wins and how the relative error
//! behaves — showing why FP8 thresholds (4.5%) don't transfer to FP4
//! (the error floor of E2M1 is ~10x higher), which is exactly the
//! "more efficient invariance metrics" problem the paper leaves open.
//!
//! Run: `cargo run --release --example nvfp4_extension`

use mor::formats::ReprType;
use mor::mor::recipes::{Recipe, RecipeKind};
use mor::quant::fake_quant::fake_quantize;
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;

fn main() {
    println!("NVFP4 (E2M1 + 1x16 E4M3 block scales) vs E4M3 vs BF16\n");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>10}",
        "spread", "fp4 relerr", "e4m3 relerr", "bf16 relerr", "MoR picks"
    );

    for spread_decades in [0i32, 1, 2, 3, 4, 6] {
        let mut x = Tensor::normal(&[256, 256], 1.0, 21 + spread_decades as u64);
        if spread_decades > 0 {
            let period = (2 * spread_decades + 1) as usize;
            for (i, v) in x.data_mut().iter_mut().enumerate() {
                *v *= (10.0f32).powi((i % period) as i32 - spread_decades);
            }
        }
        let e_fp4 = fake_quantize(
            &x,
            ReprType::NvFp4,
            Partition::SubChannelRows { len: 16 },
            ScalingAlgo::Gam,
        )
        .global_err
        .mean();
        let e_e4m3 =
            fake_quantize(&x, ReprType::E4M3, Partition::BLOCK128, ScalingAlgo::Gam)
                .global_err
                .mean();
        let e_bf16 =
            fake_quantize(&x, ReprType::Bf16, Partition::Tensor, ScalingAlgo::Gam)
                .global_err
                .mean();

        // Extended MoR walk with per-format thresholds: FP4 gets a
        // looser bound (its quantization floor is ~6%), E4M3 keeps the
        // paper's 4.5%.
        let r = Recipe {
            kind: RecipeKind::NvFp4TensorLevel { threshold_fp4: 0.10, threshold_e4m3: 0.045 },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        let pick = r.block_types[0];
        println!(
            "{:>7}d {:>11.3}% {:>11.3}% {:>11.4}% {:>10}",
            spread_decades,
            e_fp4 * 100.0,
            e_e4m3 * 100.0,
            e_bf16 * 100.0,
            pick.name()
        );
    }

    println!(
        "\nTakeaway: E2M1's *mean relative error* sits near 20% even on\n\
         well-conditioned tensors (most values land in the coarse low end of\n\
         the {{0, .5, 1, 1.5, 2, 3, 4, 6}} grid), so the relative-error\n\
         invariance that cleanly separates E4M3-safe tensors at 4.5% will\n\
         essentially never accept NVFP4. The invariance is a *sufficient*\n\
         condition — too conservative for 4-bit formats — which is exactly\n\
         the refinement the paper names as future work (§1, §5)."
    );
}
