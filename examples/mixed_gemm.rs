//! Sub-tensor GEMM demo (paper Figure 3): two operand matrices whose
//! blocks carry different representations, multiplied with the
//! upcast-on-mismatch rule, reporting what fraction of MACs ran in each
//! effective precision — the efficiency side of the sub-tensor story.
//!
//! Run: `cargo run --release --example mixed_gemm`

use mor::formats::ReprType;
use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::ops::{matmul, mixed_gemm, BlockTypes};
use mor::tensor::Tensor;

fn block_types_from_outcome(
    rows: usize,
    cols: usize,
    block: usize,
    outcome: &mor::mor::framework::MorOutcome,
) -> BlockTypes {
    let mut bt = BlockTypes::uniform(rows, cols, block, ReprType::Bf16);
    let bc = cols.div_ceil(block);
    for (i, t) in outcome.block_types.iter().enumerate() {
        bt.grid[i / bc][i % bc] = *t;
    }
    bt
}

fn main() {
    const N: usize = 256;
    const BLK: usize = 64;

    // A: block-structured conditioning — most 64x64 blocks are smooth
    // (E4M3-friendly); every fourth block carries a wide internal
    // dynamic range (E5M2 or BF16 territory). This is the sub-tensor
    // scenario of Fig. 3: one tensor, mixed representations.
    let mut a = Tensor::normal(&[N, N], 1.0, 11);
    for (i, v) in a.data_mut().iter_mut().enumerate() {
        let (r, c) = (i / N, i % N);
        let (bi, bj) = (r / BLK, c / BLK);
        if (bi + bj) % 4 == 0 {
            *v *= (10.0f32).powi((i % 9) as i32 - 4); // wide-range block
        }
    }
    // B: well-behaved → all E4M3.
    let b = Tensor::normal(&[N, N], 1.5, 13);

    let recipe = Recipe {
        kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
        partition: Partition::Block { r: BLK, c: BLK },
        scaling: ScalingAlgo::Gam,
    };
    let oa = recipe.apply(&a);
    let ob = recipe.apply(&b);
    let fa = oa.type_fractions();
    let fb = ob.type_fractions();
    println!("operand A blocks: {:.0}% E4M3 / {:.0}% E5M2 / {:.0}% BF16", fa[0] * 100.0, fa[1] * 100.0, fa[2] * 100.0);
    println!("operand B blocks: {:.0}% E4M3 / {:.0}% E5M2 / {:.0}% BF16", fb[0] * 100.0, fb[1] * 100.0, fb[2] * 100.0);

    let ta = block_types_from_outcome(N, N, BLK, &oa);
    let tb = block_types_from_outcome(N, N, BLK, &ob);
    let rep = mixed_gemm(&oa.out, &ta, &ob.out, &tb);
    let total: u64 = rep.macs.iter().sum();
    println!("\nFig. 3 mixed GEMM ({N}x{N}x{N}, {BLK}-blocks):");
    println!("  MACs in E4M3:  {:5.1}%", rep.macs[0] as f64 / total as f64 * 100.0);
    println!("  MACs in E5M2:  {:5.1}%", rep.macs[1] as f64 / total as f64 * 100.0);
    println!("  MACs in BF16:  {:5.1}% (mismatched pairs upcast)", rep.macs[2] as f64 / total as f64 * 100.0);

    // Numerics: the mixed-precision product vs the exact product of the
    // unquantized inputs.
    let exact = matmul(&a, &b);
    let mut err = 0f64;
    let mut norm = 0f64;
    for (e, q) in exact.data().iter().zip(rep.out.data()) {
        err += ((e - q) as f64).powi(2);
        norm += (*e as f64).powi(2);
    }
    println!(
        "  relative Frobenius error vs exact GEMM: {:.4}",
        (err / norm).sqrt()
    );

    // Hypothetical speedup if fp8 MACs run 2x BF16 (H100 figure).
    let t_mixed = rep.macs[0] as f64 / 2.0 + rep.macs[1] as f64 / 2.0 + rep.macs[2] as f64;
    println!(
        "  modelled speedup vs all-BF16 (fp8 = 2x FLOPS): {:.2}x",
        total as f64 / t_mixed
    );
}
