"""Layer-2: the decoder-only transformer with an **explicit manual
backward pass**, MoR fake quantization on every linear-layer GEMM
operand, and the fused Adam train step that gets AOT-lowered to HLO.

Why manual backward: the paper quantizes the *gradient* tensors flowing
into the two backward GEMMs of each linear layer (dx = dy @ W^T and
dW = x^T @ dy) and reports per-tensor relative-error statistics for
them. ``jax.grad`` hides those activation gradients; writing the VJP by
hand makes every GEMM operand a first-class value we can quantize and
instrument. Correctness is pinned by ``tests/test_model.py``: with
quantization disabled, the manual gradients must match ``jax.grad`` to
float tolerance.

Parameter flattening order must match ``rust/src/model/naming.rs``
(``param_specs``): embedding, per-layer [ln1.scale, ln1.bias,
qkv.weight, proj.weight, ln2.scale, ln2.bias, fc1.weight, fc2.weight],
final_ln.scale, final_ln.bias, lm_head.weight.
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import fake_quant as fqk
from .kernels import ref


# ---------------------------------------------------------------------------
# Presets (mirror rust/src/model/config.rs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


PRESETS = {
    "tiny": ModelConfig("tiny", 256, 64, 2, 2, 256, 64),
    "small": ModelConfig("small", 256, 256, 4, 4, 1024, 128),
    "base": ModelConfig("base", 256, 896, 12, 14, 3584, 256),
}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """One MoR recipe, statically baked into the artifact.

    recipe: "baseline" | "tensor_level" | "subtensor2" | "subtensor3"
    partition: "tensor" | "blockRxC" | "channel" (direction-resolved)
    scaling: "gam" | "amax" | "e8m0"
    """

    recipe: str = "baseline"
    partition: str = "block128x128"
    scaling: str = "gam"
    use_pallas: bool = True

    @property
    def enabled(self):
        return self.recipe != "baseline"


def param_names(cfg: ModelConfig):
    names = ["embedding.weight"]
    for l in range(cfg.n_layers):
        names += [
            f"decoder.layer.{l}.ln1.scale",
            f"decoder.layer.{l}.ln1.bias",
            f"decoder.layer.{l}.self_attention.linear_qkv.weight",
            f"decoder.layer.{l}.self_attention.linear_proj.weight",
            f"decoder.layer.{l}.ln2.scale",
            f"decoder.layer.{l}.ln2.bias",
            f"decoder.layer.{l}.mlp.fc1.weight",
            f"decoder.layer.{l}.mlp.fc2.weight",
        ]
    names += ["final_ln.scale", "final_ln.bias", "lm_head.weight"]
    return names


def param_shapes(cfg: ModelConfig):
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    shapes = [(v, d)]
    for _ in range(cfg.n_layers):
        shapes += [(d,), (d,), (d, 3 * d), (d, d), (d,), (d,), (d, f), (f, d)]
    shapes += [(d,), (d,), (d, v)]
    return shapes


def init_params(cfg: ModelConfig, key):
    """Test-path initialization (the runtime initializes in Rust)."""
    params = []
    for name, shape in zip(param_names(cfg), param_shapes(cfg)):
        key, sub = jax.random.split(key)
        if name.endswith("scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("bias"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif name.startswith(("embedding", "lm_head")):
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            std = (2.0 / (cfg.d_model + shape[0])) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# MoR quantization of one GEMM operand
# ---------------------------------------------------------------------------


def _fq(x2d, fmt, partition, scaling, use_pallas):
    if use_pallas:
        return fqk.fake_quant_pallas(x2d, fmt, partition, scaling)
    br, bc = fqk.block_dims(partition, *x2d.shape)
    return ref.fake_quant_blocked(x2d, fmt, f"block{br}x{bc}", scaling)


def _partition_for(q: QuantConfig, direction: int):
    """Concrete partition name for a contraction direction.

    direction 0: contraction along columns → row-blocks for channel.
    direction 1: contraction along rows → column-blocks for channel.
    """
    if q.partition == "channel":
        return "channel_rows" if direction == 0 else "channel_cols"
    return q.partition


def mor_quantize(q: QuantConfig, x2d, th, direction: int):
    """Apply the MoR recipe to one 2-D GEMM operand.

    Returns (quantized tensor, relerr scalar, fallback fraction scalar).
    ``th`` is the traced E4M3 acceptance threshold (tensor-level recipe).
    The decision is data-dependent (jnp.where), made fresh every
    mini-batch — the paper's "runtime decision" — so a single compiled
    step serves the whole run.
    """
    if not q.enabled:
        z = jnp.float32(0.0)
        return x2d, z, z
    part = _partition_for(q, direction)
    br, bc = fqk.block_dims(part, *x2d.shape)
    part_rc = f"block{br}x{bc}"

    fq8 = _fq(x2d, "e4m3", part, q.scaling, q.use_pallas)
    relerr = ref.mean_relative_error(x2d, fq8)

    if q.recipe == "tensor_level":
        use = relerr < th
        out = jnp.where(use, fq8, x2d)
        fallback = 1.0 - use.astype(jnp.float32)
        return out, relerr, fallback

    # Sub-tensor recipes need the E5M2 candidate and per-block metrics.
    fq5 = _fq(x2d, "e5m2", part, q.scaling, q.use_pallas)
    s8 = ref.block_relerr_sums(x2d, fq8, br, bc)
    s5 = ref.block_relerr_sums(x2d, fq5, br, bc)
    m1 = s8 < s5  # Eq. (3): E4M3 wins
    if q.recipe == "subtensor2":
        # Two-way: E4M3 if M1, else BF16 (E5M2 is benchmark only).
        pick8 = jnp.repeat(jnp.repeat(m1, br, 0), bc, 1)
        out = jnp.where(pick8, fq8, x2d)
        fallback = 1.0 - m1.astype(jnp.float32).mean()
        return out, relerr, fallback
    if q.recipe == "subtensor3":
        m2 = ref.range_fits_e5m2(x2d, br, bc)  # Eq. (4)
        pick8 = jnp.repeat(jnp.repeat(m1, br, 0), bc, 1)
        pick5 = jnp.repeat(jnp.repeat(jnp.logical_and(~m1, m2), br, 0), bc, 1)
        out = jnp.where(pick8, fq8, jnp.where(pick5, fq5, x2d))
        fallback = jnp.logical_and(~m1, ~m2).astype(jnp.float32).mean()
        return out, relerr, fallback
    raise ValueError(f"unknown recipe {q.recipe!r}")


# ---------------------------------------------------------------------------
# Quantized linear layer: forward and manual backward
# ---------------------------------------------------------------------------
#
# Stats layout: stats[name] = (relerr, fallback) with name =
# (layer, linear_idx, tensor_idx, direction); tensor_idx 0=input,
# 1=weight, 2=grad. For non-channel partitions direction 1 duplicates 0.


def _record(stats, key, relerr, fallback):
    stats[key] = (relerr, fallback)


def linear_fwd(q, th, stats, layer, linear_idx, x2d, w):
    """y = fq(x) @ fq(w); returns y and the residuals for backward."""
    qx, rex, fbx = mor_quantize(q, x2d, th, direction=0)
    qw, rew, fbw = mor_quantize(q, w, th, direction=1)
    _record(stats, (layer, linear_idx, 0, 0), rex, fbx)
    _record(stats, (layer, linear_idx, 1, 0), rew, fbw)
    y = qx @ qw
    return y, (x2d, w)


def linear_bwd(q, th, stats, layer, linear_idx, res, dy2d):
    """Backward GEMMs with their own quantized operands (the paper's
    'and their transposes'):

      dx = fq(dy, dir0) @ fq(W, dir0 over W^T)  — W^T contracts along
           W's columns, i.e. direction 1 of W is the fwd use, direction
           0 of W^T == channel_rows of W^T == channel_cols of W.
      dW = fq(x, dir1)^T @ fq(dy, dir1)
    """
    x2d, w = res
    # dx = dy @ W^T: quantize dy row-wise (contraction along its cols)
    # and W^T column-wise — i.e. "direction 1" of the weight tensor.
    qdy0, reg0, fbg0 = mor_quantize(q, dy2d, th, direction=0)
    qwt, rew1, fbw1 = mor_quantize(q, w.T, th, direction=1)
    dx = qdy0 @ qwt
    # dW = x^T @ dy: x^T is the first operand (contraction along its
    # columns → row-blocks of x^T = *column*-blocks of x, the transpose
    # direction of the activation tensor, recorded as stats dir 1).
    qxt, rex1, fbx1 = mor_quantize(q, x2d.T, th, direction=0)
    qdy1, reg1, fbg1 = mor_quantize(q, dy2d, th, direction=1)
    dw = qxt @ qdy1
    _record(stats, (layer, linear_idx, 0, 1), rex1, fbx1)
    _record(stats, (layer, linear_idx, 1, 1), rew1, fbw1)
    _record(stats, (layer, linear_idx, 2, 0), reg0, fbg0)
    _record(stats, (layer, linear_idx, 2, 1), reg1, fbg1)
    return dx, dw


# ---------------------------------------------------------------------------
# Non-linear components (unquantized, per the paper's §4 scope)
# ---------------------------------------------------------------------------

LN_EPS = 1e-5


def layernorm_fwd(x, scale, bias):
    mu = x.mean(-1, keepdims=True)
    xc = x - mu
    var = (xc * xc).mean(-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + LN_EPS)
    xhat = xc * rstd
    return xhat * scale + bias, (xhat, rstd, scale)


def layernorm_bwd(res, dy):
    xhat, rstd, scale = res
    d = xhat.shape[-1]
    dxhat = dy * scale
    dscale = (dy * xhat).sum(axis=tuple(range(dy.ndim - 1)))
    dbias = dy.sum(axis=tuple(range(dy.ndim - 1)))
    # dx = rstd * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))
    m1 = dxhat.mean(-1, keepdims=True)
    m2 = (dxhat * xhat).mean(-1, keepdims=True)
    dx = rstd * (dxhat - m1 - xhat * m2)
    del d
    return dx, dscale, dbias


_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def gelu_fwd(x):
    inner = _GELU_C * (x + 0.044715 * x**3)
    t = jnp.tanh(inner)
    return 0.5 * x * (1.0 + t), (x, t)


def gelu_bwd(res, dy):
    x, t = res
    dinner = _GELU_C * (1.0 + 3 * 0.044715 * x * x)
    dt = (1.0 - t * t) * dinner
    return dy * (0.5 * (1.0 + t) + 0.5 * x * dt)


def attention_fwd(cfg, q3d, k3d, v3d):
    """Causal multi-head attention. Inputs (B, S, D) already projected."""
    B, S, D = q3d.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = q3d.reshape(B, S, H, hd).transpose(0, 2, 1, 3)  # B,H,S,hd
    k = k3d.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = v3d.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / (hd**0.5)  # B,H,S,S
    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    scores = jnp.where(mask > 0, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = p @ v  # B,H,S,hd
    out = out.transpose(0, 2, 1, 3).reshape(B, S, D)
    return out, (q, k, v, p)


def attention_bwd(cfg, res, dout):
    q, k, v, p = res
    B, H, S, hd = q.shape
    D = H * hd
    do = dout.reshape(B, S, H, hd).transpose(0, 2, 1, 3)  # B,H,S,hd
    dv = p.transpose(0, 1, 3, 2) @ do
    dp = do @ v.transpose(0, 1, 3, 2)  # B,H,S,S
    # softmax backward: ds = p * (dp - sum(dp * p))
    ds = p * (dp - (dp * p).sum(-1, keepdims=True))
    ds = ds / (hd**0.5)
    dq = ds @ k
    dk = ds.transpose(0, 1, 3, 2) @ q
    to3d = lambda t: t.transpose(0, 2, 1, 3).reshape(B, S, D)
    return to3d(dq), to3d(dk), to3d(dv)


# ---------------------------------------------------------------------------
# Full forward + manual backward
# ---------------------------------------------------------------------------


def unpack(cfg, params):
    """params list → (emb, layers[8 each], ln_f_scale, ln_f_bias, head)."""
    emb = params[0]
    layers = []
    i = 1
    for _ in range(cfg.n_layers):
        layers.append(tuple(params[i : i + 8]))
        i += 8
    lnf_s, lnf_b, head = params[i], params[i + 1], params[i + 2]
    return emb, layers, lnf_s, lnf_b, head


def forward(cfg, q, th, params, tokens, stats=None, save=False):
    """Forward pass. With save=True returns residuals for the manual
    backward; stats (dict) collects per-operand MoR telemetry."""
    if stats is None:
        stats = {}
    emb, layers, lnf_s, lnf_b, head = unpack(cfg, params)
    B, S = tokens.shape
    D = cfg.d_model
    x = emb[tokens]  # B,S,D
    res_layers = []
    for l, (ln1s, ln1b, wqkv, wproj, ln2s, ln2b, w1, w2) in enumerate(layers):
        h, r_ln1 = layernorm_fwd(x, ln1s, ln1b)
        h2d = h.reshape(B * S, D)
        qkv2d, r_qkv = linear_fwd(q, th, stats, l, 0, h2d, wqkv)
        qkv = qkv2d.reshape(B, S, 3 * D)
        q3d, k3d, v3d = jnp.split(qkv, 3, axis=-1)
        attn, r_attn = attention_fwd(cfg, q3d, k3d, v3d)
        a2d = attn.reshape(B * S, D)
        proj2d, r_proj = linear_fwd(q, th, stats, l, 1, a2d, wproj)
        x = x + proj2d.reshape(B, S, D)

        h2, r_ln2 = layernorm_fwd(x, ln2s, ln2b)
        f2d, r_fc1 = linear_fwd(q, th, stats, l, 2, h2.reshape(B * S, D), w1)
        g, r_gelu = gelu_fwd(f2d)
        o2d, r_fc2 = linear_fwd(q, th, stats, l, 3, g, w2)
        x = x + o2d.reshape(B, S, D)
        if save:
            res_layers.append((r_ln1, r_qkv, r_attn, r_proj, r_ln2, r_fc1, r_gelu, r_fc2))
    xf, r_lnf = layernorm_fwd(x, lnf_s, lnf_b)
    logits = xf.reshape(B * S, D) @ head  # lm_head unquantized (§4 scope)
    logits = logits.reshape(B, S, cfg.vocab_size)
    residuals = (tokens, res_layers, r_lnf, xf) if save else None
    return logits, stats, residuals


def loss_fwd(cfg, logits, tokens):
    """Next-token cross entropy; returns loss and residuals."""
    B, S, V = logits.shape
    lg = logits[:, :-1, :].reshape(-1, V)
    tg = tokens[:, 1:].reshape(-1)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tg[:, None], axis=-1)[:, 0]
    n = lg.shape[0]
    loss = (lse - ll).sum() / n
    return loss, (lg, tg, n)


def loss_bwd(cfg, res, B, S):
    """d loss / d logits."""
    lg, tg, n = res
    p = jax.nn.softmax(lg, axis=-1)
    onehot = jax.nn.one_hot(tg, cfg.vocab_size, dtype=jnp.float32)
    dlg = (p - onehot) / n
    V = cfg.vocab_size
    dlogits = jnp.zeros((B, S, V), jnp.float32)
    dlogits = dlogits.at[:, :-1, :].set(dlg.reshape(B, S - 1, V))
    return dlogits


def backward(cfg, q, th, params, residuals, dlogits, stats):
    """Manual backward through the whole model; returns grads in
    canonical parameter order."""
    emb, layers, lnf_s, lnf_b, head = unpack(cfg, params)
    tokens, res_layers, r_lnf, xf = residuals
    B, S = tokens.shape
    D = cfg.d_model

    # lm_head GEMM (unquantized).
    dlg2d = dlogits.reshape(B * S, cfg.vocab_size)
    xf2d = xf.reshape(B * S, D)
    dhead = xf2d.T @ dlg2d
    dxf = (dlg2d @ head.T).reshape(B, S, D)
    dx, dlnf_s, dlnf_b = layernorm_bwd(r_lnf, dxf)

    dlayers = []
    for l in reversed(range(cfg.n_layers)):
        (r_ln1, r_qkv, r_attn, r_proj, r_ln2, r_fc1, r_gelu, r_fc2) = res_layers[l]
        # MLP block: x = x_in + fc2(gelu(fc1(ln2(x_in))))
        do2d = dx.reshape(B * S, D)
        dg, dw2 = linear_bwd(q, th, stats, l, 3, r_fc2, do2d)
        df = gelu_bwd(r_gelu, dg)
        dh2_2d, dw1 = linear_bwd(q, th, stats, l, 2, r_fc1, df)
        dh2 = dh2_2d.reshape(B, S, D)
        dx_mlp, dln2s, dln2b = layernorm_bwd(r_ln2, dh2)
        dx = dx + dx_mlp  # residual add

        # Attention block: x = x_in + proj(attn(qkv(ln1(x_in))))
        dproj2d = dx.reshape(B * S, D)
        da2d, dwproj = linear_bwd(q, th, stats, l, 1, r_proj, dproj2d)
        dattn = da2d.reshape(B, S, D)
        dq3, dk3, dv3 = attention_bwd(cfg, r_attn, dattn)
        dqkv = jnp.concatenate([dq3, dk3, dv3], axis=-1).reshape(B * S, 3 * D)
        dh2d, dwqkv = linear_bwd(q, th, stats, l, 0, r_qkv, dqkv)
        dh = dh2d.reshape(B, S, D)
        dx_attn, dln1s, dln1b = layernorm_bwd(r_ln1, dh)
        dx = dx + dx_attn

        dlayers.append([dln1s, dln1b, dwqkv, dwproj, dln2s, dln2b, dw1, dw2])
    dlayers.reverse()

    # Embedding: scatter-add of dx at token positions.
    demb = jnp.zeros_like(emb).at[tokens.reshape(-1)].add(dx.reshape(B * S, D))

    grads = [demb]
    for dl in dlayers:
        grads.extend(dl)
    grads += [dlnf_s, dlnf_b, dhead]
    return grads


def loss_and_grads(cfg, q, params, tokens, th):
    """One fwd+bwd with MoR telemetry. Returns (loss, grads, stats)."""
    stats = {}
    logits, stats, residuals = forward(cfg, q, th, params, tokens, stats, save=True)
    loss, lres = loss_fwd(cfg, logits, tokens)
    B, S = tokens.shape
    dlogits = loss_bwd(cfg, lres, B, S)
    grads = backward(cfg, q, th, params, residuals, dlogits, stats)
    return loss, grads, stats


def pack_stats(cfg, stats):
    """Dict → dense [n_slots] arrays (relerr, fallback), slot order =
    rust QuantTensorId::flat: ((layer*4 + linear)*3 + tensor)*2 + dir."""
    n = cfg.n_layers * 4 * 3 * 2
    relerr = [jnp.float32(0.0)] * n
    fallback = [jnp.float32(0.0)] * n
    for (layer, linear, tensor, direction), (re, fb) in stats.items():
        idx = ((layer * 4 + linear) * 3 + tensor) * 2 + direction
        relerr[idx] = re
        fallback[idx] = fb
    return jnp.stack(relerr), jnp.stack(fallback)


# ---------------------------------------------------------------------------
# Train / eval steps (the AOT entry points)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def train_step(cfg: ModelConfig, q: QuantConfig, params, m, v, tokens,
               adam_t, lr, th):
    """One fused step: fwd + manual bwd + Adam. Returns
    (params', m', v', loss, relerr[n_slots], fallback[n_slots])."""
    loss, grads, stats = loss_and_grads(cfg, q, params, tokens, th)
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1**adam_t
    bc2 = 1.0 - ADAM_B2**adam_t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        mhat = mi / bc1
        vhat = vi / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    relerr, fallback = pack_stats(cfg, stats)
    return new_p, new_m, new_v, loss, relerr, fallback


def eval_step(cfg: ModelConfig, params, tokens, mask):
    """Masked eval: mean loss and next-token accuracy over positions
    with mask=1 (predicting tokens[:, i+1] from position i)."""
    qcfg = QuantConfig(recipe="baseline")
    logits, _, _ = forward(cfg, qcfg, jnp.float32(1.0), params, tokens)
    B, S, V = logits.shape
    lg = logits[:, :-1, :]
    tg = tokens[:, 1:]
    msk = mask[:, : S - 1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    n = jnp.maximum(msk.sum(), 1.0)
    loss = ((lse - ll) * msk).sum() / n
    pred = lg.argmax(-1)
    acc = ((pred == tg).astype(jnp.float32) * msk).sum() / n
    return loss, acc


def make_train_fn(cfg: ModelConfig, q: QuantConfig, batch: int):
    """Flat-signature train step for AOT lowering: positional args are
    params*N, m*N, v*N, tokens, adam_t, lr, th."""
    n = len(param_names(cfg))

    def fn(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        tokens, adam_t, lr, th = args[3 * n : 3 * n + 4]
        new_p, new_m, new_v, loss, relerr, fallback = train_step(
            cfg, q, params, m, v, tokens, adam_t, lr, th
        )
        # Anchor every scalar input into the graph: jax DCEs unused
        # parameters at trace time (the baseline recipe ignores th),
        # which would change the artifact's input arity.
        loss = loss + 0.0 * th + 0.0 * lr + 0.0 * adam_t
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, relerr, fallback)

    specs = []
    for shape in param_shapes(cfg):
        specs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
    specs = specs * 3
    specs.append(jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32))
    specs += [jax.ShapeDtypeStruct((), jnp.float32)] * 3
    return fn, specs


def make_eval_fn(cfg: ModelConfig, batch: int):
    n = len(param_names(cfg))

    def fn(*args):
        params = list(args[:n])
        tokens, mask = args[n], args[n + 1]
        return eval_step(cfg, params, tokens, mask)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(cfg)]
    specs.append(jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.float32))
    return fn, specs


def make_quant_fn(fmt: str, partition: str, scaling: str, rows: int, cols: int,
                  use_pallas: bool = True):
    """Standalone fake-quant kernel for cross-validation and benches:
    (x) → (qdq(x), mean relative error)."""

    def fn(x):
        if use_pallas:
            y = fqk.fake_quant_pallas(x, fmt, partition, scaling)
        else:
            y = ref.fake_quant_blocked(x, fmt, partition, scaling)
        return y, ref.mean_relative_error(x, y)

    return fn, [jax.ShapeDtypeStruct((rows, cols), jnp.float32)]
