"""Pure-jnp reference oracle for the MoR quantization numerics.

Everything here is the *specification*: the Pallas kernels
(`fake_quant.py`) and the Rust host mirror (`rust/src/quant/`) are both
tested against these functions. Keep this file dependency-light and
obviously-correct; speed does not matter.

Paper mapping:
  * ``gam_scales``          — Algorithm 1 (Group Amax Mantissa scaling)
  * ``fake_quant_blocked``  — Figure 4 pipeline over a §3 partition
  * ``mean_relative_error`` — Eq. (1)-(2)
  * ``block_relerr_sums``   — Eq. (3) metric M1 inputs
  * ``range_fits_e5m2``     — Eq. (4) metric M2
"""

import jax.numpy as jnp
import numpy as np

# q_amax of the formats (Section 2 of the paper).
E4M3_MAX = 448.0
E5M2_MAX = 57344.0
E5M2_MIN_NORMAL = 2.0 ** -14

FP8_DTYPES = {
    "e4m3": jnp.float8_e4m3fn,
    "e5m2": jnp.float8_e5m2,
}
FP8_MAX = {"e4m3": E4M3_MAX, "e5m2": E5M2_MAX}


def qdq_elem(x, fmt: str):
    """Scalar/array quantize-dequantize through an FP8 dtype (saturating:
    the caller guarantees |x| <= q_amax via scaling, so saturation only
    guards the exact-max rounding edge)."""
    dt = FP8_DTYPES[fmt]
    clipped = jnp.clip(x, -FP8_MAX[fmt], FP8_MAX[fmt])
    return clipped.astype(dt).astype(jnp.float32)


def qdq_bf16(x):
    """BF16 round-trip (the fallback 'representation')."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def mantissa_exponent(s):
    """frexp-style decomposition s = m * 2^e with m in [1, 2).

    (jnp.frexp returns m in [0.5, 1); Algorithm 1's convention is the
    IEEE significand in [1, 2), so shift by one.)
    """
    m, e = jnp.frexp(s)
    return m * 2.0, e - 1


def block_shape_for(partition: str, rows: int, cols: int, block: int = 128):
    """Block (br, bc) for a partition name, matching
    rust/src/quant/partition.rs. 'channel_rows' = one row per block."""
    if partition == "tensor":
        return rows, cols
    if partition.startswith("block"):
        r, c = partition[len("block"):].split("x")
        return int(r), int(c)
    if partition == "channel_rows":
        return 1, cols
    if partition == "channel_cols":
        return rows, 1
    raise ValueError(f"unknown partition {partition!r}")


def _blockwise_amax(x, br, bc):
    """Per-block amax, shape (R/br, C/bc); requires divisible dims."""
    r, c = x.shape
    assert r % br == 0 and c % bc == 0, (x.shape, br, bc)
    xb = jnp.abs(x).reshape(r // br, br, c // bc, bc)
    return xb.max(axis=(1, 3))


def gam_scales(x, q_amax: float, br: int, bc: int):
    """Algorithm 1 with group = whole tensor.

    Returns (scale per block, group mantissa). scale = m_g * 2^e_b with
    the round-down rule; all-zero blocks get scale 1.0.
    """
    g_amax = jnp.abs(x).max()
    s_g = q_amax / jnp.where(g_amax > 0, g_amax, 1.0)
    m_g, _ = mantissa_exponent(s_g)
    b_amax = _blockwise_amax(x, br, bc)
    s_b = q_amax / jnp.where(b_amax > 0, b_amax, 1.0)
    m_b, e_b = mantissa_exponent(s_b)
    e = jnp.where(m_g <= m_b, e_b, e_b - 1)
    scale = jnp.where(b_amax > 0, m_g * jnp.exp2(e.astype(jnp.float32)), 1.0)
    return scale, m_g


def amax_scales(x, q_amax: float, br: int, bc: int):
    """Standard per-block FP32 amax scaling (the §4.1.2 baseline)."""
    b_amax = _blockwise_amax(x, br, bc)
    return jnp.where(b_amax > 0, q_amax / jnp.where(b_amax > 0, b_amax, 1.0), 1.0)


def e8m0_scales(x, q_amax: float, br: int, bc: int):
    """Pure power-of-two scaling: 2^floor(log2(q_amax / b_amax))."""
    b_amax = _blockwise_amax(x, br, bc)
    s = q_amax / jnp.where(b_amax > 0, b_amax, 1.0)
    _, e = mantissa_exponent(s)
    return jnp.where(b_amax > 0, jnp.exp2(e.astype(jnp.float32)), 1.0)


SCALERS = {"gam": gam_scales, "amax": amax_scales, "e8m0": e8m0_scales}


def scales_for(x, fmt: str, partition: str, scaling: str, block: int = 128):
    rows, cols = x.shape
    br, bc = block_shape_for(partition, rows, cols, block)
    fn = SCALERS[scaling]
    out = fn(x, FP8_MAX[fmt], br, bc)
    scale = out[0] if isinstance(out, tuple) else out
    return scale, (br, bc)


def _expand(scale, br, bc):
    """Broadcast per-block scales back to element shape."""
    return jnp.repeat(jnp.repeat(scale, br, axis=0), bc, axis=1)


def fake_quant_blocked(x, fmt: str, partition: str, scaling: str = "gam",
                       block: int = 128):
    """The Figure 4 pipeline: scale → cast fp8 → cast back → de-scale.

    Returns the dequantized tensor (float32, same shape).
    """
    if fmt == "bf16":
        return qdq_bf16(x)
    scale, (br, bc) = scales_for(x, fmt, partition, scaling, block)
    s = _expand(scale, br, bc)
    return qdq_elem(x * s, fmt) / s


def relerr_terms(x, q):
    """|x - q| / |x| over non-zero x, 0 elsewhere (Eq. 2 summands)."""
    nz = x != 0
    return jnp.where(nz, jnp.abs((x - q) / jnp.where(nz, x, 1.0)), 0.0)


def mean_relative_error(x, q):
    """Eq. (1)-(2): mean relative error over non-zero elements."""
    nz = (x != 0).sum()
    return relerr_terms(x, q).sum() / jnp.maximum(nz, 1).astype(jnp.float32)


def block_relerr_sums(x, q, br, bc):
    """Eq. (3): per-block sums of relative error."""
    r, c = x.shape
    t = relerr_terms(x, q).reshape(r // br, br, c // bc, bc)
    return t.sum(axis=(1, 3))


def range_fits_e5m2(x, br, bc):
    """Eq. (4) metric M2 per block: amax/amin_nonzero < E5M2 normal ratio."""
    r, c = x.shape
    a = jnp.abs(x).reshape(r // br, br, c // bc, bc)
    amax = a.max(axis=(1, 3))
    amin = jnp.where(a > 0, a, jnp.inf).min(axis=(1, 3))
    ratio = E5M2_MAX / E5M2_MIN_NORMAL
    return jnp.where(jnp.isfinite(amin), amax / amin < ratio, True)


def np_reference_qdq_e4m3(x: np.ndarray) -> np.ndarray:
    """A from-scratch numpy E4M3 quantizer (independent of ml_dtypes),
    used to validate that our use of jnp.float8_e4m3fn matches the
    format spec. Saturating RNE."""
    out = np.zeros_like(x, dtype=np.float32)
    for idx, v in np.ndenumerate(x):
        if not np.isfinite(v):
            out[idx] = np.nan
            continue
        a = abs(float(v))
        if a == 0.0:
            out[idx] = 0.0
            continue
        a = min(a, 448.0)
        e = int(np.floor(np.log2(a))) if a > 0 else 0
        e = max(e, -6)  # subnormal floor
        step = 2.0 ** (e - 3)
        q = round(a / step)
        # round-half-to-even
        if abs(a / step - round(a / step)) == 0.5:
            q = int(a / step)
            if q % 2 == 1:
                q += 1
        got = min(q * step, 448.0)
        out[idx] = np.copysign(got, v)
    return out
