"""Layer-1 Pallas kernels: GAM-scaled fake quantization (Fig. 4).

One Pallas program instance handles one MoR partition block: the
BlockSpec grid *is* the quantization partition, which is exactly the
HBM↔VMEM schedule a TPU implementation would use (DESIGN.md
§Hardware-Adaptation): the block lives in VMEM (128×128×4B = 64 KiB),
the GAM group mantissa arrives as a broadcast scalar, and the kernel is
a pure VPU elementwise pass (scale → cast fp8 → cast back → de-scale).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs under the Rust runtime. Correctness is pinned against ``ref.py``
(pytest + hypothesis) and against the bit-exact Rust mirror (the
integration_quant cross-check).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_FP8 = {"e4m3": jnp.float8_e4m3fn, "e5m2": jnp.float8_e5m2}


def _mantissa_exponent(s):
    m, e = jnp.frexp(s)
    return m * 2.0, e - 1


def _fq_kernel(mg_ref, x_ref, o_ref, *, fmt: str, scaling: str):
    """Fake-quantize one partition block.

    mg_ref: (1,1) group mantissa (GAM; ignored by amax/e8m0 scaling).
    x_ref/o_ref: (br, bc) block in f32.
    """
    x = x_ref[...]
    q_amax = ref.FP8_MAX[fmt]
    amax = jnp.max(jnp.abs(x))
    safe_amax = jnp.where(amax > 0, amax, 1.0)
    s_ideal = q_amax / safe_amax
    if scaling == "gam":
        m_g = mg_ref[0, 0]
        m_b, e_b = _mantissa_exponent(s_ideal)
        # Algorithm 1 round-down: never saturate when m_g > m_b.
        e = jnp.where(m_g <= m_b, e_b, e_b - 1)
        s = m_g * jnp.exp2(e.astype(jnp.float32))
    elif scaling == "e8m0":
        _, e_b = _mantissa_exponent(s_ideal)
        s = jnp.exp2(e_b.astype(jnp.float32))
    elif scaling == "amax":
        s = s_ideal
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown scaling {scaling!r}")
    s = jnp.where(amax > 0, s, 1.0)
    scaled = jnp.clip(x * s, -q_amax, q_amax)
    y = scaled.astype(_FP8[fmt]).astype(jnp.float32) / s
    o_ref[...] = y


def pick_block(dim: int, want: int) -> int:
    """Largest power-of-two divisor of ``dim`` that is <= ``want``.

    The model's dims are all multiples of 64, so the 128×128 paper
    default degrades gracefully (e.g. 192 → 64-wide blocks) while
    keeping jnp-reshape blocking exact. Mirrors nothing in Rust: the
    Rust host mirror handles ragged blocks natively, and cross-check
    artifacts use divisible shapes.
    """
    b = 1
    while b * 2 <= min(dim, want) and dim % (b * 2) == 0:
        b *= 2
    return b


def block_dims(partition: str, rows: int, cols: int, want: int = 128):
    """Partition name → concrete (br, bc) for this tensor shape."""
    if partition == "tensor":
        return rows, cols
    if partition.startswith("block"):
        r, c = partition[len("block"):].split("x")
        return pick_block(rows, int(r)), pick_block(cols, int(c))
    if partition == "channel_rows":
        return 1, cols
    if partition == "channel_cols":
        return rows, 1
    raise ValueError(f"unknown partition {partition!r}")


def group_mantissa(x, fmt: str):
    """GAM group metadata (group = whole tensor), shape (1,1)."""
    g_amax = jnp.abs(x).max()
    s_g = ref.FP8_MAX[fmt] / jnp.where(g_amax > 0, g_amax, 1.0)
    m_g, _ = _mantissa_exponent(s_g)
    return m_g.reshape(1, 1).astype(jnp.float32)


def fake_quant_pallas(x, fmt: str, partition: str, scaling: str = "gam",
                      want_block: int = 128):
    """Fake-quantize a 2-D f32 tensor through the Pallas kernel."""
    rows, cols = x.shape
    br, bc = block_dims(partition, rows, cols, want_block)
    grid = (rows // br, cols // bc)
    kernel = functools.partial(_fq_kernel, fmt=fmt, scaling=scaling)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # broadcast m_g
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(group_mantissa(x, fmt), x)
