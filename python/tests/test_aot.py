"""AOT path correctness: HLO-text emission, artifact ABI arity, and the
input-anchoring guarantee (no parameter may be DCE'd away, or the Rust
runtime's buffer count would mismatch)."""

import re

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_to_hlo_text_emits_parseable_module():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(fn, [spec, spec])
    assert "HloModule" in text
    assert "parameter(0)" in text and "parameter(1)" in text
    # Tuple root (return_tuple=True) so the Rust side can decompose.
    assert re.search(r"ROOT.*tuple", text)


def test_train_fn_keeps_all_inputs():
    """Every train variant must keep exactly 3N+4 parameters in the
    lowered HLO — the Rust TrainSession ABI."""
    cfg = M.PRESETS["tiny"]
    n = len(M.param_names(cfg))
    for name, q in aot.TRAIN_VARIANTS[:2]:  # baseline + default MoR
        fn, specs = M.make_train_fn(cfg, q, batch=2)
        assert len(specs) == 3 * n + 4
        text = aot.to_hlo_text(fn, specs)
        for i in range(3 * n + 4):
            assert f"parameter({i})" in text, (name, i)


def test_eval_fn_arity():
    cfg = M.PRESETS["tiny"]
    n = len(M.param_names(cfg))
    fn, specs = M.make_eval_fn(cfg, batch=2)
    assert len(specs) == n + 2
    text = aot.to_hlo_text(fn, specs)
    for i in range(n + 2):
        assert f"parameter({i})" in text


def test_manifest_variant_names_match_rust_expectations():
    """The report harness addresses artifacts by these exact names."""
    names = {name for name, _ in aot.TRAIN_VARIANTS}
    for expected in [
        "train_baseline",
        "train_mor_tensor_block",
        "train_mor_tensor_block_jnp",
        "train_mor_tensor_tensor",
        "train_mor_tensor_channel",
        "train_mor_tensor_block64",
        "train_mor_tensor_block_amax",
        "train_mor_tensor_block_e8m0",
        "train_mor_subtensor_two_way",
        "train_mor_subtensor_three_way",
    ]:
        assert expected in names
    quant_names = {name for name, *_ in aot.QUANT_VARIANTS}
    assert "quant_e4m3_gam_block128" in quant_names
    assert len(quant_names) == len(aot.QUANT_VARIANTS)


def test_stats_len_formula():
    for preset in M.PRESETS.values():
        assert preset.n_layers * 4 * 3 * 2 == len(
            M.pack_stats(preset, _full_stats(preset))[0]
        )


def _full_stats(cfg):
    z = jnp.float32(0.0)
    return {
        (l, li, t, d): (z, z)
        for l in range(cfg.n_layers)
        for li in range(4)
        for t in range(3)
        for d in range(2)
    }
