"""L1 correctness: the Pallas fake-quant kernel vs the pure-jnp oracle
(ref.py), including hypothesis sweeps over shapes, partitions, scalings
and value distributions. This is the core kernel correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from compile.kernels import fake_quant as fqk
from compile.kernels import ref

PARTITIONS = ["tensor", "block128x128", "block64x64", "channel_rows", "channel_cols"]
SCALINGS = ["gam", "amax", "e8m0"]
FORMATS = ["e4m3", "e5m2"]


def rand(shape, scale=1.0, seed=0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def ref_blocked(x, fmt, partition, scaling):
    """ref.fake_quant_blocked with the same shape-adaptive block rule
    the Pallas wrapper applies (ref itself requires divisible dims)."""
    br, bc = fqk.block_dims(partition, *x.shape)
    return ref.fake_quant_blocked(x, fmt, f"block{br}x{bc}", scaling)


@pytest.mark.parametrize("partition", PARTITIONS)
@pytest.mark.parametrize("scaling", SCALINGS)
@pytest.mark.parametrize("fmt", FORMATS)
def test_pallas_matches_ref(partition, scaling, fmt):
    x = rand((256, 128), 3.0, seed=1)
    a = np.asarray(fqk.fake_quant_pallas(x, fmt, partition, scaling))
    b = np.asarray(ref.fake_quant_blocked(x, fmt, partition, scaling))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("fmt", FORMATS)
def test_zero_tensor_passthrough(fmt):
    x = jnp.zeros((128, 128), jnp.float32)
    y = fqk.fake_quant_pallas(x, fmt, "block128x128", "gam")
    np.testing.assert_array_equal(np.asarray(y), np.zeros((128, 128)))


def test_wide_dynamic_range_no_saturation():
    """GAM must never saturate: outputs stay finite and within q_amax of
    the original magnitude envelope."""
    x = rand((128, 128), 1.0, seed=2) * (10.0 ** (jnp.arange(128 * 128).reshape(128, 128) % 9 - 4))
    for scaling in SCALINGS:
        y = np.asarray(fqk.fake_quant_pallas(x, "e4m3", "block128x128", scaling))
        assert np.isfinite(y).all(), scaling
        assert np.abs(y).max() <= np.abs(np.asarray(x)).max() * 1.01


def test_gam_relerr_close_to_amax_relerr():
    """GAM loses < one binade of scale vs ideal amax scaling, so its
    relative error should be within ~2x of amax scaling."""
    x = rand((256, 256), 2.0, seed=3)
    e_gam = float(ref.mean_relative_error(x, ref.fake_quant_blocked(x, "e4m3", "block128x128", "gam")))
    e_amax = float(ref.mean_relative_error(x, ref.fake_quant_blocked(x, "e4m3", "block128x128", "amax")))
    assert e_gam < 2.0 * e_amax + 1e-6


def test_relative_error_scale_invariance():
    x = rand((64, 64), 1.0, seed=4)
    e1 = float(ref.mean_relative_error(x, ref.fake_quant_blocked(x, "e4m3", "tensor", "gam")))
    for k in [1e-4, 1e3]:
        ek = float(
            ref.mean_relative_error(k * x, ref.fake_quant_blocked(k * x, "e4m3", "tensor", "gam"))
        )
        assert abs(e1 - ek) < 0.002, (k, e1, ek)


def test_e4m3_matches_independent_numpy_reference():
    """jnp.float8_e4m3fn (saturating clip path) vs the from-scratch
    numpy E4M3 quantizer — pins the dtype semantics we rely on."""
    vals = np.array(
        [0.0, 1.0, -1.0, 0.3, 447.9, 448.0, 1.0625, 1.1875, 0.001, 0.002, -17.3, 300.0],
        np.float32,
    )
    ours = np.asarray(ref.qdq_elem(jnp.array(vals), "e4m3"))
    theirs = ref.np_reference_qdq_e4m3(vals)
    np.testing.assert_allclose(ours, theirs, rtol=0, atol=0)


def test_block_dims_rules():
    assert fqk.block_dims("block128x128", 512, 192) == (128, 64)
    assert fqk.block_dims("block128x128", 64, 64) == (64, 64)
    assert fqk.block_dims("tensor", 100, 7) == (100, 7)
    assert fqk.block_dims("channel_rows", 8, 16) == (1, 16)
    assert fqk.block_dims("channel_cols", 8, 16) == (8, 1)
    assert fqk.pick_block(192, 128) == 64
    assert fqk.pick_block(896, 128) == 128


def test_eq4_range_metric():
    x = jnp.array([[1.0, 2.0], [1e-9, 3.0]], jnp.float32)
    fits = np.asarray(ref.range_fits_e5m2(x, 1, 2))
    assert fits[0, 0]  # range 2
    assert not fits[1, 0]  # range 3e9 >> 2^29.8


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        rows_pow=st.integers(0, 3),
        cols_pow=st.integers(0, 3),
        scale_log=st.integers(-12, 12),
        partition=st.sampled_from(PARTITIONS),
        scaling=st.sampled_from(SCALINGS),
        fmt=st.sampled_from(FORMATS),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_pallas_vs_ref(rows_pow, cols_pow, scale_log, partition, scaling, fmt, seed):
        rows, cols = 32 << rows_pow, 32 << cols_pow
        x = rand((rows, cols), 10.0**scale_log / 4.0, seed=seed % 65536)
        a = np.asarray(fqk.fake_quant_pallas(x, fmt, partition, scaling))
        b = np.asarray(ref_blocked(x, fmt, partition, scaling))
        np.testing.assert_array_equal(a, b)
        assert np.isfinite(a).all()

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31), scaling=st.sampled_from(SCALINGS))
    def test_hypothesis_relerr_bound(seed, scaling):
        """E4M3 with per-block scaling on Gaussian data keeps the mean
        relative error under the half-ulp+scale-slack analytic bound."""
        x = rand((128, 128), 3.0, seed=seed % 65536)
        y = ref.fake_quant_blocked(x, "e4m3", "block64x64", scaling)
        assert float(ref.mean_relative_error(x, y)) < 0.07
