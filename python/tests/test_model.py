"""L2 correctness: manual backward vs jax.grad, train-step semantics,
MoR decision plumbing, and the stats ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]
BASE = M.QuantConfig(recipe="baseline")


def make_inputs(batch=4, seed=0):
    params = M.init_params(CFG, jax.random.PRNGKey(seed))
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (batch, CFG.seq_len), 0, CFG.vocab_size
    )
    return params, tokens


def test_manual_backward_matches_autodiff():
    params, tokens = make_inputs()
    th = jnp.float32(1.0)
    loss_m, grads_m, _ = M.loss_and_grads(CFG, BASE, params, tokens, th)

    def loss_fn(params):
        logits, _, _ = M.forward(CFG, BASE, th, params, tokens)
        return M.loss_fwd(CFG, logits, tokens)[0]

    loss_a, grads_a = jax.value_and_grad(loss_fn)(params)
    assert abs(float(loss_m) - float(loss_a)) < 1e-5
    for name, gm, ga in zip(M.param_names(CFG), grads_m, grads_a):
        scale = float(jnp.abs(ga).max()) + 1e-20
        rel = float(jnp.abs(gm - ga).max()) / scale
        assert rel < 1e-4, (name, rel)


@pytest.mark.parametrize(
    "recipe,partition",
    [
        ("tensor_level", "block128x128"),
        ("tensor_level", "tensor"),
        ("tensor_level", "channel"),
        ("subtensor2", "block128x128"),
        ("subtensor3", "block128x128"),
    ],
)
def test_quantized_backward_close_to_autodiff(recipe, partition):
    """With quantization ON, manual grads should still be close to the
    unquantized autodiff grads (FP8 noise, not structural error)."""
    params, tokens = make_inputs(seed=3)
    q = M.QuantConfig(recipe, partition, "gam", use_pallas=False)
    th = jnp.float32(0.045)
    loss_m, grads_m, stats = M.loss_and_grads(CFG, q, params, tokens, th)

    def loss_fn(params):
        logits, _, _ = M.forward(CFG, BASE, th, params, tokens)
        return M.loss_fwd(CFG, logits, tokens)[0]

    loss_a, grads_a = jax.value_and_grad(loss_fn)(params)
    assert abs(float(loss_m) - float(loss_a)) < 0.05 * abs(float(loss_a))
    # Quantized linear weights see fp8 noise; LN/embedding grads flow
    # through quantized GEMMs too. Allow a generous but bounded gap.
    for name, gm, ga in zip(M.param_names(CFG), grads_m, grads_a):
        na = float(jnp.linalg.norm(ga)) + 1e-20
        rel = float(jnp.linalg.norm(gm - ga)) / na
        assert rel < 0.35, (name, rel)
    assert len(stats) == CFG.n_layers * 4 * 3 * 2


def test_stats_slots_complete_and_ordered():
    params, tokens = make_inputs(seed=5)
    q = M.QuantConfig("tensor_level", "block128x128", "gam", use_pallas=False)
    _, _, stats = M.loss_and_grads(CFG, q, params, tokens, jnp.float32(0.045))
    relerr, fallback = M.pack_stats(CFG, stats)
    n = CFG.n_layers * 4 * 3 * 2
    assert relerr.shape == (n,)
    assert fallback.shape == (n,)
    # Every (layer, linear, tensor, dir) combination present.
    for l in range(CFG.n_layers):
        for li in range(4):
            for t in range(3):
                for d in range(2):
                    assert (l, li, t, d) in stats
    # Relerr values sane.
    re = np.asarray(relerr)
    assert (re >= 0).all() and (re < 1.0).all()


def test_threshold_controls_fallback():
    params, tokens = make_inputs(seed=7)
    q = M.QuantConfig("tensor_level", "tensor", "gam", use_pallas=False)
    _, _, stats_strict = M.loss_and_grads(CFG, q, params, tokens, jnp.float32(1e-9))
    _, _, stats_loose = M.loss_and_grads(CFG, q, params, tokens, jnp.float32(0.9))
    fb_strict = float(M.pack_stats(CFG, stats_strict)[1].mean())
    fb_loose = float(M.pack_stats(CFG, stats_loose)[1].mean())
    assert fb_strict == 1.0
    assert fb_loose == 0.0


def test_baseline_recipe_is_exact_passthrough():
    params, tokens = make_inputs(seed=9)
    th = jnp.float32(0.045)
    l1, _, _ = M.forward(CFG, BASE, th, params, tokens)
    q = M.QuantConfig("tensor_level", "tensor", "gam", use_pallas=False)
    l2, _, _ = M.forward(CFG, q, jnp.float32(1e9), params, tokens)
    # With an infinite threshold every tensor quantizes... so instead
    # compare baseline vs threshold=0 (always fall back → passthrough).
    l3, _, _ = M.forward(CFG, q, jnp.float32(-1.0), params, tokens)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l3))
    assert not np.array_equal(np.asarray(l1), np.asarray(l2))


def test_train_step_decreases_loss():
    params, tokens = make_inputs(batch=8, seed=11)
    q = M.QuantConfig("tensor_level", "block128x128", "gam", use_pallas=False)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    losses = []
    step = jax.jit(
        lambda p, m, v, t, at: M.train_step(
            CFG, q, p, m, v, t, at, jnp.float32(1e-3), jnp.float32(0.045)
        )
    )
    for i in range(8):
        params, m, v, loss, relerr, fallback = step(params, m, v, tokens, jnp.float32(i + 1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_eval_step_masked_accuracy():
    params, tokens = make_inputs(batch=4, seed=13)
    mask = jnp.ones((4, CFG.seq_len), jnp.float32)
    loss, acc = M.eval_step(CFG, params, tokens, mask)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0
    # Zero mask: defined behaviour (no NaN).
    loss0, acc0 = M.eval_step(CFG, params, tokens, jnp.zeros_like(mask))
    assert np.isfinite(float(loss0)) and float(acc0) == 0.0


def test_eval_accuracy_on_predictable_sequence():
    """A cyclic sequence must be near-perfectly predictable by a model
    that has the pattern in-context... an untrained model won't ace it,
    but a trained-on-batch model should beat chance. Here we only check
    the metric wiring: accuracy of predicting a constant sequence with
    an untrained model is already >> 1/vocab after few-step training."""
    params, _ = make_inputs(seed=15)
    tokens = jnp.full((2, CFG.seq_len), 7, jnp.int32)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    for i in range(12):
        params, m, v, loss, _, _ = M.train_step(
            CFG, M.QuantConfig(), params, m, v, tokens,
            jnp.float32(i + 1), jnp.float32(3e-3), jnp.float32(0.045),
        )
    mask = jnp.ones((2, CFG.seq_len), jnp.float32)
    _, acc = M.eval_step(CFG, params, tokens, mask)
    assert float(acc) > 0.9, float(acc)


def test_param_shapes_match_rust_convention():
    names = M.param_names(CFG)
    shapes = M.param_shapes(CFG)
    assert names[0] == "embedding.weight" and shapes[0] == (256, 64)
    assert names[3] == "decoder.layer.0.self_attention.linear_qkv.weight"
    assert shapes[3] == (64, 192)
    assert names[-1] == "lm_head.weight" and shapes[-1] == (64, 256)
    assert len(names) == 1 + 8 * CFG.n_layers + 3
    total = sum(int(np.prod(s)) for s in shapes)
    assert total == 256 * 64 * 2 + CFG.n_layers * (2 * 64 + 64 * 192 + 64 * 64 + 2 * 64 + 64 * 256 + 256 * 64) + 2 * 64
