//! Vendored stand-in for the `xla` PJRT bindings used by
//! `rust/src/runtime/client.rs`.
//!
//! The offline build environment has neither crates.io nor the
//! `xla_extension` C++ distribution, so this crate provides:
//!
//! * a fully functional host [`Literal`] — a shaped, typed (f32/i32)
//!   array container with the reshape/tuple/readback API the runtime
//!   uses. The host execution backend (`mor::runtime::host`) stores
//!   training state in these, so everything except HLO execution works.
//! * stub PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`], ...)
//!   whose `compile`/`execute` return a descriptive error. Artifact-
//!   driven paths self-skip when artifacts are absent, and report a
//!   clear message instead of a link failure when they are present.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml`; the API surface here matches the subset the
//! runtime consumes.

use std::borrow::Borrow;
use std::fmt;

/// Error type; converts into `anyhow::Error` at the runtime layer via
/// the std-error blanket impl.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "XLA/PJRT execution is unavailable in this offline build \
(the `xla` crate is a vendored host stub); use the host backend \
(`Runtime::host`) or link the real xla_extension bindings";

// ---------------------------------------------------------------------------
// Literal: a real host array container
// ---------------------------------------------------------------------------

/// Element payload of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A shaped host array (or tuple of arrays), mirroring the subset of
/// `xla::Literal` the runtime uses.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

/// Scalar element types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn vec_into(v: Vec<Self>) -> LiteralData;
    fn vec_from(d: &LiteralData) -> Option<Vec<Self>>;
    const NAME: &'static str;
}

impl NativeType for f32 {
    fn vec_into(v: Vec<f32>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn vec_from(d: &LiteralData) -> Option<Vec<f32>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    fn vec_into(v: Vec<i32>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn vec_from(d: &LiteralData) -> Option<Vec<i32>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "i32";
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::vec_into(v.to_vec()) }
    }

    /// Rank-0 scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::vec_into(vec![v]) }
    }

    /// Tuple literal (what multi-output executables return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: LiteralData::Tuple(parts) }
    }

    fn volume(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => 0,
        }
    }

    /// Reinterpret with new dimensions (volume must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::msg("cannot reshape a tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.volume() {
            return Err(Error::msg(format!(
                "reshape {:?} -> {dims:?}: volume mismatch ({} elements)",
                self.dims,
                self.volume()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(parts) => Ok(parts),
            _ => Err(Error::msg("literal is not a tuple")),
        }
    }

    /// Copy out the flat element buffer.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::vec_from(&self.data)
            .ok_or_else(|| Error::msg(format!("literal does not hold {}", T::NAME)))
    }

    /// First element (scalar readback).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::msg("empty literal"))
    }

    /// Array shape (errors on tuples, like the real bindings).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            LiteralData::Tuple(_) => Err(Error::msg("tuple literal has no array shape")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// PJRT stubs
// ---------------------------------------------------------------------------

/// Parsed HLO module (stores the text; the stub cannot compile it).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// PJRT client stub. Construction succeeds (so `Runtime::load` can
/// parse and validate manifests); `compile` reports the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(STUB_MSG))
    }
}

/// Compiled-executable stub (unconstructible outside this crate; the
/// stub client never produces one).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(STUB_MSG))
    }
}

/// Device buffer stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_and_tuple() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i32, 2])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![1, 2]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn reshape_checks_volume() {
        let l = Literal::vec1(&[0i32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn stub_client_compiles_to_error() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { text: "HloModule m".into() };
        let e = c.compile(&comp).unwrap_err();
        assert!(format!("{e}").contains("offline"));
    }
}
