//! Vendored minimal replacement for the `anyhow` crate, API-compatible
//! with the subset this repository uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`.
//!
//! The build environment is fully offline (no crates.io), so external
//! dependencies are vendored under `rust/vendor/`. Like the real crate,
//! `Error` deliberately does **not** implement `std::error::Error`:
//! that keeps the blanket `From<E: std::error::Error>` conversion free
//! of coherence conflicts with the reflexive `From<Error> for Error`.

use std::fmt;

/// A context-carrying error. The chain is stored innermost-first;
/// `Display` shows the outermost message, `{:#}` the full chain
/// separated by `: ` (matching anyhow's alternate formatting).
pub struct Error {
    /// Message chain, innermost (root cause) first.
    chain: Vec<String>,
}

impl Error {
    /// Build from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Used by the `anyhow!(expr)` macro arm: accept anything already
    /// convertible to an `Error` (including `Error` itself) without
    /// flattening its chain.
    pub fn from_any<T: Into<Error>>(t: T) -> Error {
        t.into()
    }

    /// Push an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The messages from outermost to innermost (anyhow's `chain()`
    /// order).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain.iter().rev() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(self.chain.last().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug prints the full chain like anyhow's multi-line report,
        // compacted to one line.
        write!(f, "{self:#}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut cur: Option<&dyn std::error::Error> = Some(&e);
        while let Some(c) = cur {
            chain.push(c.to_string());
            cur = c.source();
        }
        chain.reverse(); // store innermost first
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option` (the two impls the repo
/// relies on).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, a displayable
/// expression, or an existing error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_any($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err()
            .context("loading runtime");
        assert_eq!(format!("{e}"), "loading runtime");
        assert_eq!(format!("{e:#}"), "loading runtime: reading manifest: missing file");
        assert_eq!(e.root_cause(), "missing file");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("artifact {name:?} missing");
        assert_eq!(format!("{e}"), "artifact \"x\" missing");
        let e2 = anyhow!("field {} of {}", 3, name);
        assert_eq!(format!("{e2}"), "field 3 of x");
        let e3 = anyhow!(e2); // expr arm passes an Error through
        assert_eq!(format!("{e3}"), "field 3 of x");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        let v = Some(3).with_context(|| "unused").unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
