//! Runtime-dispatched AVX2 vector microkernels for the packed GEMM
//! layer, bit-identical to the scalar kernels in [`super::gemm`].
//!
//! ## Bit-exactness argument
//!
//! The scalar tile loop accumulates each output element as a strictly
//! ascending-`k` chain of `acc[c] += a * brow[c]` f32 operations. The
//! vector kernels here keep exactly that chain and only change *how
//! many columns advance per instruction*: the [`NR`]-wide full panel is
//! two 8-lane `__m256` registers, `a` is broadcast, and every `k` step
//! performs one IEEE multiply then one IEEE add per lane —
//! `_mm256_add_ps(acc, _mm256_mul_ps(a, b))`, never `_mm256_fmadd_ps`,
//! because a fused multiply-add rounds once where the reference rounds
//! twice and would break bitwise equality. Per-lane AVX mul/add are the
//! same correctly-rounded IEEE 754 operations as their scalar
//! counterparts, the reference zero-skip is evaluated scalar-side
//! before the broadcast, and the ragged last panel (width < `NR`) runs
//! the scalar tile loop verbatim — so SIMD ≡ blocked-scalar ≡ naive
//! stays bitwise for every shape (pinned by the unit tests below and by
//! `rust/tests/parallel_equivalence.rs`).
//!
//! ## Dispatch
//!
//! Every entry point checks [`available`] at runtime and falls back to
//! the scalar kernel when AVX2 is absent (or off-x86); the fallback is
//! the *same function* the `KernelMode::Blocked` oracle runs, so
//! results never depend on the host ISA. The FP8 QDQ lane kernels live
//! in [`super::qdq`] (they need the private encode tables) behind the
//! same [`available`] gate.

use super::gemm::{self, PackedB, MR, NR};

// The vector kernels hardcode NR = two 8-lane registers.
const _: () = assert!(NR == 16);

/// Whether the AVX2 vector kernels can run on this host. Detection is
/// cached by the standard library; callers may query per call.
#[inline]
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// AVX2 [`gemm::nn_panel`]: C-panel rows `[r0, r1)` of `C = A @ B` with
/// the reference zero-skip. Scalar fallback where AVX2 is absent.
pub fn nn_panel(ad: &[f32], k: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        unsafe {
            avx2::tile_loop(bp, r0, r1, cd, |kk, i| {
                let a = ad[i * k + kk];
                if a == 0.0 {
                    None
                } else {
                    Some(a)
                }
            });
        }
        return;
    }
    gemm::nn_panel(ad, k, bp, cd, r0, r1);
}

/// AVX2 [`gemm::tn_panel`]: C-panel rows of `C = A^T @ B` with the
/// reference zero-skip. Scalar fallback where AVX2 is absent.
pub fn tn_panel(ad: &[f32], m: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        unsafe {
            avx2::tile_loop(bp, r0, r1, cd, |kk, i| {
                let a = ad[kk * m + i];
                if a == 0.0 {
                    None
                } else {
                    Some(a)
                }
            });
        }
        return;
    }
    gemm::tn_panel(ad, m, bp, cd, r0, r1);
}

/// AVX2 [`gemm::nt_panel`]: C-panel rows of `C = A @ B^T` over a
/// [`gemm::pack_bt`] pack — **no** zero-skip, exactly like the
/// reference `nt` loop. Scalar fallback where AVX2 is absent.
pub fn nt_panel(ad: &[f32], k: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        unsafe {
            avx2::tile_loop(bp, r0, r1, cd, |kk, i| Some(ad[i * k + kk]));
        }
        return;
    }
    gemm::nt_panel(ad, k, bp, cd, r0, r1);
}

/// AVX2 [`gemm::nn_block_inplace`]: in-place register-tiled `C += A @ B`
/// for one `(i, k, j)` block, reference zero-skip included. Scalar
/// fallback where AVX2 is absent.
#[allow(clippy::too_many_arguments)]
pub fn nn_block_inplace(
    ad: &[f32],
    k: usize,
    bd: &[f32],
    n: usize,
    od: &mut [f32],
    row0: usize,
    (i0, i1): (usize, usize),
    (k0, k1): (usize, usize),
    (j0, j1): (usize, usize),
) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        unsafe {
            avx2::nn_block_inplace(ad, k, bd, n, od, row0, (i0, i1), (k0, k1), (j0, j1));
        }
        return;
    }
    gemm::nn_block_inplace(ad, k, bd, n, od, row0, (i0, i1), (k0, k1), (j0, j1));
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{gemm, PackedB, MR, NR};
    use std::arch::x86_64::*;

    /// Vectorized twin of the scalar `tile_loop`: full-width `NR`
    /// panels accumulate in two `__m256` registers per output row with
    /// a separate multiply and add per `k` step (two roundings, same as
    /// scalar — FMA deliberately not used); the ragged last panel runs
    /// the scalar loop body verbatim. `a_at` is evaluated scalar-side
    /// so the zero-skip decision is shared with the reference.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile_loop<F>(bp: &PackedB, r0: usize, r1: usize, cd: &mut [f32], a_at: F)
    where
        F: Fn(usize, usize) -> Option<f32>,
    {
        let (k, n) = (bp.k, bp.n);
        for p in 0..bp.panels() {
            let j0 = p * NR;
            let pb = bp.panel(p);
            let jw = NR.min(n - j0);
            let mut i = r0;
            while i < r1 {
                let mr = MR.min(r1 - i);
                if jw == NR {
                    let mut lo = [_mm256_setzero_ps(); MR];
                    let mut hi = [_mm256_setzero_ps(); MR];
                    for kk in 0..k {
                        let brow = pb.as_ptr().add(kk * NR);
                        let b0 = _mm256_loadu_ps(brow);
                        let b1 = _mm256_loadu_ps(brow.add(8));
                        let rows = lo.iter_mut().zip(hi.iter_mut()).enumerate().take(mr);
                        for (r, (alo, ahi)) in rows {
                            let Some(a) = a_at(kk, i + r) else { continue };
                            let av = _mm256_set1_ps(a);
                            *alo = _mm256_add_ps(*alo, _mm256_mul_ps(av, b0));
                            *ahi = _mm256_add_ps(*ahi, _mm256_mul_ps(av, b1));
                        }
                    }
                    for r in 0..mr {
                        let at = (i + r - r0) * n + j0;
                        _mm256_storeu_ps(cd.as_mut_ptr().add(at), lo[r]);
                        _mm256_storeu_ps(cd.as_mut_ptr().add(at + 8), hi[r]);
                    }
                } else {
                    // Ragged last panel: the scalar reference tile body.
                    let mut acc = [[0f32; NR]; MR];
                    for kk in 0..k {
                        let brow = &pb[kk * jw..kk * jw + jw];
                        for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                            let Some(a) = a_at(kk, i + r) else { continue };
                            for c in 0..jw {
                                accr[c] += a * brow[c];
                            }
                        }
                    }
                    for (r, accr) in acc.iter().enumerate().take(mr) {
                        let at = (i + r - r0) * n + j0;
                        cd[at..at + jw].copy_from_slice(&accr[..jw]);
                    }
                }
                i += mr;
            }
        }
    }

    /// Vectorized twin of [`gemm::nn_block_inplace`]: C loads into the
    /// tile registers before the `kk` loop and stores after it, so
    /// accumulation order across successive k-blocks stays the naive
    /// `bk`-then-`kk` sequence. Ragged `j` blocks delegate to the
    /// scalar kernel.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn nn_block_inplace(
        ad: &[f32],
        k: usize,
        bd: &[f32],
        n: usize,
        od: &mut [f32],
        row0: usize,
        (i0, i1): (usize, usize),
        (k0, k1): (usize, usize),
        (j0, j1): (usize, usize),
    ) {
        let mut jt = j0;
        while jt < j1 {
            let jw = NR.min(j1 - jt);
            if jw < NR {
                gemm::nn_block_inplace(ad, k, bd, n, od, row0, (i0, i1), (k0, k1), (jt, jt + jw));
                jt += jw;
                continue;
            }
            let mut i = i0;
            while i < i1 {
                let mr = MR.min(i1 - i);
                let mut lo = [_mm256_setzero_ps(); MR];
                let mut hi = [_mm256_setzero_ps(); MR];
                for r in 0..mr {
                    let at = (i + r - row0) * n + jt;
                    lo[r] = _mm256_loadu_ps(od.as_ptr().add(at));
                    hi[r] = _mm256_loadu_ps(od.as_ptr().add(at + 8));
                }
                for kk in k0..k1 {
                    let brow = bd.as_ptr().add(kk * n + jt);
                    let b0 = _mm256_loadu_ps(brow);
                    let b1 = _mm256_loadu_ps(brow.add(8));
                    for (r, (alo, ahi)) in lo.iter_mut().zip(hi.iter_mut()).enumerate().take(mr) {
                        let a = ad[(i + r) * k + kk];
                        if a == 0.0 {
                            continue;
                        }
                        let av = _mm256_set1_ps(a);
                        *alo = _mm256_add_ps(*alo, _mm256_mul_ps(av, b0));
                        *ahi = _mm256_add_ps(*ahi, _mm256_mul_ps(av, b1));
                    }
                }
                for r in 0..mr {
                    let at = (i + r - row0) * n + jt;
                    _mm256_storeu_ps(od.as_mut_ptr().add(at), lo[r]);
                    _mm256_storeu_ps(od.as_mut_ptr().add(at + 8), hi[r]);
                }
                i += mr;
            }
            jt += jw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn mat(rows: usize, cols: usize, seed: u64, with_zeros: bool) -> Tensor {
        let mut t = Tensor::normal(&[rows, cols], 1.0, seed);
        if with_zeros {
            for (i, v) in t.data_mut().iter_mut().enumerate() {
                if i % 5 == 0 {
                    *v = 0.0;
                }
            }
        }
        t
    }

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what} len");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} element {i}: {x} vs {y}");
        }
    }

    /// SIMD ≡ scalar for every panel variant over the adversarial shape
    /// set. On hosts without AVX2 the SIMD entry points *are* the
    /// scalar kernels, so this documents the fallback rather than
    /// proving vector parity — CI's x86 runners prove both.
    #[test]
    fn simd_panels_match_scalar_bitwise_adversarial_shapes() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 7, 1),
            (5, 1, 9),
            (MR, 3, NR),
            (MR + 1, 5, NR + 1),
            (MR - 1, 4, NR - 1),
            (13, 17, 33),
            (16, 16, 16),
            (3, 64, 2),
            (9, 8, 2 * NR),
        ];
        for (m, k, n) in shapes {
            let a = mat(m, k, (m * 31 + n) as u64, true);
            let b = mat(k, n, (k * 17 + n) as u64 + 1, true);

            let bp = gemm::pack_b(&b);
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            gemm::nn_panel(a.data(), k, &bp, &mut want, 0, m);
            nn_panel(a.data(), k, &bp, &mut got, 0, m);
            assert_bits(&got, &want, &format!("nn {m}x{k}x{n}"));

            let at = a.transpose();
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            gemm::tn_panel(at.data(), m, &bp, &mut want, 0, m);
            tn_panel(at.data(), m, &bp, &mut got, 0, m);
            assert_bits(&got, &want, &format!("tn {m}x{k}x{n}"));

            let bt = b.transpose();
            let btp = gemm::pack_bt(&bt);
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            gemm::nt_panel(a.data(), k, &btp, &mut want, 0, m);
            nt_panel(a.data(), k, &btp, &mut got, 0, m);
            assert_bits(&got, &want, &format!("nt {m}x{k}x{n}"));

            // Split row panels (the par_panels decomposition).
            if m > 2 {
                let split = m / 2;
                let mut got = vec![0f32; m * n];
                let mut want = vec![0f32; m * n];
                gemm::nn_panel(a.data(), k, &bp, &mut want, 0, m);
                let (lo, hi) = got.split_at_mut(split * n);
                nn_panel(a.data(), k, &bp, lo, 0, split);
                nn_panel(a.data(), k, &bp, hi, split, m);
                assert_bits(&got, &want, &format!("nn split {m}x{k}x{n}"));
            }
        }
    }

    /// The `nt` variant must keep `0 * Inf = NaN` (no zero-skip) and
    /// `nn` must skip it, exactly like the scalar kernels.
    #[test]
    fn simd_zero_skip_matches_scalar_semantics() {
        let a = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]);
        let bt = Tensor::from_vec(&[1, 2], vec![f32::INFINITY, 2.0]);
        let btp = gemm::pack_bt(&bt);
        let mut c = vec![0f32; 1];
        nt_panel(a.data(), 2, &btp, &mut c, 0, 1);
        assert!(c[0].is_nan(), "nt must not skip 0 * Inf");

        let b = Tensor::from_vec(&[2, 1], vec![f32::INFINITY, 2.0]);
        let bp = gemm::pack_b(&b);
        let mut c = vec![0f32; 1];
        nn_panel(a.data(), 2, &bp, &mut c, 0, 1);
        assert_eq!(c[0], 2.0, "nn must skip the zero row");
    }

    /// In-place k-block accumulation: SIMD ≡ scalar across a two-block
    /// schedule, including a ragged j tail.
    #[test]
    fn simd_block_inplace_matches_scalar_bitwise() {
        for (m, k, n) in [(10usize, 9usize, 11usize), (7, 5, 2 * NR + 3), (MR, 4, NR)] {
            let a = mat(m, k, 5, true);
            let b = mat(k, n, 6, false);
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            let ksplit = k / 2;
            for (k0, k1) in [(0usize, ksplit), (ksplit, k)] {
                gemm::nn_block_inplace(
                    a.data(),
                    k,
                    b.data(),
                    n,
                    &mut want,
                    0,
                    (0, m),
                    (k0, k1),
                    (0, n),
                );
                nn_block_inplace(a.data(), k, b.data(), n, &mut got, 0, (0, m), (k0, k1), (0, n));
            }
            assert_bits(&got, &want, &format!("block inplace {m}x{k}x{n}"));
        }
    }
}
