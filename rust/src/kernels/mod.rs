//! The bit-exact kernel layer: table-driven FP8/BF16 quantization and
//! packed, cache-blocked host GEMM microkernels.
//!
//! Everything in this module is a **drop-in replacement for a scalar
//! reference loop elsewhere in the crate, bit-identical by
//! construction**:
//!
//! * [`qdq`] — 256-entry decode LUTs per FP8 format (filled from
//!   [`crate::formats::fp8::Fp8Format::decode`], so equality is
//!   structural) plus a table-driven saturating RNE encode whose
//!   per-exponent drop counts reproduce the reference
//!   `encode_with(x, Rounding::Saturate)` arithmetic exactly —
//!   exhaustively parity-tested over all 256 byte patterns, the full
//!   rounding-boundary set (every grid point and adjacent-pair
//!   midpoint ± 2 f32 ulps) and random bit patterns. LUT-based QDQ is
//!   exactly value-preserving: it changes *how* the value is computed,
//!   never *which* value.
//! * [`gemm`] — operand packing into contiguous column panels and
//!   MR×NR register-tiled microkernels for the four matmul variants.
//!   Work is tiled over the output's `j` dimension and over row
//!   groups; the contraction index `k` stays **strictly sequential per
//!   output element**, with the reference loops' exact zero-skip
//!   behaviour, so every `c[i][j]` accumulates the identical f32
//!   sequence as the naive triple loop and the results are bitwise
//!   equal (pinned by `rust/tests/parallel_equivalence.rs`).
//!
//! * [`simd`] — runtime-dispatched AVX2 twins of the GEMM microkernels
//!   (and, in [`qdq`], of the FP8 segment QDQ): the same per-element
//!   IEEE operation chains executed `NR` lanes at a time, with a
//!   guaranteed fall-through to the scalar kernels where the ISA is
//!   absent. FMA is deliberately never used — one rounding where the
//!   reference takes two would break the bitwise contract.
//!
//! Selection rides the per-run [`crate::util::par::Parallelism`] handle
//! ([`crate::util::par::KernelMode`]): `Simd` (default) runs this layer
//! with the vector kernels, `Blocked` pins it to the scalar blocked
//! paths (`MOR_NO_SIMD=1` flips auto-configured handles), and `Scalar`
//! keeps the original reference loops reachable as the parity oracle
//! and the bench baseline (`MOR_SCALAR_KERNELS=1`). Because all three
//! modes are bit-identical, the parallel ≡ serial and resume ≡
//! continuous contracts are unaffected by which one runs.

pub mod gemm;
pub mod qdq;
pub mod simd;
