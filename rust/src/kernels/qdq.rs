//! Table-driven FP8/BF16 quantize–dequantize: the slice-level fast path
//! behind `quant::fake_quant`'s Fig. 4 pipeline.
//!
//! Two tables per FP8 format, built once per process:
//!
//! * **decode LUT** — all 256 byte patterns decoded via the reference
//!   [`Fp8Format::decode`], so `decode[b]` is *definitionally* the
//!   reference value (the scalar path's `powi`-based decode never runs
//!   on the hot path again);
//! * **drop table** — for each of the 256 f32 biased-exponent values,
//!   how many significand bits the RNE rounding must drop to land on
//!   the fp8 grid at that exponent (`0xFF` = the value rounds to ±0
//!   regardless of mantissa, `0xFE` = Inf/NaN exponent). This is the
//!   same `drop` the reference `encode_with` computes arithmetically;
//!   the table just hoists the range classification out of the
//!   element loop, removing every float-domain branch
//!   (`is_nan`/`is_infinite`/subnormal tests) from [`QdqTables::
//!   encode_sat`].
//!
//! Only [`Rounding::Saturate`] is implemented here — the mode the
//! fake-quant pipeline uses after amax scaling; `NanOnOverflow`
//! (golden-table cross-validation against `ml_dtypes`) stays on the
//! reference implementation. Parity with
//! `Fp8Format::encode_with(x, Saturate)` is pinned by the exhaustive
//! tests below.

use crate::formats::fp8::{Fp8Format, Rounding, E4M3, E5M2};
use crate::formats::{bf16, fp4, ReprType};
use std::sync::OnceLock;

/// Drop-table sentinel: the value rounds to ±0 for every mantissa
/// (f32 zero/subnormal input, or more than 32 bits to drop).
const DROP_ZERO: u8 = 0xFF;
/// Drop-table sentinel: f32 exponent 255 (Inf or NaN input).
const DROP_SPECIAL: u8 = 0xFE;

/// Precomputed decode/encode tables for one FP8 format.
pub struct QdqTables {
    /// Reference decode of every byte pattern.
    pub decode: [f32; 256],
    /// Significand bits to drop, indexed by the f32 biased exponent.
    drop: [u8; 256],
    /// The drop table widened to i32 for `_mm256_i32gather_epi32`.
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    drop32: [i32; 256],
    man_bits: u32,
    man_mask: u8,
    has_inf: bool,
    bias: i32,
    /// f32 biased exponent of the smallest normal fp8 magnitude.
    min_norm_e: u32,
    /// Largest fp8 exponent field that holds finite values.
    max_exp_field: i32,
    /// Byte encoding of +MAX (saturation target), sign bit clear.
    max_byte: u8,
    /// Canonical NaN byte, sign bit clear.
    nan_byte: u8,
}

impl QdqTables {
    fn build<F: Fp8Format>() -> QdqTables {
        let mut decode = [0f32; 256];
        for (b, slot) in decode.iter_mut().enumerate() {
            *slot = F::decode(b as u8);
        }
        let min_norm_exp = 1 - F::BIAS;
        let mut drop = [0u8; 256];
        for (e, slot) in drop.iter_mut().enumerate() {
            *slot = match e {
                0 => DROP_ZERO,
                255 => DROP_SPECIAL,
                _ => {
                    let f32_exp = e as i32 - 127;
                    let d = if f32_exp >= min_norm_exp {
                        23 - F::MAN_BITS as i32
                    } else {
                        23 - F::MAN_BITS as i32 + (min_norm_exp - f32_exp)
                    };
                    if d >= 33 {
                        DROP_ZERO
                    } else {
                        d as u8
                    }
                }
            };
        }
        let exp_mask = ((1u32 << F::EXP_BITS) - 1) as u8;
        let man_mask = ((1u32 << F::MAN_BITS) - 1) as u8;
        let mut drop32 = [0i32; 256];
        for (w, d) in drop32.iter_mut().zip(drop.iter()) {
            *w = *d as i32;
        }
        QdqTables {
            decode,
            drop,
            drop32,
            man_bits: F::MAN_BITS,
            man_mask,
            has_inf: F::HAS_INF,
            bias: F::BIAS,
            min_norm_e: (min_norm_exp + 127) as u32,
            max_exp_field: if F::HAS_INF {
                exp_mask as i32 - 1
            } else {
                exp_mask as i32
            },
            max_byte: F::encode_max_with_sign(0, Rounding::Saturate),
            nan_byte: if F::HAS_INF {
                (exp_mask << F::MAN_BITS) | (1 << (F::MAN_BITS - 1))
            } else {
                (exp_mask << F::MAN_BITS) | man_mask
            },
        }
    }

    /// The process-wide E4M3 tables.
    pub fn e4m3() -> &'static QdqTables {
        static T: OnceLock<QdqTables> = OnceLock::new();
        T.get_or_init(QdqTables::build::<E4M3>)
    }

    /// The process-wide E5M2 tables.
    pub fn e5m2() -> &'static QdqTables {
        static T: OnceLock<QdqTables> = OnceLock::new();
        T.get_or_init(QdqTables::build::<E5M2>)
    }

    /// Encode with RNE and saturation-on-overflow — bit-identical to
    /// `Fp8Format::encode_with(x, Rounding::Saturate)` for every f32
    /// input (exhaustive parity tests below). The float-range
    /// classification is one table lookup on the exponent field; the
    /// rounding itself is the reference's staged integer RNE.
    #[inline]
    pub fn encode_sat(&self, x: f32) -> u8 {
        let bits = x.to_bits();
        let sign = ((bits >> 31) as u8) << 7;
        let abs = bits & 0x7fff_ffff;
        let drop = self.drop[(abs >> 23) as usize];
        if drop == DROP_ZERO {
            return sign; // ±0, f32 subnormal, or deep underflow
        }
        if drop == DROP_SPECIAL {
            // Saturate mode: Inf clamps to ±MAX; NaN stays NaN.
            return if abs == 0x7f80_0000 {
                sign | self.max_byte
            } else {
                sign | self.nan_byte
            };
        }

        // Staged RNE on the 24-bit significand (reference arithmetic).
        let significand24 = (abs & 0x007f_ffff) | 0x0080_0000;
        let staged = (significand24 as u64) << 10;
        let total_drop = drop as u32 + 10;
        let keep = staged >> total_drop;
        let round_bit = (staged >> (total_drop - 1)) & 1;
        let sticky = (staged & ((1u64 << (total_drop - 1)) - 1)) != 0;
        let rounded = keep + ((round_bit != 0 && (sticky || (keep & 1) == 1)) as u64);

        let (e_fp8, m_fp8);
        if (abs >> 23) >= self.min_norm_e {
            let mut exp = (abs >> 23) as i32 - 127;
            let mut sig = rounded;
            if sig >= (1u64 << (self.man_bits + 1)) {
                sig >>= 1;
                exp += 1;
            }
            e_fp8 = exp + self.bias;
            m_fp8 = (sig as u8) & self.man_mask;
        } else if rounded >= (1u64 << self.man_bits) {
            e_fp8 = 1;
            m_fp8 = (rounded as u8) & self.man_mask;
        } else {
            e_fp8 = 0;
            m_fp8 = rounded as u8;
        }

        let overflowed = e_fp8 > self.max_exp_field
            || (!self.has_inf && e_fp8 == self.max_exp_field && m_fp8 == self.man_mask);
        if overflowed {
            return sign | self.max_byte;
        }
        sign | ((e_fp8 as u8) << self.man_bits) | m_fp8
    }

    /// One LUT quantize–dequantize round trip (Saturate mode).
    #[inline]
    pub fn qdq_sat(&self, x: f32) -> f32 {
        self.decode[self.encode_sat(x) as usize]
    }
}

#[cfg(target_arch = "x86_64")]
impl QdqTables {
    /// Eight-lane AVX2 `qdq_sat(x * scale) / scale`, bit-identical to
    /// the scalar loop in [`qdq_segment_scaled`].
    ///
    /// Per lane this is [`QdqTables::encode_sat`] with the staged-u64
    /// RNE collapsed to 32-bit lane arithmetic: every non-sentinel drop
    /// count lies in `[20, 32]`, so `keep = sig24 >> drop` and the
    /// round/sticky bits fit native 32-bit variable shifts
    /// (`_mm256_srlv_epi32` yields 0 for counts ≥ 32, exactly the
    /// staged behaviour at `drop == 32`), and the sticky test
    /// `staged & ((1 << (total_drop-1)) - 1) != 0` equals
    /// `sig24 & ((1 << (drop-1)) - 1) != 0` because the staged value's
    /// low 10 bits are zero by construction. Sentinel lanes (zero /
    /// Inf / NaN classes) compute garbage through the arithmetic and
    /// are blended to their classified bytes before the decode gather.
    /// The surrounding multiply and divide are per-lane IEEE ops
    /// identical to their scalar counterparts.
    #[target_feature(enable = "avx2")]
    unsafe fn qdq_segment_avx2(&self, xs: &[f32], out: &mut [f32], scale: f32) {
        use std::arch::x86_64::*;
        debug_assert_eq!(xs.len(), out.len());
        let sv = _mm256_set1_ps(scale);
        let ones = _mm256_set1_epi32(1);
        let zero = _mm256_setzero_si256();
        let man_bits_v = _mm256_set1_epi32(self.man_bits as i32);
        let man_mask_v = _mm256_set1_epi32(self.man_mask as i32);
        let max_byte_v = _mm256_set1_epi32(self.max_byte as i32);
        let nan_byte_v = _mm256_set1_epi32(self.nan_byte as i32);
        let max_exp_v = _mm256_set1_epi32(self.max_exp_field);
        let min_norm_v = _mm256_set1_epi32(self.min_norm_e as i32);
        let carry_lim = _mm256_set1_epi32((1i32 << (self.man_bits + 1)) - 1);
        let promote_lim = _mm256_set1_epi32((1i32 << self.man_bits) - 1);
        let bias_off = _mm256_set1_epi32(self.bias - 127);
        let n = xs.len();
        let mut i = 0;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(xs.as_ptr().add(i));
            let scaled = _mm256_mul_ps(xv, sv);
            let bits = _mm256_castps_si256(scaled);
            let sign8 = _mm256_and_si256(_mm256_srli_epi32::<24>(bits), _mm256_set1_epi32(0x80));
            let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
            let e = _mm256_srli_epi32::<23>(abs);
            let drop = _mm256_i32gather_epi32::<4>(self.drop32.as_ptr(), e);
            let zero_m = _mm256_cmpeq_epi32(drop, _mm256_set1_epi32(DROP_ZERO as i32));
            let spec_m = _mm256_cmpeq_epi32(drop, _mm256_set1_epi32(DROP_SPECIAL as i32));

            // Staged RNE on the 24-bit significand, 32-bit lanes.
            let sig24 = _mm256_or_si256(
                _mm256_and_si256(abs, _mm256_set1_epi32(0x007f_ffff)),
                _mm256_set1_epi32(0x0080_0000),
            );
            let keep = _mm256_srlv_epi32(sig24, drop);
            let dm1 = _mm256_sub_epi32(drop, ones);
            let rbit = _mm256_and_si256(_mm256_srlv_epi32(sig24, dm1), ones);
            let lowmask = _mm256_sub_epi32(_mm256_sllv_epi32(ones, dm1), ones);
            let sticky0 = _mm256_cmpeq_epi32(_mm256_and_si256(sig24, lowmask), zero);
            let sticky = _mm256_andnot_si256(sticky0, ones);
            let odd = _mm256_and_si256(keep, ones);
            let inc = _mm256_and_si256(rbit, _mm256_or_si256(sticky, odd));
            let rounded = _mm256_add_epi32(keep, inc);

            // Normal result: renormalize a rounding carry-out.
            let carry = _mm256_cmpgt_epi32(rounded, carry_lim);
            let sig_n = _mm256_blendv_epi8(rounded, _mm256_srli_epi32::<1>(rounded), carry);
            let e_n =
                _mm256_add_epi32(_mm256_add_epi32(e, bias_off), _mm256_and_si256(carry, ones));
            let m_n = _mm256_and_si256(sig_n, man_mask_v);
            // Subnormal result: may promote into the first normal binade.
            let promoted = _mm256_cmpgt_epi32(rounded, promote_lim);
            let e_s = _mm256_and_si256(promoted, ones);
            let m_s = _mm256_and_si256(rounded, man_mask_v);
            let is_sub = _mm256_cmpgt_epi32(min_norm_v, e);
            let e8 = _mm256_blendv_epi8(e_n, e_s, is_sub);
            let m8 = _mm256_blendv_epi8(m_n, m_s, is_sub);

            // Saturating overflow (the E4M3 NaN slot also saturates).
            let over_hi = _mm256_cmpgt_epi32(e8, max_exp_v);
            let over = if self.has_inf {
                over_hi
            } else {
                let at_max = _mm256_cmpeq_epi32(e8, max_exp_v);
                let m_all = _mm256_cmpeq_epi32(m8, man_mask_v);
                _mm256_or_si256(over_hi, _mm256_and_si256(at_max, m_all))
            };
            let fin = _mm256_or_si256(_mm256_sllv_epi32(e8, man_bits_v), m8);
            let fin = _mm256_blendv_epi8(fin, max_byte_v, over);

            // Exponent-255 lanes: Inf clamps to ±MAX, NaN stays NaN.
            let is_inf = _mm256_cmpeq_epi32(abs, _mm256_set1_epi32(0x7f80_0000));
            let spec = _mm256_blendv_epi8(nan_byte_v, max_byte_v, is_inf);
            let byte = _mm256_blendv_epi8(fin, spec, spec_m);
            let byte = _mm256_blendv_epi8(byte, zero, zero_m);
            let byte = _mm256_or_si256(byte, sign8);

            let dec = _mm256_i32gather_ps::<4>(self.decode.as_ptr(), byte);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_div_ps(dec, sv));
            i += 8;
        }
        for (x, o) in xs[i..].iter().zip(out[i..].iter_mut()) {
            *o = self.qdq_sat(*x * scale) / scale;
        }
    }
}

/// Slice-level scaled QDQ: `out[i] = qdq(x[i] * scale) / scale`, the
/// per-block body of fake-quant phase B. The arithmetic per element is
/// exactly the scalar path's `qdq(target, v * s) / s` — multiply,
/// round-trip, divide, in that order — so outputs are bit-identical for
/// every target type; only the fp8 round-trip itself goes through the
/// tables instead of the branchy codec.
pub fn qdq_segment_scaled(target: ReprType, xs: &[f32], out: &mut [f32], scale: f32) {
    debug_assert_eq!(xs.len(), out.len());
    match target {
        ReprType::E4M3 => {
            let t = QdqTables::e4m3();
            for (x, o) in xs.iter().zip(out.iter_mut()) {
                *o = t.qdq_sat(*x * scale) / scale;
            }
        }
        ReprType::E5M2 => {
            let t = QdqTables::e5m2();
            for (x, o) in xs.iter().zip(out.iter_mut()) {
                *o = t.qdq_sat(*x * scale) / scale;
            }
        }
        ReprType::Bf16 => {
            for (x, o) in xs.iter().zip(out.iter_mut()) {
                *o = bf16::quantize_dequantize(*x * scale) / scale;
            }
        }
        ReprType::NvFp4 => {
            for (x, o) in xs.iter().zip(out.iter_mut()) {
                *o = fp4::e2m1_quantize_dequantize(*x * scale) / scale;
            }
        }
    }
}

/// SIMD twin of [`qdq_segment_scaled`]: AVX2 lanes for the fp8 targets
/// where the host supports them ([`super::simd::available`]),
/// bit-identical scalar segment fallback otherwise. BF16/NVFP4 targets
/// always run the scalar segment loops — their round trips are already
/// branch-free bit manipulation.
pub fn qdq_segment_scaled_simd(target: ReprType, xs: &[f32], out: &mut [f32], scale: f32) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::available() {
        match target {
            ReprType::E4M3 => {
                unsafe { QdqTables::e4m3().qdq_segment_avx2(xs, out, scale) };
                return;
            }
            ReprType::E5M2 => {
                unsafe { QdqTables::e5m2().qdq_segment_avx2(xs, out, scale) };
                return;
            }
            ReprType::Bf16 | ReprType::NvFp4 => {}
        }
    }
    qdq_segment_scaled(target, xs, out, scale)
}

/// Slice-level unscaled BF16 round trip (the BF16-target fast path of
/// fake-quant, which needs no scaling). Pure bit manipulation per
/// element; bit-identical to `bf16::quantize_dequantize` by definition.
pub fn bf16_segment(xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (x, o) in xs.iter().zip(out.iter_mut()) {
        *o = bf16::quantize_dequantize(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full rounding-boundary set for one format: every finite grid
    /// point, every adjacent-pair midpoint, each ± 2 f32 ulps, the
    /// overflow/underflow boundaries, f32 specials, and per-exponent
    /// mantissa extremes — both signs throughout.
    fn boundary_bits(decode: &[f32; 256]) -> Vec<u32> {
        let mut grid: Vec<f32> = decode
            .iter()
            .copied()
            .filter(|v| v.is_finite() && *v >= 0.0)
            .collect();
        grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
        grid.dedup();
        let mut set = std::collections::BTreeSet::new();
        let push_near = |v: f32, set: &mut std::collections::BTreeSet<u32>| {
            let b = v.to_bits() & 0x7fff_ffff;
            for d in -2i64..=2 {
                set.insert((b as i64 + d).clamp(0, 0x7fff_ffff) as u32);
            }
        };
        for (i, g) in grid.iter().enumerate() {
            push_near(*g, &mut set);
            if i + 1 < grid.len() {
                push_near((g + grid[i + 1]) / 2.0, &mut set);
            }
        }
        let max = *grid.last().unwrap();
        push_near(max * 1.0625, &mut set); // past the overflow midpoint
        push_near(grid[1] / 2.0, &mut set); // half the min subnormal
        for b in [
            0u32,
            1,
            0x007f_ffff,
            0x0080_0000,
            0x0080_0001,
            0x7f7f_ffff,
            0x7f80_0000,
            0x7f80_0001,
            0x7fc0_0000,
            0x7fff_ffff,
        ] {
            set.insert(b);
        }
        for e in 0u32..=255 {
            for m in [0u32, 1, 0x7f_fffe, 0x7f_ffff, 0x40_0000, 0x3f_ffff] {
                set.insert((e << 23) | m);
            }
        }
        let mut out: Vec<u32> = set.iter().copied().collect();
        out.extend(set.iter().map(|b| *b | 0x8000_0000));
        out
    }

    fn assert_byte_parity<F: Fp8Format>(t: &QdqTables, bits: u32) {
        let x = f32::from_bits(bits);
        let want = F::encode_with(x, Rounding::Saturate);
        let got = t.encode_sat(x);
        assert_eq!(
            got, want,
            "{}: encode mismatch at bits {bits:#010x} (x = {x:e}): LUT {got:#04x} vs \
             reference {want:#04x}",
            F::NAME
        );
    }

    #[test]
    fn decode_lut_matches_reference_all_256() {
        let e4 = QdqTables::e4m3();
        let e5 = QdqTables::e5m2();
        for b in 0u16..=255 {
            let b = b as u8;
            let (l4, r4) = (e4.decode[b as usize], E4M3::decode(b));
            let (l5, r5) = (e5.decode[b as usize], E5M2::decode(b));
            assert_eq!(l4.to_bits(), r4.to_bits(), "e4m3 byte {b:#04x}");
            assert_eq!(l5.to_bits(), r5.to_bits(), "e5m2 byte {b:#04x}");
        }
    }

    #[test]
    fn encode_parity_over_rounding_boundary_set() {
        let e4 = QdqTables::e4m3();
        for bits in boundary_bits(&e4.decode) {
            assert_byte_parity::<E4M3>(e4, bits);
        }
        let e5 = QdqTables::e5m2();
        for bits in boundary_bits(&e5.decode) {
            assert_byte_parity::<E5M2>(e5, bits);
        }
    }

    #[test]
    fn encode_parity_over_random_bit_patterns() {
        // xorshift64* stream over raw bit patterns: NaN payloads,
        // subnormals, huge magnitudes — everything.
        let mut s = 0x1234_5678_9abc_def0u64;
        let e4 = QdqTables::e4m3();
        let e5 = QdqTables::e5m2();
        for _ in 0..200_000 {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let bits = (s >> 32) as u32;
            assert_byte_parity::<E4M3>(e4, bits);
            assert_byte_parity::<E5M2>(e5, bits);
        }
    }

    #[test]
    fn qdq_sat_equals_reference_roundtrip() {
        let e4 = QdqTables::e4m3();
        let mut x = -500.0f32;
        while x < 500.0 {
            let want = E4M3::quantize_dequantize(x, Rounding::Saturate);
            let got = e4.qdq_sat(x);
            assert_eq!(got.to_bits(), want.to_bits(), "x = {x}");
            x += 0.0713;
        }
        assert!(e4.qdq_sat(f32::NAN).is_nan());
        assert_eq!(e4.qdq_sat(f32::INFINITY), 448.0);
        assert_eq!(e4.qdq_sat(f32::NEG_INFINITY), -448.0);
    }

    #[test]
    fn segments_match_scalar_loop_bitwise() {
        let xs: Vec<f32> = (0..1000)
            .map(|i| ((i as f32) * 0.7311).sin() * (1.5f32).powi((i % 40) as i32 - 20))
            .collect();
        for target in [ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4] {
            for scale in [1.0f32, 0.125, 3.7, 1e-3, 217.0] {
                let mut out = vec![0f32; xs.len()];
                qdq_segment_scaled(target, &xs, &mut out, scale);
                for (x, o) in xs.iter().zip(out.iter()) {
                    // The dynamic-dispatch helper uses Saturate for fp8
                    // and the scalar codecs for bf16/fp4 — exactly the
                    // fake-quant scalar path.
                    let want = crate::formats::fp8::quantize_dequantize(
                        target,
                        x * scale,
                        Rounding::Saturate,
                    ) / scale;
                    assert_eq!(o.to_bits(), want.to_bits(), "{target} x={x} s={scale}");
                }
            }
        }
        let mut out = vec![0f32; xs.len()];
        bf16_segment(&xs, &mut out);
        for (x, o) in xs.iter().zip(out.iter()) {
            assert_eq!(o.to_bits(), bf16::quantize_dequantize(*x).to_bits());
        }
    }

    fn assert_simd_segment_parity(target: ReprType, bits: &[u32]) {
        let xs: Vec<f32> = bits.iter().map(|b| f32::from_bits(*b)).collect();
        for scale in [1.0f32, 0.37, 64.0, 1e-3] {
            let mut want = vec![0f32; xs.len()];
            let mut got = vec![0f32; xs.len()];
            qdq_segment_scaled(target, &xs, &mut want, scale);
            qdq_segment_scaled_simd(target, &xs, &mut got, scale);
            for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{target} scale={scale} x={:e} (bits {:#010x}): simd {g:e} vs scalar {w:e}",
                    xs[i],
                    bits[i]
                );
            }
        }
    }

    /// SIMD ≡ scalar over every f32 exponent × a mantissa pattern set ×
    /// both signs, plus the full rounding-boundary set — the slice
    /// lengths leave a non-multiple-of-8 tail so the scalar remainder
    /// path is exercised too. On hosts without AVX2 the SIMD entry
    /// point *is* the scalar kernel; x86 CI proves vector parity.
    #[test]
    fn simd_segment_matches_scalar_exhaustive_exponents_and_boundaries() {
        for (target, tables) in
            [(ReprType::E4M3, QdqTables::e4m3()), (ReprType::E5M2, QdqTables::e5m2())]
        {
            let mut bits = boundary_bits(&tables.decode);
            for e in 0u32..=255 {
                for m in [0u32, 1, 0x2a_aaaa, 0x55_5555, 0x3f_ffff, 0x40_0000, 0x7f_ffff] {
                    bits.push((e << 23) | m);
                    bits.push(0x8000_0000 | (e << 23) | m);
                }
            }
            assert_simd_segment_parity(target, &bits);
        }
    }

    /// SIMD ≡ scalar over random raw bit patterns (NaN payloads,
    /// subnormals, huge magnitudes) for every target type, including
    /// the bf16/fp4 targets that dispatch back to the scalar loops.
    #[test]
    fn simd_segment_matches_scalar_random_patterns() {
        let mut s = 0xdead_beef_1234_5678u64;
        let mut bits = Vec::with_capacity(50_003);
        for _ in 0..50_003 {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            bits.push((s >> 32) as u32);
        }
        for target in [ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4] {
            assert_simd_segment_parity(target, &bits);
        }
    }
}
