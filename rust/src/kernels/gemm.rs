//! Packed, cache-blocked GEMM microkernels for the host backend.
//!
//! ## Bit-exactness argument
//!
//! The reference loops in `tensor::ops` accumulate every output element
//! `c[i][j]` as a strictly ascending-`k` chain of `c += a[i][k] *
//! b[k][j]` f32 operations (with a skip when `a[i][k] == 0.0` in the
//! `nn`/`tn`/mixed variants, and no skip in `nt`). Floating-point
//! addition is not associative, so that per-element chain is the
//! contract. The kernels here change only:
//!
//! * **where operands live** — B is packed into contiguous column
//!   panels of width [`NR`] (a pure copy; for `nt`, a transpose copy),
//! * **which elements are computed together** — [`MR`] rows × `NR`
//!   columns of `C` accumulate simultaneously in registers,
//!
//! and never the per-element operation sequence: the `k` loop stays
//! outermost-sequential inside each tile, each register accumulates
//! `a[i][k] * pack[k][j]` in ascending `k` with the reference's exact
//! zero-skip, and is stored to `C` once at the end (loads/stores move
//! bits, not values). Output is therefore bitwise equal to the naive
//! loops for every shape — pinned by the unit tests below and by
//! `rust/tests/parallel_equivalence.rs` across thread counts.
//!
//! The perf win is memory traffic: the naive loops re-stream all of B
//! (or B^T) once per output row; the tiled kernels read each packed
//! panel element once per `MR` rows and keep `MR × NR` accumulators in
//! registers, with panel-contiguous loads the compiler vectorizes.

use crate::tensor::Tensor;

/// Rows of C per register tile.
pub const MR: usize = 4;
/// Columns of C per register tile (= packed panel width).
pub const NR: usize = 16;

/// One GEMM operand packed into contiguous column panels: panel `p`
/// holds columns `[p*NR, min((p+1)*NR, n))` of the logical row-major
/// `[k, n]` matrix B, stored `k`-major within the panel
/// (`panel[kk * width + c] = B[kk][p*NR + c]`). Full panels have width
/// `NR`; the ragged last panel is stored tight at its own width.
pub struct PackedB {
    /// Contraction length (rows of logical B).
    pub k: usize,
    /// Output width (columns of logical B).
    pub n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// An all-zero pack buffer for `k`×`n` — the fused quantize-on-pack
    /// writers fill it block by block.
    pub fn zeroed(k: usize, n: usize) -> PackedB {
        PackedB { k, n, data: vec![0.0; k * n] }
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// (flat data offset, width) of panel `p`.
    #[inline]
    fn panel_off_width(&self, p: usize) -> (usize, usize) {
        let j0 = p * NR;
        (j0 * self.k, NR.min(self.n - j0))
    }

    /// Panel `p` as a flat `k * width` slice.
    #[inline]
    pub fn panel(&self, p: usize) -> &[f32] {
        let (off, w) = self.panel_off_width(p);
        &self.data[off..off + self.k * w]
    }

    /// The whole pack buffer (tests compare fused vs unfused packs).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Copy `vals` — columns `[j0, j0 + vals.len())` of logical row
    /// `kk` — into the pack buffer, splitting across panels as needed.
    /// This is the fused quantize-on-pack write primitive: block
    /// quantizers emit row segments and this routes them to panel
    /// storage without materializing the row-major tensor first.
    pub fn write_row_segment(&mut self, kk: usize, j0: usize, vals: &[f32]) {
        debug_assert!(kk < self.k && j0 + vals.len() <= self.n);
        let mut j = j0;
        let mut src = vals;
        while !src.is_empty() {
            let p = j / NR;
            let (off, w) = self.panel_off_width(p);
            let c = j - p * NR;
            let take = (w - c).min(src.len());
            let dst_at = off + kk * w + c;
            self.data[dst_at..dst_at + take].copy_from_slice(&src[..take]);
            j += take;
            src = &src[take..];
        }
    }
}

/// Pack row-major `[k, n]` data into column panels.
pub fn pack_rows(bd: &[f32], k: usize, n: usize) -> PackedB {
    debug_assert_eq!(bd.len(), k * n);
    let mut out = PackedB::zeroed(k, n);
    for kk in 0..k {
        out.write_row_segment(kk, 0, &bd[kk * n..kk * n + n]);
    }
    out
}

/// Pack a row-major tensor into column panels (leading dims folded
/// into rows, like every 2-D view in the GEMM layer).
pub fn pack_b(b: &Tensor) -> PackedB {
    let (k, n) = b.as_2d();
    pack_rows(b.data(), k, n)
}

/// Pack a row-major `[n, k]` tensor (B^T, the second operand of the
/// `nt` variant) into column panels of the **logical** `[k, n]` B — a
/// transpose copy, so the `nt` microkernel reads panel-contiguous
/// rows exactly like `nn` does.
pub fn pack_bt(bt: &Tensor) -> PackedB {
    let (n, k) = (bt.rows(), bt.cols());
    let mut out = PackedB::zeroed(k, n);
    let sd = bt.data();
    for p in 0..out.panels() {
        let (off, w) = out.panel_off_width(p);
        let j0 = p * NR;
        for kk in 0..k {
            for c in 0..w {
                out.data[off + kk * w + c] = sd[(j0 + c) * k + kk];
            }
        }
    }
    out
}

/// C-panel rows `[r0, r1)` of `C = A @ B` over a packed B. `ad` is the
/// row-major `[m, k]` A, `cd` the output row-panel slice (row size
/// `bp.n`, row 0 = global row `r0`). Zero-`a` terms are skipped exactly
/// like the reference `nn` loop.
pub fn nn_panel(ad: &[f32], k: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    tile_loop(bp, r0, r1, cd, |kk, i| {
        let a = ad[i * k + kk];
        if a == 0.0 {
            None
        } else {
            Some(a)
        }
    });
}

/// C-panel rows of `C = A^T @ B`: `ad` is the row-major `[k, m]` A
/// whose column `i` is the logical row. Same zero-skip as the
/// reference `tn` loop.
pub fn tn_panel(ad: &[f32], m: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    tile_loop(bp, r0, r1, cd, |kk, i| {
        let a = ad[kk * m + i];
        if a == 0.0 {
            None
        } else {
            Some(a)
        }
    });
}

/// C-panel rows of `C = A @ B^T` over a [`pack_bt`] pack. The reference
/// `nt` loop accumulates **without** a zero-skip, so this one must not
/// skip either (adding `0.0 * b` is observable when `b` is Inf/NaN).
pub fn nt_panel(ad: &[f32], k: usize, bp: &PackedB, cd: &mut [f32], r0: usize, r1: usize) {
    tile_loop(bp, r0, r1, cd, |kk, i| Some(ad[i * k + kk]));
}

/// Shared MR×NR tile driver: `a_at(kk, i)` yields the A factor for
/// output row `i` at contraction index `kk`, or `None` to skip the term
/// (the reference loops' zero-skip). Per output element the returned
/// factors are consumed in strictly ascending `kk`, so the accumulation
/// chain matches the naive loops bit for bit.
#[inline]
fn tile_loop<F>(bp: &PackedB, r0: usize, r1: usize, cd: &mut [f32], a_at: F)
where
    F: Fn(usize, usize) -> Option<f32>,
{
    let (k, n) = (bp.k, bp.n);
    for p in 0..bp.panels() {
        let j0 = p * NR;
        let pb = bp.panel(p);
        let jw = NR.min(n - j0);
        let mut i = r0;
        while i < r1 {
            let mr = MR.min(r1 - i);
            let mut acc = [[0f32; NR]; MR];
            if jw == NR {
                // Full-width tile: constant bounds let the compiler
                // unroll and vectorize the j loop.
                for kk in 0..k {
                    let brow = &pb[kk * NR..kk * NR + NR];
                    for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                        let Some(a) = a_at(kk, i + r) else { continue };
                        for c in 0..NR {
                            accr[c] += a * brow[c];
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let brow = &pb[kk * jw..kk * jw + jw];
                    for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                        let Some(a) = a_at(kk, i + r) else { continue };
                        for c in 0..jw {
                            accr[c] += a * brow[c];
                        }
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate().take(mr) {
                let at = (i + r - r0) * n + j0;
                cd[at..at + jw].copy_from_slice(&accr[..jw]);
            }
            i += mr;
        }
    }
}

/// In-place register-tiled accumulation for one `(i, k, j)` block of
/// `C += A @ B` — the mixed-type blocked GEMM's inner kernel. `od` is
/// the output row-panel slice (row size `n`, row 0 = global row
/// `row0`); rows `[i0, i1)`, columns `[j0, j1)` accumulate the
/// contraction range `[k0, k1)` with the reference loop's zero-skip.
/// Because C is loaded into the tile registers before the `kk` loop and
/// stored after it, per-element accumulation order across successive
/// k-blocks is exactly the naive `bk`-then-`kk` sequence.
#[allow(clippy::too_many_arguments)]
pub fn nn_block_inplace(
    ad: &[f32],
    k: usize,
    bd: &[f32],
    n: usize,
    od: &mut [f32],
    row0: usize,
    (i0, i1): (usize, usize),
    (k0, k1): (usize, usize),
    (j0, j1): (usize, usize),
) {
    let mut jt = j0;
    while jt < j1 {
        let jw = NR.min(j1 - jt);
        let mut i = i0;
        while i < i1 {
            let mr = MR.min(i1 - i);
            let mut acc = [[0f32; NR]; MR];
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let at = (i + r - row0) * n + jt;
                accr[..jw].copy_from_slice(&od[at..at + jw]);
            }
            for kk in k0..k1 {
                let brow = &bd[kk * n + jt..kk * n + jt + jw];
                for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                    let a = ad[(i + r) * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    for c in 0..jw {
                        accr[c] += a * brow[c];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate().take(mr) {
                let at = (i + r - row0) * n + jt;
                od[at..at + jw].copy_from_slice(&accr[..jw]);
            }
            i += mr;
        }
        jt += jw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64, with_zeros: bool) -> Tensor {
        let mut t = Tensor::normal(&[rows, cols], 1.0, seed);
        if with_zeros {
            for (i, v) in t.data_mut().iter_mut().enumerate() {
                if i % 5 == 0 {
                    *v = 0.0;
                }
            }
        }
        t
    }

    fn naive_nn(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for kk in 0..k {
                let aik = a.data()[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c.data_mut()[i * n + j] += aik * b.data()[kk * n + j];
                }
            }
        }
        c
    }

    fn naive_nt(a: &Tensor, bt: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = bt.rows();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a.data()[i * k + kk] * bt.data()[j * k + kk];
                }
                c.data_mut()[i * n + j] = acc;
            }
        }
        c
    }

    fn assert_bits(a: &Tensor, b: &Tensor, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what} shape");
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn pack_roundtrips_values() {
        let b = mat(7, 37, 3, false);
        let bp = pack_b(&b);
        assert_eq!(bp.panels(), 3);
        for kk in 0..7 {
            for j in 0..37 {
                let p = j / NR;
                let pb = bp.panel(p);
                let w = NR.min(37 - p * NR);
                assert_eq!(
                    pb[kk * w + (j - p * NR)].to_bits(),
                    b.data()[kk * 37 + j].to_bits(),
                    "({kk},{j})"
                );
            }
        }
        // pack_bt of the transpose is the same pack.
        let bt = b.transpose();
        let bp2 = pack_bt(&bt);
        assert_eq!(bp.data().len(), bp2.data().len());
        for (x, y) in bp.data().iter().zip(bp2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn write_row_segment_splits_across_panels() {
        let mut bp = PackedB::zeroed(2, 40);
        let vals: Vec<f32> = (0..30).map(|i| i as f32 + 1.0).collect();
        bp.write_row_segment(1, 5, &vals); // spans panels 0, 1, 2
        let full = {
            let mut t = Tensor::zeros(&[2, 40]);
            t.data_mut()[40 + 5..40 + 35].copy_from_slice(&vals);
            pack_b(&t)
        };
        for (x, y) in bp.data().iter().zip(full.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn panels_match_naive_bitwise_adversarial_shapes() {
        // 1×1, k=1, single-column, tile-boundary ± 1, ragged everything.
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 7, 1),
            (5, 1, 9),
            (MR, 3, NR),
            (MR + 1, 5, NR + 1),
            (MR - 1, 4, NR - 1),
            (13, 17, 33),
            (16, 16, 16),
            (3, 64, 2),
        ];
        for (m, k, n) in shapes {
            let a = mat(m, k, (m * 31 + n) as u64, true);
            let b = mat(k, n, (k * 17 + n) as u64 + 1, true);
            let want = naive_nn(&a, &b);

            let bp = pack_b(&b);
            let mut c = Tensor::zeros(&[m, n]);
            nn_panel(a.data(), k, &bp, c.data_mut(), 0, m);
            assert_bits(&c, &want, &format!("nn {m}x{k}x{n}"));

            // tn over A^T reproduces the same product.
            let at = a.transpose();
            let mut c = Tensor::zeros(&[m, n]);
            tn_panel(at.data(), m, &bp, c.data_mut(), 0, m);
            assert_bits(&c, &want, &format!("tn {m}x{k}x{n}"));

            // nt over B^T: no zero-skip in the reference — compare
            // against the skip-free naive.
            let bt = b.transpose();
            let want_nt = naive_nt(&a, &bt);
            let btp = pack_bt(&bt);
            let mut c = Tensor::zeros(&[m, n]);
            nt_panel(a.data(), k, &btp, c.data_mut(), 0, m);
            assert_bits(&c, &want_nt, &format!("nt {m}x{k}x{n}"));

            // Partial row panels (the par_panels split) agree too.
            if m > 2 {
                let split = m / 2;
                let mut c = Tensor::zeros(&[m, n]);
                let (lo, hi) = c.data_mut().split_at_mut(split * n);
                nn_panel(a.data(), k, &bp, lo, 0, split);
                nn_panel(a.data(), k, &bp, hi, split, m);
                assert_bits(&c, &want, &format!("nn split {m}x{k}x{n}"));
            }
        }
    }

    #[test]
    fn nt_keeps_zero_times_inf_nan() {
        // 0 * inf = NaN must survive: the nt reference has no zero-skip.
        let a = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]);
        let bt = Tensor::from_vec(&[1, 2], vec![f32::INFINITY, 2.0]);
        let want = naive_nt(&a, &bt);
        assert!(want.data()[0].is_nan());
        let btp = pack_bt(&bt);
        let mut c = Tensor::zeros(&[1, 1]);
        nt_panel(a.data(), 2, &btp, c.data_mut(), 0, 1);
        assert!(c.data()[0].is_nan());
        // ...while nn skips the zero row exactly like its reference.
        let b = Tensor::from_vec(&[2, 1], vec![f32::INFINITY, 2.0]);
        let want_nn = naive_nn(&a, &b);
        let bp = pack_b(&b);
        let mut c = Tensor::zeros(&[1, 1]);
        nn_panel(a.data(), 2, &bp, c.data_mut(), 0, 1);
        assert_bits(&c, &want_nn, "nn zero-skip");
        assert_eq!(c.data()[0], 2.0);
    }

    #[test]
    fn block_inplace_matches_naive_block_accumulation() {
        let (m, k, n) = (10, 9, 11);
        let a = mat(m, k, 5, true);
        let b = mat(k, n, 6, false);
        // Naive: accumulate two k-blocks in sequence.
        let mut want = Tensor::zeros(&[m, n]);
        for (k0, k1) in [(0usize, 4usize), (4, 9)] {
            for i in 0..m {
                for kk in k0..k1 {
                    let aik = a.data()[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        want.data_mut()[i * n + j] += aik * b.data()[kk * n + j];
                    }
                }
            }
        }
        let mut c = Tensor::zeros(&[m, n]);
        for (k0, k1) in [(0usize, 4usize), (4, 9)] {
            nn_block_inplace(
                a.data(),
                k,
                b.data(),
                n,
                c.data_mut(),
                0,
                (0, m),
                (k0, k1),
                (0, n),
            );
        }
        assert_bits(&c, &want, "block inplace");
    }
}
