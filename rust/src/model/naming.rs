//! The canonical parameter inventory and quantized-tensor identity
//! scheme — the Rust side of the artifact ABI. `python/compile/model.py`
//! flattens parameters in exactly this order; the manifest pins it and
//! [`crate::runtime::manifest`] verifies names at load time.

use super::config::ModelConfig;
use crate::mor::stats::TensorKey;

/// Linear layers MoR quantizes per transformer block (§4: "four linear
/// layers in one transformer block").
pub const LINEARS_PER_LAYER: usize = 4;
/// Tensors per linear layer the paper tracks: input activation, weight,
/// output gradient.
pub const TENSORS_PER_LINEAR: usize = 3;

/// One model parameter: name + shape, in canonical flattening order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn volume(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The full parameter list for a preset, in the order both sides flatten.
pub fn param_specs(m: &ModelConfig) -> Vec<ParamSpec> {
    let d = m.d_model;
    let mut out = vec![ParamSpec {
        name: "embedding.weight".into(),
        shape: vec![m.vocab_size, d],
    }];
    for l in 0..m.n_layers {
        let p = |name: String, shape: Vec<usize>| ParamSpec { name, shape };
        out.push(p(format!("decoder.layer.{l}.ln1.scale"), vec![d]));
        out.push(p(format!("decoder.layer.{l}.ln1.bias"), vec![d]));
        out.push(p(
            format!("decoder.layer.{l}.self_attention.linear_qkv.weight"),
            vec![d, 3 * d],
        ));
        out.push(p(
            format!("decoder.layer.{l}.self_attention.linear_proj.weight"),
            vec![d, d],
        ));
        out.push(p(format!("decoder.layer.{l}.ln2.scale"), vec![d]));
        out.push(p(format!("decoder.layer.{l}.ln2.bias"), vec![d]));
        out.push(p(format!("decoder.layer.{l}.mlp.fc1.weight"), vec![d, m.d_ff]));
        out.push(p(format!("decoder.layer.{l}.mlp.fc2.weight"), vec![m.d_ff, d]));
    }
    out.push(ParamSpec { name: "final_ln.scale".into(), shape: vec![d] });
    out.push(ParamSpec { name: "final_ln.bias".into(), shape: vec![d] });
    out.push(ParamSpec { name: "lm_head.weight".into(), shape: vec![d, m.vocab_size] });
    out
}

/// Identity of one quantized-tensor slot in the train-step stats output:
/// the stats arrays are laid out `[n_layers, 4 linears, 3 tensors, 2
/// directions]`, flattened row-major.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantTensorId {
    pub layer: usize,
    /// 0 = linear_qkv, 1 = linear_proj, 2 = fc1, 3 = fc2.
    pub linear: usize,
    /// 0 = input activation, 1 = weight, 2 = output gradient.
    pub tensor: usize,
    /// 0 = primary contraction direction, 1 = transpose direction
    /// (distinct only for per-channel partitioning).
    pub direction: usize,
}

impl QuantTensorId {
    pub const TENSOR_NAMES: [&'static str; TENSORS_PER_LINEAR] = ["input", "weight", "grad"];

    /// Flat index in the stats arrays.
    pub fn flat(&self, _n_layers: usize) -> usize {
        ((self.layer * LINEARS_PER_LAYER + self.linear) * TENSORS_PER_LINEAR + self.tensor) * 2
            + self.direction
    }

    /// Inverse of [`Self::flat`].
    pub fn from_flat(idx: usize) -> QuantTensorId {
        let direction = idx % 2;
        let rest = idx / 2;
        let tensor = rest % TENSORS_PER_LINEAR;
        let rest = rest / TENSORS_PER_LINEAR;
        let linear = rest % LINEARS_PER_LAYER;
        let layer = rest / LINEARS_PER_LAYER;
        QuantTensorId { layer, linear, tensor, direction }
    }

    /// Total stats slots for a model.
    pub fn count(m: &ModelConfig) -> usize {
        m.n_layers * LINEARS_PER_LAYER * TENSORS_PER_LINEAR * 2
    }

    /// Map to the heatmap naming scheme.
    pub fn key(&self, per_channel: bool) -> TensorKey {
        let dir = if per_channel {
            if self.direction == 0 {
                "row"
            } else {
                "col"
            }
        } else {
            ""
        };
        TensorKey::new(self.layer, self.linear, Self::TENSOR_NAMES[self.tensor], dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_specs_order_and_volume() {
        let m = ModelConfig::TINY;
        let specs = param_specs(&m);
        assert_eq!(specs[0].name, "embedding.weight");
        assert_eq!(specs.last().unwrap().name, "lm_head.weight");
        // 1 + 8*n_layers + 3
        assert_eq!(specs.len(), 1 + 8 * m.n_layers + 3);
        let total: usize = specs.iter().map(|s| s.volume()).sum();
        assert_eq!(total, m.num_params());
    }

    #[test]
    fn tiny_has_expected_qkv_shape() {
        let specs = param_specs(&ModelConfig::TINY);
        let qkv = specs
            .iter()
            .find(|s| s.name == "decoder.layer.0.self_attention.linear_qkv.weight")
            .unwrap();
        assert_eq!(qkv.shape, vec![64, 192]);
    }

    #[test]
    fn quant_id_flat_roundtrip() {
        let m = ModelConfig::SMALL;
        for idx in 0..QuantTensorId::count(&m) {
            let id = QuantTensorId::from_flat(idx);
            assert_eq!(id.flat(m.n_layers), idx);
            assert!(id.layer < m.n_layers);
            assert!(id.linear < LINEARS_PER_LAYER);
            assert!(id.tensor < TENSORS_PER_LINEAR);
        }
    }

    #[test]
    fn quant_id_key_naming() {
        let id = QuantTensorId { layer: 2, linear: 3, tensor: 0, direction: 0 };
        assert_eq!(id.key(false).name(), "decoder.layer.2.mlp.fc2.input");
        assert_eq!(id.key(true).name(), "decoder.layer.2.mlp.fc2.input.row");
        let id = QuantTensorId { layer: 0, linear: 1, tensor: 2, direction: 1 };
        assert_eq!(
            id.key(true).name(),
            "decoder.layer.0.self_attention.linear_proj.grad.col"
        );
    }

    #[test]
    fn stats_count_matches_paper_shape() {
        // Paper: 32 layers × 4 linears × 3 tensors = 384 rows; ours adds
        // the 2-direction axis.
        let m = ModelConfig::BASE;
        assert_eq!(QuantTensorId::count(&m), m.n_layers * 4 * 3 * 2);
    }
}
