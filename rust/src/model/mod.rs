//! Model and training configuration: transformer presets, the canonical
//! parameter inventory (the single source of truth for the Rust↔HLO
//! buffer ordering), and the Table-1 training configurations.

pub mod config;
pub mod naming;

pub use config::{ModelConfig, TrainConfig};
pub use naming::{ParamSpec, QuantTensorId, LINEARS_PER_LAYER, TENSORS_PER_LINEAR};
