//! Transformer presets and the two Table-1 training configurations,
//! scaled to this testbed (CPU PJRT; batch sizes ÷32, same LR schedule
//! shape and data-quality contrast).

/// A decoder-only transformer preset. The same presets are defined in
/// `python/compile/model.py`; `aot.py` embeds them in the artifact
/// manifest and the runtime cross-checks the two at load time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    /// ~0.8M params — unit/integration tests.
    pub const TINY: ModelConfig = ModelConfig {
        name: "tiny",
        vocab_size: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 256,
        seq_len: 64,
    };

    /// ~3.3M params — the end-to-end example and the paper-figure runs.
    pub const SMALL: ModelConfig = ModelConfig {
        name: "small",
        vocab_size: 256,
        d_model: 256,
        n_layers: 4,
        n_heads: 4,
        d_ff: 1024,
        seq_len: 128,
    };

    /// ~116M params — the "~100M transformer" scale; runnable but slow
    /// on CPU PJRT (used for a short proof-of-scale run).
    pub const BASE: ModelConfig = ModelConfig {
        name: "base",
        vocab_size: 256,
        d_model: 896,
        n_layers: 12,
        n_heads: 14,
        d_ff: 3584,
        seq_len: 256,
    };

    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::TINY),
            "small" => Some(Self::SMALL),
            "base" => Some(Self::BASE),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embedding + blocks + final LN + LM head).
    pub fn num_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 2 * d // ln1 scale+bias
            + d * 3 * d       // wqkv
            + d * d           // wproj
            + 2 * d           // ln2
            + d * self.d_ff   // fc1
            + self.d_ff * d; // fc2
        self.vocab_size * d          // embedding
            + self.n_layers * per_layer
            + 2 * d                  // final ln
            + d * self.vocab_size // lm head
    }

    /// FLOPs per token for a fwd+bwd step (the standard 6·N estimate,
    /// used by the perf report).
    pub fn flops_per_token(&self) -> u64 {
        6 * self.num_params() as u64
    }
}

/// LR schedule shape (both Table-1 configs use cosine annealing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CosineSchedule {
    pub peak_lr: f32,
    pub final_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl CosineSchedule {
    /// Learning rate at `step` (linear warmup then cosine to final_lr).
    pub fn lr_at(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let t = (step.saturating_sub(self.warmup_steps)) as f32
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let t = t.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        self.final_lr + (self.peak_lr - self.final_lr) * cos
    }
}

/// A Table-1 training configuration, scaled to the testbed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub name: &'static str,
    /// Synthetic-corpus profile: 1 = Nemotron-4-like (noisier),
    /// 2 = Nemotron-H-like (higher quality / lower entropy).
    pub data_profile: u8,
    pub schedule: CosineSchedule,
    pub batch_size: usize,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_eps: f32,
    pub weight_decay: f32,
    pub seed: u64,
}

impl TrainConfig {
    /// Configuration 1: Nemotron-4-style data, peak LR 3e-4 → 3e-5,
    /// batch 1024 (scaled ÷32 → 32).
    pub fn config1(total_steps: u64) -> TrainConfig {
        TrainConfig {
            name: "config1",
            data_profile: 1,
            schedule: CosineSchedule {
                peak_lr: 3e-4,
                final_lr: 3e-5,
                warmup_steps: (total_steps / 100).max(10),
                total_steps,
            },
            batch_size: 32,
            adam_beta1: 0.9,
            adam_beta2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.0,
            seed: 1234,
        }
    }

    /// Configuration 2: higher-quality data, peak LR 1.2e-3 → 3e-6,
    /// batch 1536 (scaled ÷32 → 48).
    pub fn config2(total_steps: u64) -> TrainConfig {
        TrainConfig {
            name: "config2",
            data_profile: 2,
            schedule: CosineSchedule {
                peak_lr: 1.2e-3,
                final_lr: 3e-6,
                warmup_steps: (total_steps / 100).max(10),
                total_steps,
            },
            batch_size: 48,
            adam_beta1: 0.9,
            adam_beta2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.0,
            seed: 5678,
        }
    }

    pub fn by_name(name: &str, total_steps: u64) -> Option<TrainConfig> {
        match name {
            "config1" => Some(Self::config1(total_steps)),
            "config2" => Some(Self::config2(total_steps)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(ModelConfig::preset("tiny"), Some(ModelConfig::TINY));
        assert_eq!(ModelConfig::preset("small"), Some(ModelConfig::SMALL));
        assert_eq!(ModelConfig::preset("base"), Some(ModelConfig::BASE));
        assert_eq!(ModelConfig::preset("huge"), None);
    }

    #[test]
    fn param_counts_in_expected_bands() {
        assert!(ModelConfig::TINY.num_params() < 2_000_000);
        let small = ModelConfig::SMALL.num_params();
        assert!((3_000_000..30_000_000).contains(&small), "small={small}");
        let base = ModelConfig::BASE.num_params();
        assert!((90_000_000..150_000_000).contains(&base), "base={base}");
    }

    #[test]
    fn head_dim_divides() {
        for m in [ModelConfig::TINY, ModelConfig::SMALL, ModelConfig::BASE] {
            assert_eq!(m.d_model % m.n_heads, 0, "{}", m.name);
        }
    }

    #[test]
    fn cosine_schedule_shape() {
        let s =
            CosineSchedule { peak_lr: 3e-4, final_lr: 3e-5, warmup_steps: 10, total_steps: 100 };
        assert!(s.lr_at(0) < s.lr_at(9)); // warming up
        assert!((s.lr_at(10) - 3e-4).abs() < 1e-8); // peak after warmup
        assert!(s.lr_at(50) < 3e-4);
        assert!((s.lr_at(100) - 3e-5).abs() < 1e-8); // annealed
        assert!((s.lr_at(1000) - 3e-5).abs() < 1e-8); // clamped past end
    }

    #[test]
    fn table1_contrast_preserved() {
        let c1 = TrainConfig::config1(1000);
        let c2 = TrainConfig::config2(1000);
        assert!(c2.schedule.peak_lr > c1.schedule.peak_lr);
        assert!(c2.schedule.final_lr < c1.schedule.final_lr);
        assert!(c2.batch_size > c1.batch_size);
        assert_ne!(c1.data_profile, c2.data_profile);
        // Scaled batch ratio matches the paper's 1536/1024.
        assert_eq!(c2.batch_size * 1024, c1.batch_size * 1536);
    }
}
