//! FP4 (E2M1) and the NVFP4 block format — the "even lower precision"
//! target the paper names for future MoR recipes (§1, §5). Implemented as
//! a first-class extension so the MoR framework can rank `[NVFP4, E4M3,
//! BF16]` type lists, and so benches can probe where the relative-error
//! invariance breaks for 4-bit formats.
//!
//! E2M1: 1 sign, 2 exponent (bias 1), 1 mantissa. Representable
//! magnitudes: 0, 0.5, 1, 1.5, 2, 3, 4, 6. No Inf/NaN encodings.
//! NVFP4: contiguous 1x16 blocks each scaled by an E4M3 scale factor
//! (plus a per-tensor FP32 scale in the full recipe; we keep the
//! per-tensor part in FP32 as the paper's GAM group mantissa does).

use super::fp8::{Fp8Format, Rounding, E4M3};

/// Largest finite E2M1 magnitude.
pub const E2M1_MAX: f32 = 6.0;

/// The eight non-negative E2M1 grid points.
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Encode f32 to a 4-bit E2M1 code (low nibble), RNE, saturating.
pub fn e2m1_encode(x: f32) -> u8 {
    if x.is_nan() {
        return 0; // no NaN encoding; flush (callers pre-filter)
    }
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let mag = x.abs();
    // Nearest grid point with ties-to-even(-code).
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for (i, g) in E2M1_GRID.iter().enumerate() {
        let d = (mag - g).abs();
        if d < best_d || (d == best_d && i % 2 == 0) {
            // Exact ties prefer the even code; grid iteration order makes
            // the lower index win ties unless the higher one is even.
            if d < best_d || (d == best_d && best % 2 == 1) {
                best = i;
                best_d = d;
            }
        }
    }
    sign | best as u8
}

/// Decode a 4-bit E2M1 code (low nibble).
pub fn e2m1_decode(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

/// Fake quantization through E2M1.
pub fn e2m1_quantize_dequantize(x: f32) -> f32 {
    e2m1_decode(e2m1_encode(x))
}

/// NVFP4 block size (1x16 sub-channel blocks, §1 of the paper).
pub const NVFP4_BLOCK: usize = 16;

/// Fake-quantize a contiguous slice through the NVFP4 recipe: for each
/// 1x16 block, scale by an E4M3-encoded factor mapping the block amax to
/// E2M1_MAX, quantize to E2M1, then de-scale. `out` must be same length.
pub fn nvfp4_quantize_dequantize(x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    for (xb, ob) in x.chunks(NVFP4_BLOCK).zip(out.chunks_mut(NVFP4_BLOCK)) {
        let amax = xb.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        if amax == 0.0 || !amax.is_finite() {
            ob.copy_from_slice(xb);
            continue;
        }
        // NVFP4 stores the *de-scale* (amax/q_amax) in E4M3; round it via
        // the E4M3 codec so metadata precision loss is modelled.
        let descale = E4M3::quantize_dequantize(amax / E2M1_MAX, Rounding::Saturate);
        if descale == 0.0 {
            ob.copy_from_slice(xb);
            continue;
        }
        let scale = 1.0 / descale;
        for (x, o) in xb.iter().zip(ob.iter_mut()) {
            *o = e2m1_quantize_dequantize(x * scale) * descale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_roundtrip() {
        for (i, g) in E2M1_GRID.iter().enumerate() {
            assert_eq!(e2m1_decode(i as u8), *g);
            assert_eq!(e2m1_decode(e2m1_encode(*g)), *g);
            assert_eq!(e2m1_decode(e2m1_encode(-*g)).abs(), *g);
        }
    }

    #[test]
    fn saturates_at_six() {
        assert_eq!(e2m1_quantize_dequantize(100.0), 6.0);
        assert_eq!(e2m1_quantize_dequantize(-7.0), -6.0);
    }

    #[test]
    fn nearest_rounding() {
        assert_eq!(e2m1_quantize_dequantize(0.2), 0.0);
        assert_eq!(e2m1_quantize_dequantize(0.3), 0.5);
        assert_eq!(e2m1_quantize_dequantize(2.4), 2.0);
        assert_eq!(e2m1_quantize_dequantize(2.6), 3.0);
        assert_eq!(e2m1_quantize_dequantize(5.1), 6.0);
    }

    #[test]
    fn ties_to_even_code() {
        // 2.5 is halfway between 2.0 (code 4, even) and 3.0 (code 5):
        // even code wins → 2.0.
        assert_eq!(e2m1_quantize_dequantize(2.5), 2.0);
        // 1.25 halfway between 1.0 (code 2) and 1.5 (code 3) → 1.0.
        assert_eq!(e2m1_quantize_dequantize(1.25), 1.0);
        // 0.25 halfway between 0.0 (code 0) and 0.5 (code 1) → 0.0.
        assert_eq!(e2m1_quantize_dequantize(0.25), 0.0);
    }

    #[test]
    fn nvfp4_blocks_never_saturate() {
        // After block scaling the amax maps to <= 6.0 * (descale rounding
        // slack); the dequantized max must stay within ~one E4M3 ulp of
        // the original amax.
        let x: Vec<f32> = (0..64).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.7).collect();
        let mut out = vec![0.0; 64];
        nvfp4_quantize_dequantize(&x, &mut out);
        let amax_in = x.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let amax_out = out.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        assert!(amax_out <= amax_in * 1.1, "{amax_out} vs {amax_in}");
        // And the elementwise relative error for a smooth block is bounded
        // by the E2M1 step (~25%) plus scale metadata error.
        for (a, b) in x.iter().zip(out.iter()) {
            if a.abs() > amax_in / 8.0 {
                assert!(((a - b) / a).abs() < 0.30, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn nvfp4_zero_block_passthrough() {
        let x = vec![0.0f32; 32];
        let mut out = vec![1.0f32; 32];
        nvfp4_quantize_dequantize(&x, &mut out);
        assert_eq!(out, x);
    }
}
