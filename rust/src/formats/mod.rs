//! Bit-exact numeric format codecs used throughout the MoR engine.
//!
//! Every format the paper touches is implemented from first principles:
//! the two FP8 formats of the OCP spec ([`fp8::E4M3`], [`fp8::E5M2`]),
//! BF16 ([`bf16`]), the E8M0 power-of-two scale-factor format ([`e8m0`]),
//! and the FP4/NVFP4 extension formats ([`fp4`]) the paper names as the
//! next target for MoR-style recipes.
//!
//! Encoding is round-to-nearest-even, matching `ml_dtypes` (the reference
//! implementation JAX uses); cross-language equivalence is pinned by a
//! golden table generated from `ml_dtypes` (`rust/tests/golden/`) and by
//! the PJRT integration tests.

pub mod bf16;
pub mod e8m0;
pub mod fp4;
pub mod fp8;

pub use bf16::Bf16;
pub use e8m0::E8M0;
pub use fp8::{Fp8Format, E4M3, E5M2};

/// A format MoR can select for a block, ordered "most aggressive" first
/// in recipe type-lists (Algorithm 2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ReprType {
    /// FP8 E4M3 (4 exponent bits, 3 mantissa bits, max 448, no Inf).
    E4M3,
    /// FP8 E5M2 (5 exponent bits, 2 mantissa bits, max 57344, IEEE-style).
    E5M2,
    /// BF16 — the "fallback to input precision" terminal of every recipe.
    Bf16,
    /// FP4 E2M1 with NVFP4-style 1x16 E4M3 block scales (extension).
    NvFp4,
}

impl ReprType {
    /// Bits per element payload (excluding scale metadata).
    pub fn bits(self) -> u32 {
        match self {
            ReprType::E4M3 | ReprType::E5M2 => 8,
            ReprType::Bf16 => 16,
            ReprType::NvFp4 => 4,
        }
    }

    /// The largest finite representable magnitude ("q_amax" in Alg. 1).
    pub fn max_finite(self) -> f32 {
        match self {
            ReprType::E4M3 => fp8::E4M3::MAX,
            ReprType::E5M2 => fp8::E5M2::MAX,
            ReprType::Bf16 => bf16::MAX,
            ReprType::NvFp4 => fp4::E2M1_MAX,
        }
    }

    /// Stable lowercase name used in manifests, CSV logs and CLI flags.
    pub fn name(self) -> &'static str {
        match self {
            ReprType::E4M3 => "e4m3",
            ReprType::E5M2 => "e5m2",
            ReprType::Bf16 => "bf16",
            ReprType::NvFp4 => "nvfp4",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "e4m3" => Some(ReprType::E4M3),
            "e5m2" => Some(ReprType::E5M2),
            "bf16" => Some(ReprType::Bf16),
            "nvfp4" => Some(ReprType::NvFp4),
            _ => None,
        }
    }
}

impl std::fmt::Display for ReprType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repr_type_roundtrip_names() {
        for t in [ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4] {
            assert_eq!(ReprType::parse(t.name()), Some(t));
        }
        assert_eq!(ReprType::parse("fp64"), None);
    }

    #[test]
    fn max_finite_matches_paper_constants() {
        // Section 2: "E4M3 ... positive values between 2^-9 and 448";
        // "E5M2 ... between 2^-16 and 57,344".
        assert_eq!(ReprType::E4M3.max_finite(), 448.0);
        assert_eq!(ReprType::E5M2.max_finite(), 57344.0);
    }

    #[test]
    fn bits_are_payload_bits() {
        assert_eq!(ReprType::E4M3.bits(), 8);
        assert_eq!(ReprType::NvFp4.bits(), 4);
        assert_eq!(ReprType::Bf16.bits(), 16);
    }
}
