//! Bit-exact FP8 codecs: E4M3 (a.k.a. `float8_e4m3fn`) and E5M2.
//!
//! E4M3 follows the "FN" (finite + NaN) convention from the FP8 paper
//! [Micikevicius et al., 2022] and the OCP spec: there is no Inf; the
//! all-ones exponent is reclaimed for normal numbers except mantissa=111
//! which is NaN. Max finite = ±448, min normal = 2^-6, min subnormal =
//! 2^-9. E5M2 is a true IEEE-754 binary8: Inf at exponent=all-ones,
//! max finite = ±57344, min normal = 2^-14, min subnormal = 2^-16.
//!
//! Encoding implements round-to-nearest-even by operating directly on the
//! f32 bit pattern, exactly as `ml_dtypes` does; overflow behaviour is
//! selectable ([`Rounding::NanOnOverflow`] matches `ml_dtypes`/JAX casts,
//! [`Rounding::Saturate`] matches hardware training recipes that clamp to
//! the max finite value).

/// Overflow behaviour for [`Fp8Format::encode_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// RNE; magnitudes that round above MAX become NaN (E4M3) or
    /// Inf (E5M2). This is the `ml_dtypes` / JAX `astype` behaviour.
    NanOnOverflow,
    /// RNE; magnitudes that round above MAX clamp to ±MAX. This is what
    /// FP8 training recipes (and the paper's fake-quant pipeline after
    /// amax scaling) effectively rely on.
    Saturate,
}

/// A static description of an FP8 format, plus bit-exact encode/decode.
pub trait Fp8Format {
    /// Number of exponent bits.
    const EXP_BITS: u32;
    /// Number of mantissa bits.
    const MAN_BITS: u32;
    /// Exponent bias.
    const BIAS: i32;
    /// Largest finite magnitude.
    const MAX: f32;
    /// Smallest positive normal magnitude.
    const MIN_NORMAL: f32;
    /// Smallest positive subnormal magnitude.
    const MIN_SUBNORMAL: f32;
    /// Whether the format has IEEE Inf/NaN at exponent=all-ones (E5M2)
    /// or reclaims the top binade, keeping only mantissa=all-ones as NaN
    /// (E4M3 "FN" convention).
    const HAS_INF: bool;
    /// Human-readable name.
    const NAME: &'static str;

    /// Decode one fp8 byte to f32 (exact).
    fn decode(byte: u8) -> f32 {
        let sign = if byte & 0x80 != 0 { -1.0f32 } else { 1.0 };
        let exp_mask = ((1u32 << Self::EXP_BITS) - 1) as u8;
        let man_mask = ((1u32 << Self::MAN_BITS) - 1) as u8;
        let e = (byte >> Self::MAN_BITS) & exp_mask;
        let m = byte & man_mask;
        if e == exp_mask && Self::HAS_INF {
            return if m == 0 { sign * f32::INFINITY } else { f32::NAN };
        }
        if !Self::HAS_INF && e == exp_mask && m == man_mask {
            return f32::NAN; // E4M3: S.1111.111 is the only NaN
        }
        if e == 0 {
            // Subnormal: m * 2^(1-bias-man_bits)
            let v = m as f32 * (2.0f32).powi(1 - Self::BIAS - Self::MAN_BITS as i32);
            return sign * v;
        }
        let significand = 1.0 + m as f32 / (1u32 << Self::MAN_BITS) as f32;
        sign * significand * (2.0f32).powi(e as i32 - Self::BIAS)
    }

    /// Encode f32 to one fp8 byte with round-to-nearest-even.
    fn encode_with(x: f32, mode: Rounding) -> u8 {
        let bits = x.to_bits();
        let sign = ((bits >> 31) as u8) << 7;
        let exp_mask = ((1u32 << Self::EXP_BITS) - 1) as u8;
        let man_mask = ((1u32 << Self::MAN_BITS) - 1) as u8;

        if x.is_nan() {
            // Canonical NaN: all-ones exponent+mantissa (E4M3) or
            // exponent=all-ones, mantissa MSB set (E5M2, quiet NaN).
            return if Self::HAS_INF {
                sign | (exp_mask << Self::MAN_BITS) | (1 << (Self::MAN_BITS - 1))
            } else {
                sign | (exp_mask << Self::MAN_BITS) | man_mask
            };
        }
        if x.is_infinite() {
            // Same policy as finite overflow below: ml_dtypes maps Inf to
            // Inf (E5M2) or NaN (E4M3, which has no Inf encoding) in
            // NanOnOverflow mode, and clamps to ±MAX in Saturate mode.
            return match mode {
                Rounding::Saturate => Self::encode_max_with_sign(sign, mode),
                Rounding::NanOnOverflow => {
                    if Self::HAS_INF {
                        sign | (exp_mask << Self::MAN_BITS) // Inf
                    } else {
                        sign | (exp_mask << Self::MAN_BITS) | man_mask // NaN
                    }
                }
            };
        }

        let mag = x.abs();
        if mag == 0.0 {
            return sign; // ±0
        }

        // Round the f32 magnitude onto the fp8 grid using integer
        // arithmetic on the significand (RNE), the same algorithm
        // ml_dtypes uses for float→float8 conversion.
        let abs_bits = bits & 0x7fff_ffff;
        let f32_exp = ((abs_bits >> 23) as i32) - 127; // unbiased, valid for normals
        // f32 subnormals (< 2^-126) are far below any fp8 subnormal: they
        // round to ±0 for both formats (min fp8 subnormal is 2^-16).
        if abs_bits < 0x0080_0000 {
            return sign;
        }

        // Target unbiased exponent of the fp8 value if it were normal.
        let min_norm_exp = 1 - Self::BIAS; // unbiased exponent of MIN_NORMAL
        // Position the value as significand * 2^exp with significand in
        // [1, 2) represented in 24 bits (implicit leading one).
        let significand24 = (abs_bits & 0x007f_ffff) | 0x0080_0000; // 1.m in Q1.23

        // shift = number of f32 mantissa bits we must drop to reach the
        // fp8 mantissa width at this exponent. For subnormal results the
        // exponent is pinned at min_norm_exp and the significand shifts
        // further right.
        let drop = if f32_exp >= min_norm_exp {
            23 - Self::MAN_BITS as i32
        } else {
            // Subnormal range: each step below min_norm_exp costs one
            // extra bit of right shift.
            23 - Self::MAN_BITS as i32 + (min_norm_exp - f32_exp)
        };

        if drop >= 33 {
            return sign; // rounds to zero regardless of mantissa
        }

        // RNE on a 64-bit staging value so large shifts are exact.
        let staged = (significand24 as u64) << 10; // headroom, Q1.33
        let total_drop = (drop + 10) as u32;
        let keep = staged >> total_drop;
        let round_bit = (staged >> (total_drop - 1)) & 1;
        let sticky = (staged & ((1u64 << (total_drop - 1)) - 1)) != 0;
        let rounded = keep + ((round_bit != 0 && (sticky || (keep & 1) == 1)) as u64);

        // `rounded` is the fp8 significand including the implicit bit for
        // normals (so in [2^MAN_BITS, 2^(MAN_BITS+1)]) or a pure mantissa
        // for subnormals (in [0, 2^MAN_BITS]). Renormalize if rounding
        // carried out.
        let (e_fp8, m_fp8);
        if f32_exp >= min_norm_exp {
            let mut exp = f32_exp;
            let mut sig = rounded;
            if sig >= (1u64 << (Self::MAN_BITS + 1)) {
                sig >>= 1;
                exp += 1;
            }
            e_fp8 = exp + Self::BIAS;
            m_fp8 = (sig as u8) & man_mask;
        } else {
            // Subnormal result; may round up into the first normal binade.
            if rounded >= (1u64 << Self::MAN_BITS) {
                e_fp8 = 1;
                m_fp8 = (rounded as u8) & man_mask;
            } else {
                e_fp8 = 0;
                m_fp8 = rounded as u8;
            }
        }

        // Overflow handling.
        let max_exp_field: i32 = if Self::HAS_INF {
            exp_mask as i32 - 1 // top binade is Inf/NaN
        } else {
            exp_mask as i32
        };
        let overflowed = e_fp8 > max_exp_field
            || (!Self::HAS_INF && e_fp8 == max_exp_field && m_fp8 == man_mask);
        if overflowed {
            return match mode {
                Rounding::Saturate => Self::encode_max_with_sign(sign, mode),
                Rounding::NanOnOverflow => {
                    if Self::HAS_INF {
                        sign | (exp_mask << Self::MAN_BITS) // Inf
                    } else {
                        sign | (exp_mask << Self::MAN_BITS) | man_mask // NaN
                    }
                }
            };
        }

        debug_assert!(e_fp8 >= 0);
        sign | ((e_fp8 as u8) << Self::MAN_BITS) | m_fp8
    }

    /// Byte encoding of ±MAX.
    fn encode_max_with_sign(sign: u8, _mode: Rounding) -> u8 {
        let exp_mask = ((1u32 << Self::EXP_BITS) - 1) as u8;
        let man_mask = ((1u32 << Self::MAN_BITS) - 1) as u8;
        if Self::HAS_INF {
            // Max finite: exponent = all-ones - 1, mantissa = all-ones.
            sign | ((exp_mask - 1) << Self::MAN_BITS) | man_mask
        } else {
            // E4M3: exponent all-ones, mantissa = all-ones - 1 (0x7E).
            sign | (exp_mask << Self::MAN_BITS) | (man_mask - 1)
        }
    }

    /// Encode with the default mode ([`Rounding::NanOnOverflow`], the
    /// `ml_dtypes` behaviour used for cross-validation).
    fn encode(x: f32) -> u8 {
        Self::encode_with(x, Rounding::NanOnOverflow)
    }

    /// Fake quantization of a single element: encode then decode
    /// ("cast fp8, cast back" in the Fig. 4 pipeline).
    fn quantize_dequantize(x: f32, mode: Rounding) -> f32 {
        Self::decode(Self::encode_with(x, mode))
    }
}

/// The E4M3 ("FN") format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E4M3;

impl Fp8Format for E4M3 {
    const EXP_BITS: u32 = 4;
    const MAN_BITS: u32 = 3;
    const BIAS: i32 = 7;
    const MAX: f32 = 448.0;
    const MIN_NORMAL: f32 = 0.015625; // 2^-6
    const MIN_SUBNORMAL: f32 = 0.001953125; // 2^-9
    const HAS_INF: bool = false;
    const NAME: &'static str = "e4m3";
}

/// The E5M2 format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct E5M2;

impl Fp8Format for E5M2 {
    const EXP_BITS: u32 = 5;
    const MAN_BITS: u32 = 2;
    const BIAS: i32 = 15;
    const MAX: f32 = 57344.0;
    const MIN_NORMAL: f32 = 6.103515625e-5; // 2^-14
    const MIN_SUBNORMAL: f32 = 1.52587890625e-5; // 2^-16
    const HAS_INF: bool = true;
    const NAME: &'static str = "e5m2";
}

/// Dynamic dispatch helper for code that selects the format at runtime
/// (the MoR framework walks a runtime list of [`super::ReprType`]s).
pub fn quantize_dequantize(t: super::ReprType, x: f32, mode: Rounding) -> f32 {
    match t {
        super::ReprType::E4M3 => E4M3::quantize_dequantize(x, mode),
        super::ReprType::E5M2 => E5M2::quantize_dequantize(x, mode),
        super::ReprType::Bf16 => super::bf16::quantize_dequantize(x),
        super::ReprType::NvFp4 => super::fp4::e2m1_quantize_dequantize(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference decode via an independent table-based method: enumerate
    /// the format definition arithmetic long-hand.
    fn decode_ref<F: Fp8Format>(byte: u8) -> f32 {
        F::decode(byte)
    }

    #[test]
    fn e4m3_decode_key_values() {
        assert_eq!(E4M3::decode(0x00), 0.0);
        assert_eq!(E4M3::decode(0x80), -0.0);
        assert_eq!(E4M3::decode(0x7E), 448.0);
        assert_eq!(E4M3::decode(0xFE), -448.0);
        assert!(E4M3::decode(0x7F).is_nan());
        assert!(E4M3::decode(0xFF).is_nan());
        assert_eq!(E4M3::decode(0x01), 0.001953125); // min subnormal 2^-9
        assert_eq!(E4M3::decode(0x08), 0.015625); // min normal 2^-6
        assert_eq!(E4M3::decode(0x38), 1.0);
        assert_eq!(E4M3::decode(0x39), 1.125);
    }

    #[test]
    fn e5m2_decode_key_values() {
        assert_eq!(E5M2::decode(0x00), 0.0);
        assert_eq!(E5M2::decode(0x7B), 57344.0);
        assert!(E5M2::decode(0x7C).is_infinite());
        assert!(E5M2::decode(0x7D).is_nan());
        assert!(E5M2::decode(0xFD).is_nan());
        assert_eq!(E5M2::decode(0x01), 1.52587890625e-5); // 2^-16
        assert_eq!(E5M2::decode(0x04), 6.103515625e-5); // 2^-14
        assert_eq!(E5M2::decode(0x3C), 1.0);
    }

    /// Every representable value must round-trip exactly.
    #[test]
    fn roundtrip_all_256_patterns_e4m3() {
        for b in 0u16..=255 {
            let b = b as u8;
            let v = decode_ref::<E4M3>(b);
            if v.is_nan() {
                assert!(E4M3::decode(E4M3::encode(v)).is_nan());
            } else {
                let e = E4M3::encode(v);
                assert_eq!(
                    E4M3::decode(e),
                    v,
                    "byte {b:#04x} decodes to {v}, re-encodes to {e:#04x}"
                );
            }
        }
    }

    #[test]
    fn roundtrip_all_256_patterns_e5m2() {
        for b in 0u16..=255 {
            let b = b as u8;
            let v = decode_ref::<E5M2>(b);
            if v.is_nan() {
                assert!(E5M2::decode(E5M2::encode(v)).is_nan());
            } else {
                let e = E5M2::encode(v);
                assert_eq!(E5M2::decode(e), v, "byte {b:#04x}");
            }
        }
    }

    /// RNE: exact midpoints go to even mantissa.
    #[test]
    fn rne_ties_to_even() {
        // Between 1.0 (0x38, m=000) and 1.125 (0x39, m=001) midpoint 1.0625
        // must go to even mantissa (1.0).
        assert_eq!(E4M3::decode(E4M3::encode(1.0625)), 1.0);
        // Between 1.125 (m=001) and 1.25 (m=010): midpoint 1.1875 → 1.25.
        assert_eq!(E4M3::decode(E4M3::encode(1.1875)), 1.25);
        // E5M2: between 1.0 (m=00) and 1.25 (m=01): 1.125 → 1.0.
        assert_eq!(E5M2::decode(E5M2::encode(1.125)), 1.0);
        // Between 1.25 and 1.5: 1.375 → 1.5 (m=10 even).
        assert_eq!(E5M2::decode(E5M2::encode(1.375)), 1.5);
    }

    #[test]
    fn overflow_behaviour() {
        // E4M3 overflow: NaN in ml_dtypes mode, ±448 in saturate mode.
        assert!(E4M3::decode(E4M3::encode_with(500.0, Rounding::NanOnOverflow)).is_nan());
        assert_eq!(
            E4M3::decode(E4M3::encode_with(500.0, Rounding::Saturate)),
            448.0
        );
        assert_eq!(
            E4M3::decode(E4M3::encode_with(-1e9, Rounding::Saturate)),
            -448.0
        );
        // Boundary: exactly 448 + half-ulp (=464) rounds to 448 with RNE
        // (tie toward even ... 464 is the midpoint between 448 and the
        // would-be 480; ml_dtypes rounds ties away from max? No: 464 ties
        // to even mantissa 110 → 448 stays).
        assert_eq!(E4M3::decode(E4M3::encode(464.0)), 448.0);
        assert!(E4M3::decode(E4M3::encode(465.0)).is_nan());
        // E5M2 overflow → Inf.
        assert!(E5M2::decode(E5M2::encode(70000.0)).is_infinite());
        assert_eq!(
            E5M2::decode(E5M2::encode_with(70000.0, Rounding::Saturate)),
            57344.0
        );
        // Inf input follows the same policy as finite overflow: E4M3 has
        // no Inf encoding, so ml_dtypes maps it to NaN (byte 0x7f/0xff).
        assert_eq!(E4M3::encode(f32::INFINITY), 0x7F);
        assert_eq!(E4M3::encode(f32::NEG_INFINITY), 0xFF);
        assert_eq!(
            E4M3::decode(E4M3::encode_with(f32::INFINITY, Rounding::Saturate)),
            448.0
        );
        assert_eq!(E5M2::encode(f32::NEG_INFINITY), 0xFC);
    }

    #[test]
    fn underflow_to_zero() {
        // Below half the min subnormal flushes to zero.
        assert_eq!(E4M3::decode(E4M3::encode(0.0009)), 0.0);
        // Above half the min subnormal rounds up to it.
        assert_eq!(E4M3::decode(E4M3::encode(0.001)), 0.001953125);
        // Exactly half: tie to even → 0.
        assert_eq!(E4M3::decode(E4M3::encode(0.0009765625)), 0.0);
        // 1.5x min subnormal: tie to even → 2 ulp = 0.00390625.
        assert_eq!(E4M3::decode(E4M3::encode(0.0029296875)), 0.00390625);
        // f32 subnormals flush to zero.
        assert_eq!(E4M3::encode(f32::from_bits(1)), 0);
        assert_eq!(E5M2::encode(-f32::from_bits(0x0040_0000)) & 0x7f, 0);
    }

    #[test]
    fn sign_preserved() {
        assert_eq!(E4M3::encode(-1.0), 0xB8);
        assert_eq!(E4M3::decode(0xB8), -1.0);
        assert_eq!(E4M3::encode(-0.0), 0x80);
    }

    /// Monotonicity of encode over a dense sweep: quantize_dequantize must
    /// be a non-decreasing function.
    #[test]
    fn quantize_monotone() {
        let mut prev = f32::NEG_INFINITY;
        let mut x = -460.0f32;
        while x <= 460.0 {
            let q = E4M3::quantize_dequantize(x, Rounding::Saturate);
            assert!(q >= prev, "non-monotone at {x}: {q} < {prev}");
            prev = q;
            x += 0.173;
        }
    }

    /// The quantized value is always one of the two neighbouring grid
    /// points (|q - x| <= ulp at x), i.e. correct rounding.
    #[test]
    fn correctly_rounded_against_grid() {
        // Build the sorted set of finite non-negative E4M3 values.
        let mut grid: Vec<f32> = (0u16..=255)
            .map(|b| E4M3::decode(b as u8))
            .filter(|v| v.is_finite() && *v >= 0.0)
            .collect();
        grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
        grid.dedup();
        let mut x = 0.0f32;
        while x < 448.0 {
            let q = E4M3::quantize_dequantize(x, Rounding::Saturate);
            // q must be in the grid
            assert!(grid.binary_search_by(|g| g.partial_cmp(&q).unwrap()).is_ok());
            // and must be the nearest grid point (or tie).
            let idx = grid.partition_point(|g| *g < x);
            let below = if idx > 0 { grid[idx - 1] } else { grid[0] };
            let above = if idx < grid.len() { grid[idx] } else { *grid.last().unwrap() };
            let best = if (x - below).abs() <= (above - x).abs() {
                (x - below).abs()
            } else {
                (above - x).abs()
            };
            assert!(
                (q - x).abs() <= best + best * 1e-6,
                "x={x} q={q} below={below} above={above}"
            );
            x += 0.7791;
        }
    }
}
