//! E8M0 — the OCP micro-scaling power-of-two scale-factor format: an
//! 8-bit biased exponent with **no mantissa and no sign**. It represents
//! exactly the powers of two 2^-127 .. 2^127 plus a NaN encoding (0xFF).
//!
//! GAM (Alg. 1) stores one E8M0 exponent per block; the "E8M0 scaling"
//! ablation of §4.1.2 uses it directly as the whole scale factor.

/// Bias of the E8M0 exponent field.
pub const BIAS: i32 = 127;

/// An E8M0-encoded power-of-two scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct E8M0(pub u8);

impl E8M0 {
    /// NaN encoding.
    pub const NAN: E8M0 = E8M0(0xFF);

    /// Construct from an unbiased exponent, clamping to the representable
    /// range [-127, 127].
    pub fn from_exponent(e: i32) -> Self {
        E8M0((e.clamp(-BIAS, BIAS) + BIAS) as u8)
    }

    /// The unbiased exponent.
    pub fn exponent(self) -> i32 {
        self.0 as i32 - BIAS
    }

    /// Decode to the exact f32 power of two (NaN for the NaN encoding).
    pub fn to_f32(self) -> f32 {
        if self.0 == 0xFF {
            return f32::NAN;
        }
        exp2i(self.exponent())
    }

    /// Encode an arbitrary positive scale by taking floor(log2(s)) — the
    /// round-down convention, which never *increases* the scale and thus
    /// never introduces saturation when the scale multiplies data toward
    /// a format's max (the same safety direction as GAM's rounding rule).
    pub fn from_scale_floor(s: f32) -> Self {
        if !(s > 0.0) || !s.is_finite() {
            return E8M0::NAN;
        }
        Self::from_exponent(floor_log2(s))
    }
}

/// Exact 2^e for |e| <= 127 without powf.
pub fn exp2i(e: i32) -> f32 {
    debug_assert!((-BIAS..=BIAS).contains(&e));
    f32::from_bits(((e + 127) as u32) << 23)
}

/// floor(log2(x)) for positive finite x, exact via the exponent field
/// (handles f32 subnormals by renormalizing).
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let e = (bits >> 23) as i32;
    if e > 0 {
        (e & 0xff) - 127
    } else {
        // Subnormal: x = m * 2^-149, so floor(log2 x) = msb(m) - 149.
        let m = bits & 0x007f_ffff;
        let msb = 31 - m.leading_zeros() as i32;
        msb - 149
    }
}

/// The mantissa (significand in [1,2)) and unbiased exponent of a
/// positive finite f32: x = mantissa * 2^exponent. This is the
/// `mantissa(s)` / `exponent(s)` decomposition used by Algorithm 1.
pub fn frexp1(x: f32) -> (f32, i32) {
    debug_assert!(x > 0.0 && x.is_finite(), "frexp1 domain: {x}");
    let bits = x.to_bits();
    let e = ((bits >> 23) & 0xff) as i32;
    if e > 0 {
        let mantissa = f32::from_bits((bits & 0x007f_ffff) | 0x3f80_0000);
        (mantissa, e - 127)
    } else {
        // Subnormal: x = m * 2^-149 = 1.f * 2^(msb-149) after sliding the
        // MSB of m into the implicit-one position (bit 23).
        let m = bits & 0x007f_ffff;
        let msb = 31 - m.leading_zeros() as i32;
        let norm_m = (m << (23 - msb)) & 0x007f_ffff;
        let mantissa = f32::from_bits(norm_m | 0x3f80_0000);
        (mantissa, msb - 149)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn powers_of_two_roundtrip() {
        for e in -127..=127 {
            let s = E8M0::from_exponent(e);
            assert_eq!(s.exponent(), e);
            assert_eq!(s.to_f32(), exp2i(e));
        }
    }

    #[test]
    fn nan_encoding() {
        assert!(E8M0::NAN.to_f32().is_nan());
        assert!(E8M0::from_scale_floor(f32::NAN).to_f32().is_nan());
        assert!(E8M0::from_scale_floor(-1.0).to_f32().is_nan());
        assert!(E8M0::from_scale_floor(0.0).to_f32().is_nan());
    }

    #[test]
    fn floor_rounding_never_exceeds() {
        for s in [1.0f32, 1.5, 2.0, 3.99, 4.0, 0.75, 1e-20, 7e20] {
            let q = E8M0::from_scale_floor(s).to_f32();
            assert!(q <= s, "E8M0({s}) = {q} > {s}");
            assert!(q > s / 2.0, "E8M0({s}) = {q} not within one binade");
        }
    }

    #[test]
    fn clamping_at_range_ends() {
        assert_eq!(E8M0::from_exponent(500).exponent(), 127);
        assert_eq!(E8M0::from_exponent(-500).exponent(), -127);
    }

    #[test]
    fn frexp1_normal_and_subnormal() {
        let (m, e) = frexp1(6.0);
        assert_eq!((m, e), (1.5, 2));
        let (m, e) = frexp1(1.0);
        assert_eq!((m, e), (1.0, 0));
        let (m, e) = frexp1(0.1);
        assert!((m * exp2i(e) - 0.1).abs() < 1e-9);
        assert!((1.0..2.0).contains(&m));
        // Subnormal f32.
        let x = f32::from_bits(0x0000_0400); // 2^-136
        let (m, e) = frexp1(x);
        assert!((1.0..2.0).contains(&m), "m={m}");
        assert_eq!(m as f64 * (e as f64).exp2(), x as f64);
    }

    #[test]
    fn floor_log2_matches_float_log2() {
        let mut x = 1.3e-35f32;
        while x < 1e30 {
            assert_eq!(floor_log2(x), x.log2().floor() as i32, "x={x}");
            x *= 2.31;
        }
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(0.9999999), -1);
        assert_eq!(floor_log2(2.0), 1);
    }
}
