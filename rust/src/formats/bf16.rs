//! BF16 codec (truncated-exponent-range f32, 8 exponent / 7 mantissa
//! bits). BF16 is the paper's "original precision" — every MoR recipe
//! terminates in a BF16 fallback, and the fake-quant pipeline (Fig. 4)
//! keeps tensors materialized in BF16.

/// Largest finite BF16 magnitude.
pub const MAX: f32 = 3.3895314e38; // 0x7F7F as bf16

/// A 16-bit storage wrapper around a BF16 value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Round-to-nearest-even conversion from f32 (matches hardware and
    /// `ml_dtypes.bfloat16`).
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet the NaN and keep the payload non-zero.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = (bits >> 15) & 1;
        let sticky = bits & 0x7fff;
        let mut hi = (bits >> 16) as u16;
        if round_bit == 1 && (sticky != 0 || (hi & 1) == 1) {
            hi = hi.wrapping_add(1); // may carry into exponent → Inf, correct
        }
        Bf16(hi)
    }

    /// Exact conversion back to f32.
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Fake quantization through BF16 (round-trip f32 → bf16 → f32).
pub fn quantize_dequantize(x: f32) -> f32 {
    Bf16::from_f32(x).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 448.0, 57344.0, MAX] {
            assert_eq!(quantize_dequantize(v), v);
        }
    }

    #[test]
    fn rne_rounding() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // bf16 (1 + 2^-7): ties to even → 1.0.
        let half_ulp = 1.0 + (2f32).powi(-8);
        assert_eq!(quantize_dequantize(half_ulp), 1.0);
        // 1 + 3*2^-8 is halfway between 1+2^-7 and 1+2^-6 → even → 1+2^-6.
        let v = 1.0 + 3.0 * (2f32).powi(-8);
        assert_eq!(quantize_dequantize(v), 1.0 + (2f32).powi(-6));
        // Just above the midpoint rounds up.
        assert_eq!(
            quantize_dequantize(1.0 + (2f32).powi(-8) + (2f32).powi(-20)),
            1.0 + (2f32).powi(-7)
        );
    }

    #[test]
    fn overflow_to_inf() {
        assert!(quantize_dequantize(3.4e38).is_infinite());
        assert!(quantize_dequantize(f32::INFINITY).is_infinite());
        assert!(quantize_dequantize(-f32::INFINITY).is_infinite());
    }

    #[test]
    fn nan_stays_nan() {
        assert!(quantize_dequantize(f32::NAN).is_nan());
    }

    #[test]
    fn sign_of_zero() {
        assert_eq!(Bf16::from_f32(-0.0).0, 0x8000);
        assert_eq!(Bf16::from_f32(0.0).0, 0x0000);
    }

    #[test]
    fn relative_error_bounded_by_ulp() {
        // For normals, |x - bf16(x)|/|x| <= 2^-8.
        let mut x = 1e-30f32;
        while x < 1e30 {
            let q = quantize_dequantize(x);
            assert!(((x - q) / x).abs() <= (2f32).powi(-8), "x={x} q={q}");
            x *= 3.7;
        }
    }
}
