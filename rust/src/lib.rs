//! # MoR — Mixture of Representations for Mixed-Precision Training
//!
//! A full-system reproduction of the MoR paper (Su et al., NVIDIA 2025):
//! the Group Amax Mantissa (GAM) scaling algorithm, the dynamic MoR
//! quantization framework, the tensor-level and sub-tensor recipes, and
//! the fake-quantized training evaluation pipeline.
//!
//! Architecture (three layers, Python never on the request path):
//! * Layer 1 — Pallas fake-quantization kernels (build time, `python/`).
//! * Layer 2 — JAX transformer with explicit manual backward, lowered
//!   once to HLO text artifacts (`python/compile/aot.py`).
//! * Layer 3 — this crate: the runtime coordinator ([`runtime`],
//!   [`coordinator`]), a bit-exact host mirror of the numerics
//!   ([`formats`], [`scaling`], [`quant`], [`mor`]) with a table-driven
//!   /cache-blocked kernel layer ([`kernels`]), the data pipeline
//!   ([`data`]), and the paper-table/figure report harness ([`report`]).
//!
//! Start with [`mor::Recipe`] for the decision engine and
//! [`coordinator::Trainer`] for the training loop.

pub mod coordinator;
pub mod data;
pub mod faults;
pub mod formats;
pub mod kernels;
pub mod model;
pub mod mor;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod scaling;
pub mod tensor;
pub mod util;
