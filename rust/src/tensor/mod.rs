//! A minimal dense row-major f32 tensor — the host-side data substrate
//! for the MoR engine mirror, the data pipeline, and the Fig. 3
//! mixed-type GEMM. Deliberately small: 2-D is the common case (every
//! tensor MoR quantizes is a GEMM operand), with just enough n-D support
//! for batched token tensors.

pub mod ops;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from existing data; `data.len()` must equal the shape volume.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Deterministic pseudo-random tensor (xorshift64*), values ~U(-a, a).
    pub fn uniform(shape: &[usize], amplitude: f32, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        let data = (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                let u = (s >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
                ((u * 2.0 - 1.0) as f32) * amplitude
            })
            .collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Deterministic ~N(0, std) tensor via Box–Muller on the xorshift
    /// stream; used for weight init and synthetic activations/gradients.
    pub fn normal(shape: &[usize], std: f32, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 11) as f64 / (1u64 << 53) as f64
        };
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let u1 = next().max(1e-12);
            let u2 = next();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s2, c2) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            data.push((r * c2) as f32 * std);
            if data.len() < n {
                data.push((r * s2) as f32 * std);
            }
        }
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / cols for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-2D tensor");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-2D tensor");
        self.shape[1]
    }

    /// 2-D element access.
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols() + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols();
        self.data[r * cols + c] = v;
    }

    /// View as 2-D by folding all leading dims into rows.
    pub fn as_2d(&self) -> (usize, usize) {
        let cols = *self.shape.last().expect("as_2d on scalar tensor");
        (self.data.len() / cols.max(1), cols)
    }

    /// Transposed copy (2-D only).
    pub fn transpose(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Absolute maximum over all elements (0 for empty).
    pub fn amax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, v| a.max(v.abs()))
    }

    /// Absolute minimum over non-zero elements (None if all zero).
    pub fn amin_nonzero(&self) -> Option<f32> {
        let m = self
            .data
            .iter()
            .filter(|v| **v != 0.0)
            .fold(f32::INFINITY, |a, v| a.min(v.abs()));
        if m.is_finite() {
            Some(m)
        } else {
            None
        }
    }

    /// L2 norm.
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.at(0, 1), 2.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::uniform(&[5, 7], 2.0, 42);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().at(3, 2), t.at(2, 3));
    }

    #[test]
    fn amax_and_amin() {
        let t = Tensor::from_vec(&[1, 4], vec![0.0, -3.0, 2.0, 0.5]);
        assert_eq!(t.amax(), 3.0);
        assert_eq!(t.amin_nonzero(), Some(0.5));
        let z = Tensor::zeros(&[2, 2]);
        assert_eq!(z.amax(), 0.0);
        assert_eq!(z.amin_nonzero(), None);
    }

    #[test]
    fn deterministic_rng() {
        let a = Tensor::normal(&[4, 4], 1.0, 7);
        let b = Tensor::normal(&[4, 4], 1.0, 7);
        let c = Tensor::normal(&[4, 4], 1.0, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn normal_moments_roughly_correct() {
        let t = Tensor::normal(&[100, 100], 2.0, 1);
        let mean = t.data().iter().sum::<f32>() / t.len() as f32;
        let var =
            t.data().iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.1, "std={}", var.sqrt());
    }

    #[test]
    fn as_2d_folds_leading_dims() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.as_2d(), (6, 4));
    }
}
