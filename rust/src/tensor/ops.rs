//! Host GEMM and the Fig. 3 mixed-representation blocked GEMM.
//!
//! The blocked GEMM implements the paper's sub-tensor story: operand
//! matrices are partitioned into blocks whose representation types were
//! chosen independently by MoR; a block-pair dot product runs "in" the
//! lower of the two precisions only when both operands share it,
//! otherwise the lower-precision block is *upcast* to the higher type
//! (E4M3/E5M2 → BF16) before multiplication — exactly the fallback the
//! paper describes when no mixed-type hardware dot product exists.
//!
//! All four GEMMs parallelize over contiguous **row panels** of the
//! output via [`crate::util::par`]. Each output element accumulates its
//! k-products in ascending-k order on exactly one thread, so results
//! are bit-identical to the serial path for any thread count (pinned by
//! `rust/tests/parallel_equivalence.rs`).
//!
//! Inside each panel, three interchangeable kernel implementations
//! exist, selected by the handle's [`crate::util::par::KernelMode`]:
//! the original naive triple loops (`matmul_naive_with` & co., the
//! parity oracle), the packed register-tiled microkernels of
//! [`crate::kernels::gemm`], and their runtime-dispatched AVX2 twins in
//! [`crate::kernels::simd`]. All run the identical per-element
//! floating-point sequence — including the zero-`a` skip — so outputs
//! are bitwise equal; only memory traffic and lane width differ.

use super::Tensor;
use crate::formats::ReprType;
use crate::kernels::gemm::{self, PackedB};
use crate::kernels::simd;
use crate::util::par::{self, KernelMode, Parallelism};

/// Below this many multiply-accumulates the operand-packing overhead of
/// the blocked kernels outweighs their cache wins; such GEMMs take the
/// naive loops even in the kernel-layer modes (bit-identical either
/// way, so the cutoff is pure scheduling).
const BLOCKED_MIN_MACS: usize = 4096;

fn use_blocked(cfg: &Parallelism, macs: usize) -> bool {
    cfg.kernel() != KernelMode::Scalar && macs >= BLOCKED_MIN_MACS
}

/// The panel microkernel for the handle's mode: the AVX2-dispatched
/// entry under [`KernelMode::Simd`], the scalar blocked kernel
/// otherwise. Both signatures are identical, so selection is one fn
/// pointer resolved outside the parallel region.
type PanelFn = fn(&[f32], usize, &PackedB, &mut [f32], usize, usize);

fn nn_panel_for(cfg: &Parallelism) -> PanelFn {
    if cfg.kernel() == KernelMode::Simd {
        simd::nn_panel
    } else {
        gemm::nn_panel
    }
}

fn tn_panel_for(cfg: &Parallelism) -> PanelFn {
    if cfg.kernel() == KernelMode::Simd {
        simd::tn_panel
    } else {
        gemm::tn_panel
    }
}

fn nt_panel_for(cfg: &Parallelism) -> PanelFn {
    if cfg.kernel() == KernelMode::Simd {
        simd::nt_panel
    } else {
        gemm::nt_panel
    }
}

/// Plain f32 GEMM: C = A @ B, parallel over output-row panels with the
/// process-global [`Parallelism`].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_with(a, b, &par::global())
}

/// [`matmul`] with an explicit [`Parallelism`]: packed blocked kernel
/// by default, the naive reference loop under [`KernelMode::Scalar`]
/// or for tiny products.
pub fn matmul_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    if use_blocked(cfg, m * k * n) {
        matmul_packed_with(a, &gemm::pack_b(b), cfg)
    } else {
        matmul_naive_with(a, b, cfg)
    }
}

/// C = A @ B over an already-packed B — the fused quantize-on-pack
/// entry: `runtime::host` builds the pack while quantizing the operand,
/// then calls this directly, skipping one full materialize+re-read
/// pass. Bitwise equal to [`matmul_with`] on the equivalent tensor.
pub fn matmul_packed_with(a: &Tensor, bp: &PackedB, cfg: &Parallelism) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(k, bp.k, "matmul inner dims: {k} vs {}", bp.k);
    let n = bp.n;
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let cfg = cfg.gate(m * n);
    let panel = nn_panel_for(&cfg);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        panel(ad, k, bp, cd, r0, r1);
    });
    c
}

/// The original naive i/k/j loop — the scalar parity oracle and the
/// small-product path.
pub fn matmul_naive_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cfg = cfg.gate(m * n);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        for (ri, i) in (r0..r1).enumerate() {
            for kk in 0..k {
                let aik = ad[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bd[kk * n..kk * n + n];
                let crow = &mut cd[ri * n..ri * n + n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    });
    c
}

/// C = A^T @ B without materializing the transpose.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_tn_with(a, b, &par::global())
}

/// [`matmul_tn`] with an explicit [`Parallelism`]. Per output element
/// the contraction still runs in ascending-k order (the loop nest is
/// output-row-major rather than the serial version's historical k-major
/// order, which accumulates the identical per-element sequence).
pub fn matmul_tn_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    if !use_blocked(cfg, m * k * n) {
        return matmul_tn_naive_with(a, b, cfg);
    }
    let bp = gemm::pack_b(b);
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let cfg = cfg.gate(m * n);
    let panel = tn_panel_for(&cfg);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        panel(ad, m, &bp, cd, r0, r1);
    });
    c
}

/// The naive `tn` reference loop.
pub fn matmul_tn_naive_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cfg = cfg.gate(m * n);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        for (ri, i) in (r0..r1).enumerate() {
            let crow = &mut cd[ri * n..ri * n + n];
            for kk in 0..k {
                let aik = ad[kk * m + i];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bd[kk * n..kk * n + n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    });
    c
}

/// C = A @ B^T.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_nt_with(a, b, &par::global())
}

/// [`matmul_nt`] with an explicit [`Parallelism`].
pub fn matmul_nt_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    if !use_blocked(cfg, m * k * n) {
        return matmul_nt_naive_with(a, b, cfg);
    }
    let bp = gemm::pack_bt(b);
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let cfg = cfg.gate(m * n);
    let panel = nt_panel_for(&cfg);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        panel(ad, k, &bp, cd, r0, r1);
    });
    c
}

/// The naive `nt` reference loop (no zero-skip — a dot product per
/// output element).
pub fn matmul_nt_naive_with(a: &Tensor, b: &Tensor, cfg: &Parallelism) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cfg = cfg.gate(m * n);
    let bounds = par::chunk_bounds(m, cfg.threads);
    par::par_panels(&cfg, &bounds, n, c.data_mut(), |_pi, (r0, r1), cd| {
        for (ri, i) in (r0..r1).enumerate() {
            let arow = &ad[i * k..i * k + k];
            for j in 0..n {
                let brow = &bd[j * k..j * k + k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                cd[ri * n + j] = acc;
            }
        }
    });
    c
}

/// Per-block representation assignment for one operand of a blocked GEMM:
/// `types[bi][bj]` is the type of block (bi, bj) under a `block` x `block`
/// partition (ragged edge blocks included).
#[derive(Debug, Clone)]
pub struct BlockTypes {
    pub block: usize,
    pub grid: Vec<Vec<ReprType>>,
}

impl BlockTypes {
    /// All blocks the same type.
    pub fn uniform(rows: usize, cols: usize, block: usize, t: ReprType) -> Self {
        let br = rows.div_ceil(block);
        let bc = cols.div_ceil(block);
        BlockTypes { block, grid: vec![vec![t; bc]; br] }
    }

    pub fn type_of(&self, bi: usize, bj: usize) -> ReprType {
        self.grid[bi][bj]
    }
}

/// The effective compute type of a block-pair dot product (Fig. 3): the
/// *least aggressive* (highest-precision) of the two operand types; when
/// the two differ, the more aggressive block is upcast.
pub fn effective_gemm_type(a: ReprType, b: ReprType) -> ReprType {
    use ReprType::*;
    // Precision order (low→high): NvFp4 < E4M3 ~ E5M2 < Bf16. A mixed
    // E4M3/E5M2 pair has no common FP8 dot product on H100-class hardware
    // either, so it also upcasts to BF16 per the paper's rule.
    match (a, b) {
        (x, y) if x == y => x,
        (Bf16, _) | (_, Bf16) => Bf16,
        (E4M3, E5M2) | (E5M2, E4M3) => Bf16,
        (NvFp4, other) | (other, NvFp4) => other,
        (x, _) => x, // unreachable: equal pairs matched first
    }
}

/// Blocked mixed-type GEMM. Numerically the inputs are already
/// fake-quantized; the purpose here is to *count* what fraction of MACs
/// ran in each effective type, which is the efficiency-side statistic for
/// the sub-tensor recipes (paper Fig. 3 discussion).
pub struct MixedGemmReport {
    pub out: Tensor,
    /// MAC counts per effective type, ordered [E4M3, E5M2, BF16, NVFP4].
    pub macs: [u64; 4],
}

pub fn mixed_gemm(a: &Tensor, ta: &BlockTypes, b: &Tensor, tb: &BlockTypes) -> MixedGemmReport {
    mixed_gemm_with(a, ta, b, tb, &par::global())
}

/// [`mixed_gemm`] with an explicit [`Parallelism`]: parallel over
/// block-row panels of the output (each worker owns whole block-rows,
/// so accumulation order per element is the serial bk-then-k order).
pub fn mixed_gemm_with(
    a: &Tensor,
    ta: &BlockTypes,
    b: &Tensor,
    tb: &BlockTypes,
    cfg: &Parallelism,
) -> MixedGemmReport {
    assert_eq!(ta.block, tb.block, "operand partitions must agree on K");
    let blk = ta.block;
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let n_bi = m.div_ceil(blk);
    let cfg = cfg.gate(m * n);
    let blocked = cfg.kernel() != par::KernelMode::Scalar;
    #[allow(clippy::type_complexity)]
    let block_inplace: fn(
        &[f32],
        usize,
        &[f32],
        usize,
        &mut [f32],
        usize,
        (usize, usize),
        (usize, usize),
        (usize, usize),
    ) = if cfg.kernel() == par::KernelMode::Simd {
        simd::nn_block_inplace
    } else {
        gemm::nn_block_inplace
    };
    let bounds = par::unit_panel_bounds(n_bi, blk, m, cfg.threads);
    let panel_macs: Vec<[u64; 4]> =
        par::par_panels(&cfg, &bounds, n, out.data_mut(), |_pi, (row0, row1), od| {
            let mut macs = [0u64; 4];
            for bi in row0 / blk..row1.div_ceil(blk) {
                for bj in 0..n.div_ceil(blk) {
                    for bk in 0..k.div_ceil(blk) {
                        let t = effective_gemm_type(ta.type_of(bi, bk), tb.type_of(bk, bj));
                        let (i0, i1) = (bi * blk, ((bi + 1) * blk).min(m));
                        let (j0, j1) = (bj * blk, ((bj + 1) * blk).min(n));
                        let (k0, k1) = (bk * blk, ((bk + 1) * blk).min(k));
                        let idx = match t {
                            ReprType::E4M3 => 0,
                            ReprType::E5M2 => 1,
                            ReprType::Bf16 => 2,
                            ReprType::NvFp4 => 3,
                        };
                        macs[idx] += ((i1 - i0) * (j1 - j0) * (k1 - k0)) as u64;
                        if blocked {
                            // Register-tiled in-place kernel: identical
                            // bk-then-kk per-element accumulation.
                            block_inplace(ad, k, bd, n, od, row0, (i0, i1), (k0, k1), (j0, j1));
                            continue;
                        }
                        for i in i0..i1 {
                            let orow = &mut od[(i - row0) * n..(i - row0) * n + n];
                            for kk in k0..k1 {
                                let aik = ad[i * k + kk];
                                if aik == 0.0 {
                                    continue;
                                }
                                for j in j0..j1 {
                                    orow[j] += aik * bd[kk * n + j];
                                }
                            }
                        }
                    }
                }
            }
            macs
        });
    let mut macs = [0u64; 4];
    for pm in panel_macs {
        for (t, v) in macs.iter_mut().zip(pm.iter()) {
            *t += v;
        }
    }
    MixedGemmReport { out, macs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_variants_agree() {
        let a = Tensor::uniform(&[7, 5], 1.0, 1);
        let b = Tensor::uniform(&[5, 9], 1.0, 2);
        let c = matmul(&a, &b);
        let c_tn = matmul_tn(&a.transpose(), &b);
        let c_nt = matmul_nt(&a, &b.transpose());
        for i in 0..c.len() {
            assert!((c.data()[i] - c_tn.data()[i]).abs() < 1e-5);
            assert!((c.data()[i] - c_nt.data()[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn mixed_gemm_matches_plain_and_counts_macs() {
        let a = Tensor::uniform(&[10, 6], 1.0, 3);
        let b = Tensor::uniform(&[6, 8], 1.0, 4);
        let ta = BlockTypes::uniform(10, 6, 4, ReprType::E4M3);
        let mut tb = BlockTypes::uniform(6, 8, 4, ReprType::E4M3);
        tb.grid[0][0] = ReprType::Bf16; // one BF16 block forces upcast
        let rep = mixed_gemm(&a, &ta, &b, &tb);
        let plain = matmul(&a, &b);
        for i in 0..plain.len() {
            assert!((rep.out.data()[i] - plain.data()[i]).abs() < 1e-5);
        }
        let total: u64 = rep.macs.iter().sum();
        assert_eq!(total, 10 * 6 * 8);
        assert!(rep.macs[2] > 0, "upcast MACs must be counted as BF16");
        assert!(rep.macs[0] > 0);
    }

    #[test]
    fn blocked_dispatch_matches_naive_bitwise() {
        use crate::util::par::{KernelMode, Parallelism};
        // Shapes above BLOCKED_MIN_MACS so the default mode actually
        // takes the packed kernels; zeros sprinkled in to exercise the
        // skip path.
        let mut a = Tensor::normal(&[33, 17], 1.0, 9);
        for (i, v) in a.data_mut().iter_mut().enumerate() {
            if i % 7 == 0 {
                *v = 0.0;
            }
        }
        let b = Tensor::normal(&[17, 29], 1.0, 10);
        let scl = Parallelism::serial().with_kernel(KernelMode::Scalar);
        assert_eq!(Parallelism::serial().kernel(), KernelMode::Simd);
        let want = matmul_with(&a, &b, &scl);

        for mode in [KernelMode::Blocked, KernelMode::Simd] {
            let cfg = Parallelism::serial().with_kernel(mode);
            let got = matmul_with(&a, &b, &cfg);
            let packed = matmul_packed_with(&a, &crate::kernels::gemm::pack_b(&b), &cfg);
            for i in 0..want.len() {
                assert_eq!(want.data()[i].to_bits(), got.data()[i].to_bits(), "nn {mode:?} {i}");
                assert_eq!(
                    want.data()[i].to_bits(),
                    packed.data()[i].to_bits(),
                    "packed {mode:?} {i}"
                );
            }

            let at = a.transpose();
            let w = matmul_tn_with(&at, &b, &scl);
            let g = matmul_tn_with(&at, &b, &cfg);
            for i in 0..w.len() {
                assert_eq!(w.data()[i].to_bits(), g.data()[i].to_bits(), "tn {mode:?} {i}");
            }

            let bt = b.transpose();
            let w = matmul_nt_with(&a, &bt, &scl);
            let g = matmul_nt_with(&a, &bt, &cfg);
            for i in 0..w.len() {
                assert_eq!(w.data()[i].to_bits(), g.data()[i].to_bits(), "nt {mode:?} {i}");
            }
        }
    }

    #[test]
    fn mixed_gemm_blocked_matches_scalar_bitwise() {
        use crate::util::par::{KernelMode, Parallelism};
        let a = Tensor::normal(&[26, 19], 1.0, 21);
        let b = Tensor::normal(&[19, 23], 1.0, 22);
        let ta = BlockTypes::uniform(26, 19, 8, ReprType::E4M3);
        let mut tb = BlockTypes::uniform(19, 23, 8, ReprType::E4M3);
        tb.grid[0][0] = ReprType::Bf16;
        let scl = Parallelism::serial().with_kernel(KernelMode::Scalar);
        let w = mixed_gemm_with(&a, &ta, &b, &tb, &scl);
        for mode in [KernelMode::Blocked, KernelMode::Simd] {
            let cfg = Parallelism::serial().with_kernel(mode);
            let g = mixed_gemm_with(&a, &ta, &b, &tb, &cfg);
            assert_eq!(w.macs, g.macs);
            for i in 0..w.out.len() {
                assert_eq!(
                    w.out.data()[i].to_bits(),
                    g.out.data()[i].to_bits(),
                    "mixed {mode:?} {i}"
                );
            }
        }
    }

    #[test]
    fn effective_type_rules() {
        use ReprType::*;
        assert_eq!(effective_gemm_type(E4M3, E4M3), E4M3);
        assert_eq!(effective_gemm_type(E4M3, E5M2), Bf16);
        assert_eq!(effective_gemm_type(E4M3, Bf16), Bf16);
        assert_eq!(effective_gemm_type(NvFp4, E4M3), E4M3);
        assert_eq!(effective_gemm_type(NvFp4, NvFp4), NvFp4);
    }
}
