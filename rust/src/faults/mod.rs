//! Deterministic fault injection for chaos testing.
//!
//! A fault schedule is a strictly-parsed spec string (`--faults SPEC` /
//! `MOR_FAULTS`) of `;`-separated entries:
//!
//! ```text
//! nan:grad@step=7        seed one NaN into a gradient tensor at step 7
//! inf:weight@step=9      seed one Inf into a parameter after the update
//! bitflip:block@p=1e-4   flip one mantissa bit per quantized block w.p. p
//! panic:worker@step=11   panic inside a parallel worker closure at step 11
//! repeat-panic:worker@step=5,count=3
//!                        panic the first 3 attempts of step 5 (rewind
//!                        replays refire until the plan spent its count)
//! stall:step@step=4      hang cooperatively before step 4 (the trainer
//!                        polls its stop flag, then self-preempts)
//! torn-save@ckpt=2       truncate the 2nd checkpoint save halfway
//! ```
//!
//! Steps are 1-based optimizer steps (the same domain as
//! `DecisionCtx::step`); checkpoint indices are 1-based save counts.
//! Every random draw comes from a counter-keyed [`Rng`] stream derived
//! from the training seed, so a chaos run is bitwise reproducible at
//! any thread count, and a post-rewind replay redraws identically.
//!
//! Parsing is strict in the house style: malformed sites, missing `@`,
//! zero probabilities and unknown fault kinds abort loudly instead of
//! silently doing nothing.

use crate::util::rng::Rng;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Grammar summary used in error messages.
pub const SPEC_GRAMMAR: &str = "nan:grad@step=N | nan:weight@step=N | inf:grad@step=N | \
     inf:weight@step=N | bitflip:block@p=P | panic:worker@step=N | \
     repeat-panic:worker@step=N,count=K | stall:step@step=N | torn-save@ckpt=K \
     (entries joined with ';')";

/// What value a seed fault injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedKind {
    Nan,
    Inf,
}

impl SeedKind {
    fn name(self) -> &'static str {
        match self {
            SeedKind::Nan => "nan",
            SeedKind::Inf => "inf",
        }
    }

    /// The poison value itself.
    pub fn value(self) -> f32 {
        match self {
            SeedKind::Nan => f32::NAN,
            SeedKind::Inf => f32::INFINITY,
        }
    }
}

/// Where a seed fault lands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedSite {
    /// One gradient element, after backward and before the update.
    Grad,
    /// One parameter element, after the update.
    Weight,
}

impl SeedSite {
    fn name(self) -> &'static str {
        match self {
            SeedSite::Grad => "grad",
            SeedSite::Weight => "weight",
        }
    }
}

/// One scheduled fault.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Seed a NaN/Inf into a gradient or parameter at a 1-based step.
    Seed {
        kind: SeedKind,
        site: SeedSite,
        step: u64,
    },
    /// Flip one mantissa bit in a quantized block with probability `p`
    /// per block per quantization call.
    Bitflip { p: f64 },
    /// Panic inside a parallel worker closure at a 1-based step.
    PanicWorker { step: u64 },
    /// Panic inside a parallel worker on the first `count` *attempts*
    /// of step `step`: unlike the one-shot [`Fault::PanicWorker`], a
    /// rewind replay of the step refires until the plan has fired
    /// `count` times — the persistent-failure shape that exercises the
    /// guard's rewind budget (and, past it, the fleet supervisor's
    /// demotion ladder).
    RepeatPanic { step: u64, count: u64 },
    /// Deterministic stall: the trainer hangs cooperatively before
    /// executing 1-based step `step` — it polls its stop flag for a
    /// fixed (wall-clock-free) budget, then self-preempts without
    /// committing progress. Fires once per plan, i.e. once per fleet
    /// slice, so a stalled tenant stays stalled across retries.
    Stall { step: u64 },
    /// Truncate the `ckpt`-th (1-based) checkpoint save halfway.
    TornSave { ckpt: u64 },
}

impl Fault {
    fn describe(&self) -> String {
        match self {
            Fault::Seed { kind, site, step } => {
                format!("{}:{}@step={}", kind.name(), site.name(), step)
            }
            Fault::Bitflip { p } => format!("bitflip:block@p={p}"),
            Fault::PanicWorker { step } => format!("panic:worker@step={step}"),
            Fault::RepeatPanic { step, count } => {
                format!("repeat-panic:worker@step={step},count={count}")
            }
            Fault::Stall { step } => format!("stall:step@step={step}"),
            Fault::TornSave { ckpt } => format!("torn-save@ckpt={ckpt}"),
        }
    }
}

/// A parsed, validated fault schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub faults: Vec<Fault>,
}

impl FaultSpec {
    /// Canonical spelling; `parse_faults(describe())` round-trips.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.faults.iter().map(|f| f.describe()).collect();
        parts.join(";")
    }
}

fn parse_u64_arg(entry: &str, key: &str, val: &str) -> Result<u64, String> {
    let n: u64 = val
        .parse()
        .map_err(|_| format!("fault {entry:?}: {key} must be a positive integer, got {val:?}"))?;
    if n == 0 {
        return Err(format!(
            "fault {entry:?}: {key}=0 is before the first step and would never fire"
        ));
    }
    Ok(n)
}

/// Split one `key=value` argument (most fault kinds take exactly one;
/// `repeat-panic` splits its comma list first and feeds each part here).
fn split_kv<'a>(entry: &str, arg: &'a str) -> Result<(&'a str, &'a str), String> {
    arg.split_once('=')
        .ok_or_else(|| format!("fault {entry:?}: argument {arg:?} is not key=value"))
}

fn parse_entry(entry: &str) -> Result<Fault, String> {
    let (head, arg) = entry
        .split_once('@')
        .ok_or_else(|| format!("fault {entry:?} is missing '@': expected {SPEC_GRAMMAR}"))?;
    let (kind, site) = match head.split_once(':') {
        Some((k, s)) => (k, Some(s)),
        None => (head, None),
    };
    match kind {
        "nan" | "inf" => {
            let sk = if kind == "nan" { SeedKind::Nan } else { SeedKind::Inf };
            let site = site.ok_or_else(|| {
                format!("fault {entry:?}: {kind} needs a site ({kind}:grad or {kind}:weight)")
            })?;
            let site = match site {
                "grad" => SeedSite::Grad,
                "weight" => SeedSite::Weight,
                other => {
                    return Err(format!(
                        "fault {entry:?}: unknown {kind} site {other:?} (expected grad or weight)"
                    ))
                }
            };
            let (key, val) = split_kv(entry, arg)?;
            if key != "step" {
                return Err(format!("fault {entry:?}: {kind} takes step=N, not {key:?}"));
            }
            let step = parse_u64_arg(entry, "step", val)?;
            Ok(Fault::Seed { kind: sk, site, step })
        }
        "bitflip" => {
            match site {
                Some("block") => {}
                Some(other) => {
                    return Err(format!(
                        "fault {entry:?}: unknown bitflip site {other:?} (only block)"
                    ))
                }
                None => {
                    return Err(format!("fault {entry:?}: bitflip needs the block site"));
                }
            }
            let (key, val) = split_kv(entry, arg)?;
            if key != "p" {
                return Err(format!("fault {entry:?}: bitflip takes p=P, not {key:?}"));
            }
            let p: f64 = val
                .parse()
                .map_err(|_| format!("fault {entry:?}: p must be a number, got {val:?}"))?;
            if !p.is_finite() || p <= 0.0 || p > 1.0 {
                return Err(format!(
                    "fault {entry:?}: p must be in (0, 1] — zero probability never fires"
                ));
            }
            Ok(Fault::Bitflip { p })
        }
        "panic" => {
            match site {
                Some("worker") => {}
                Some(other) => {
                    return Err(format!(
                        "fault {entry:?}: unknown panic site {other:?} (only worker)"
                    ))
                }
                None => {
                    return Err(format!("fault {entry:?}: panic needs the worker site"));
                }
            }
            let (key, val) = split_kv(entry, arg)?;
            if key != "step" {
                return Err(format!("fault {entry:?}: panic takes step=N, not {key:?}"));
            }
            let step = parse_u64_arg(entry, "step", val)?;
            Ok(Fault::PanicWorker { step })
        }
        "repeat-panic" => {
            match site {
                Some("worker") => {}
                Some(other) => {
                    return Err(format!(
                        "fault {entry:?}: unknown repeat-panic site {other:?} (only worker)"
                    ))
                }
                None => {
                    return Err(format!("fault {entry:?}: repeat-panic needs the worker site"));
                }
            }
            let (mut step, mut count) = (None, None);
            for part in arg.split(',') {
                let (key, val) = split_kv(entry, part)?;
                match key {
                    "step" if step.is_none() => step = Some(parse_u64_arg(entry, "step", val)?),
                    "count" if count.is_none() => {
                        count = Some(parse_u64_arg(entry, "count", val)?)
                    }
                    "step" | "count" => {
                        return Err(format!("fault {entry:?}: duplicate {key} argument"))
                    }
                    other => {
                        return Err(format!(
                            "fault {entry:?}: repeat-panic takes step=N,count=K, not {other:?}"
                        ))
                    }
                }
            }
            match (step, count) {
                (Some(step), Some(count)) => Ok(Fault::RepeatPanic { step, count }),
                _ => Err(format!(
                    "fault {entry:?}: repeat-panic needs both step=N and count=K"
                )),
            }
        }
        "stall" => {
            match site {
                Some("step") => {}
                Some(other) => {
                    return Err(format!(
                        "fault {entry:?}: unknown stall site {other:?} (only step)"
                    ))
                }
                None => {
                    return Err(format!("fault {entry:?}: stall needs the step site"));
                }
            }
            let (key, val) = split_kv(entry, arg)?;
            if key != "step" {
                return Err(format!("fault {entry:?}: stall takes step=N, not {key:?}"));
            }
            let step = parse_u64_arg(entry, "step", val)?;
            Ok(Fault::Stall { step })
        }
        "torn-save" => {
            if let Some(s) = site {
                return Err(format!(
                    "fault {entry:?}: torn-save takes no site, got {s:?}"
                ));
            }
            let (key, val) = split_kv(entry, arg)?;
            if key != "ckpt" {
                return Err(format!("fault {entry:?}: torn-save takes ckpt=K, not {key:?}"));
            }
            let ckpt = parse_u64_arg(entry, "ckpt", val)?;
            Ok(Fault::TornSave { ckpt })
        }
        other => Err(format!(
            "unknown fault kind {other:?} in {entry:?}: expected {SPEC_GRAMMAR}"
        )),
    }
}

/// Parse an explicit fault spec. `None` stays `None`; malformed specs
/// (including empty strings and empty entries) are loud errors.
pub fn parse_faults(raw: Option<&str>) -> Result<Option<FaultSpec>, String> {
    let raw = match raw {
        None => return Ok(None),
        Some(r) => r,
    };
    if raw.is_empty() {
        return Err(format!("spec is empty: expected {SPEC_GRAMMAR}"));
    }
    let mut faults = Vec::new();
    for entry in raw.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            return Err(format!("spec {raw:?} has an empty entry"));
        }
        faults.push(parse_entry(entry)?);
    }
    Ok(Some(FaultSpec { faults }))
}

/// Resolve the `MOR_FAULTS` env var; panics loudly on a malformed
/// value, mirroring the other strict knobs.
pub fn auto() -> Option<FaultSpec> {
    let raw = crate::util::env::var("MOR_FAULTS");
    match parse_faults(raw.as_deref()) {
        Ok(opt) => opt,
        Err(msg) => panic!("MOR_FAULTS {msg}"),
    }
}

/// A live fault schedule: the parsed spec plus one-shot firing state
/// and telemetry counters. One plan per training run; seeded from the
/// run's training seed so chaos runs reproduce bitwise.
pub struct FaultPlan {
    spec: FaultSpec,
    seed: u64,
    /// One-shot flags, parallel to `spec.faults` (bitflips re-fire and
    /// ignore theirs).
    fired: Vec<AtomicBool>,
    /// Per-fault attempt counters, parallel to `spec.faults` (only
    /// `repeat-panic` reads its slot: fires while the count is below
    /// its budget).
    counts: Vec<AtomicU64>,
    bitflips: AtomicU64,
    seeds: AtomicU64,
    panics: AtomicU64,
    stalls: AtomicU64,
    torn: AtomicU64,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        let fired = spec.faults.iter().map(|_| AtomicBool::new(false)).collect();
        let counts = spec.faults.iter().map(|_| AtomicU64::new(0)).collect();
        FaultPlan {
            spec,
            seed,
            fired,
            counts,
            bitflips: AtomicU64::new(0),
            seeds: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            torn: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Seed faults due at this 1-based step, firing each at most once.
    pub fn seeds_due(&self, step1: u64) -> Vec<(SeedKind, SeedSite)> {
        let mut due = Vec::new();
        for (i, f) in self.spec.faults.iter().enumerate() {
            if let Fault::Seed { kind, site, step } = f {
                if *step == step1 && !self.fired[i].swap(true, Ordering::Relaxed) {
                    self.seeds.fetch_add(1, Ordering::Relaxed);
                    due.push((*kind, *site));
                }
            }
        }
        due
    }

    /// True when a worker panic is scheduled for this attempt of the
    /// 1-based step: `panic:worker` fires exactly once per plan;
    /// `repeat-panic:worker` fires on each attempt of its step until
    /// the plan has spent its `count` (so a rewind replay of the step
    /// refires — the persistent-failure shape).
    pub fn worker_panic_due(&self, step1: u64) -> bool {
        for (i, f) in self.spec.faults.iter().enumerate() {
            match f {
                Fault::PanicWorker { step } => {
                    if *step == step1 && !self.fired[i].swap(true, Ordering::Relaxed) {
                        self.panics.fetch_add(1, Ordering::Relaxed);
                        return true;
                    }
                }
                Fault::RepeatPanic { step, count } => {
                    if *step == step1
                        && self.counts[i].fetch_add(1, Ordering::Relaxed) < *count
                    {
                        self.panics.fetch_add(1, Ordering::Relaxed);
                        return true;
                    }
                }
                _ => {}
            }
        }
        false
    }

    /// True once, at the scheduled stall step: the trainer responds by
    /// polling its cooperative stop flag (a fixed, wall-clock-free
    /// budget) and self-preempting without committing progress.
    pub fn stall_due(&self, step1: u64) -> bool {
        for (i, f) in self.spec.faults.iter().enumerate() {
            if let Fault::Stall { step } = f {
                if *step == step1 && !self.fired[i].swap(true, Ordering::Relaxed) {
                    self.stalls.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
        }
        false
    }

    /// True once, for the scheduled 1-based checkpoint save index.
    pub fn torn_save_due(&self, ckpt_idx: u64) -> bool {
        for (i, f) in self.spec.faults.iter().enumerate() {
            if let Fault::TornSave { ckpt } = f {
                if *ckpt == ckpt_idx && !self.fired[i].swap(true, Ordering::Relaxed) {
                    self.torn.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
        }
        false
    }

    /// Decide whether a bitflip fault hits the given quantized block;
    /// on a hit, returns the per-block RNG (already advanced past the
    /// hit draw) for the caller to pick the corrupted element with.
    ///
    /// The stream is keyed purely by schedule coordinates (fault index,
    /// tensor class, layer, step, direction, block index) — never by
    /// thread identity or call order — so parallel == serial holds and
    /// a post-rewind replay redraws identically.
    pub fn bitflip_stream(
        &self,
        class_idx: usize,
        layer: usize,
        step1: u64,
        direction: usize,
        block_idx: usize,
    ) -> Option<Rng> {
        for (i, f) in self.spec.faults.iter().enumerate() {
            if let Fault::Bitflip { p } = f {
                let mut h = self.seed ^ 0xB1F1_B1F1_B1F1_B1F1u64;
                for k in [
                    i as u64,
                    class_idx as u64,
                    layer as u64,
                    step1,
                    direction as u64,
                    block_idx as u64,
                ] {
                    h ^= k.wrapping_add(0x9E37_79B9_7F4A_7C15);
                    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
                    h ^= h >> 27;
                }
                let mut rng = Rng::new(h);
                if rng.f64() < *p {
                    self.bitflips.fetch_add(1, Ordering::Relaxed);
                    return Some(rng);
                }
            }
        }
        None
    }

    /// A deterministic stream for picking seed-fault targets.
    pub fn seed_target_stream(&self, step1: u64, salt: u64) -> Rng {
        let mut h = self.seed ^ 0x5EED_5EED_5EED_5EEDu64;
        for k in [step1, salt] {
            h ^= k.wrapping_add(0x9E37_79B9_7F4A_7C15);
            h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 27;
        }
        Rng::new(h)
    }

    pub fn bitflips_fired(&self) -> u64 {
        self.bitflips.load(Ordering::Relaxed)
    }
    pub fn seeds_fired(&self) -> u64 {
        self.seeds.load(Ordering::Relaxed)
    }
    pub fn panics_fired(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }
    pub fn stalls_fired(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }
    pub fn torn_fired(&self) -> u64 {
        self.torn.load(Ordering::Relaxed)
    }
}

/// Panic message used by the injected worker panic; the guard
/// recognizes injected panics by it in test assertions.
pub const WORKER_PANIC_MSG: &str = "injected fault: worker panic";

thread_local! {
    /// Armed on the trainer thread just before a step; consumed by the
    /// first `join2` call on the same thread. Thread-local (not
    /// process-global) so concurrently running tests cannot steal each
    /// other's scheduled panics.
    static WORKER_PANIC_ARMED: Cell<bool> = const { Cell::new(false) };
}

/// Arm the next `join2` on this thread to panic in its second closure.
pub fn arm_worker_panic() {
    WORKER_PANIC_ARMED.with(|c| c.set(true));
}

/// Consume the armed flag (called by `join2`).
pub fn take_worker_panic() -> bool {
    WORKER_PANIC_ARMED.with(|c| c.replace(false))
}

/// Disarm this thread's pending worker panic, if any. Multi-tenant
/// hygiene: a step that unwinds or errors between arming and its first
/// `join2` would leave the flag set on a pool thread, and the next
/// run's `join2` scheduled there would consume a panic it never armed.
/// The trainer clears before every step so a stale flag cannot cross
/// run boundaries.
pub fn clear_worker_panic() {
    WORKER_PANIC_ARMED.with(|c| c.set(false));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips_canonical_spellings() {
        let spec = "nan:grad@step=7;bitflip:block@p=0.0001;panic:worker@step=11;\
                    repeat-panic:worker@step=5,count=3;stall:step@step=4;torn-save@ckpt=2";
        let parsed = parse_faults(Some(spec)).unwrap().unwrap();
        assert_eq!(parsed.faults.len(), 6);
        assert_eq!(parsed.describe(), spec);
        let reparsed = parse_faults(Some(&parsed.describe())).unwrap().unwrap();
        assert_eq!(reparsed, parsed);
        // repeat-panic arguments are order-insensitive; the canonical
        // spelling puts step first.
        let swapped = parse_faults(Some("repeat-panic:worker@count=3,step=5")).unwrap().unwrap();
        assert_eq!(swapped.describe(), "repeat-panic:worker@step=5,count=3");
    }

    #[test]
    fn scientific_notation_probability_canonicalizes() {
        let parsed = parse_faults(Some("bitflip:block@p=1e-4")).unwrap().unwrap();
        assert_eq!(parsed.describe(), "bitflip:block@p=0.0001");
    }

    #[test]
    fn none_is_none_and_rejects_are_loud() {
        assert_eq!(parse_faults(None).unwrap(), None);
        for bad in [
            "",
            "nan:grad",                  // missing '@'
            "nan:grad@7",                // arg is not key=value
            "nan@step=1",                // missing site
            "nan:flux@step=1",           // malformed site
            "nan:grad@step=0",           // step 0 never fires
            "nan:grad@p=1",              // wrong key
            "bitflip:block@p=0",         // zero probability
            "bitflip:block@p=2",         // out of range
            "bitflip:block@p=nope",      // not a number
            "bitflip@p=0.5",             // missing site
            "panic@step=3",              // missing site
            "panic:main@step=3",         // malformed site
            "torn-save:ckpt@ckpt=1",     // torn-save takes no site
            "torn-save@step=1",          // wrong key
            "frob:grad@step=1",          // unknown kind
            "nan:grad@step=1;;inf:grad@step=2", // empty entry
            "repeat-panic@step=1,count=2",   // missing site
            "repeat-panic:main@step=1,count=2", // malformed site
            "repeat-panic:worker@step=1",    // missing count
            "repeat-panic:worker@count=2",   // missing step
            "repeat-panic:worker@step=0,count=2", // step 0 never fires
            "repeat-panic:worker@step=1,count=0", // zero budget never fires
            "repeat-panic:worker@step=1,count=2,step=3", // duplicate key
            "repeat-panic:worker@step=1,blort=2", // unknown key
            "stall@step=3",              // missing site
            "stall:worker@step=3",       // malformed site
            "stall:step@step=0",         // step 0 never fires
            "stall:step@count=3",        // wrong key
        ] {
            assert!(parse_faults(Some(bad)).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn seed_faults_fire_exactly_once() {
        let spec = parse_faults(Some("nan:grad@step=3")).unwrap().unwrap();
        let plan = FaultPlan::new(spec, 42);
        assert!(plan.seeds_due(2).is_empty());
        assert_eq!(plan.seeds_due(3), vec![(SeedKind::Nan, SeedSite::Grad)]);
        assert!(plan.seeds_due(3).is_empty(), "one-shot flag not consumed");
        assert_eq!(plan.seeds_fired(), 1);
    }

    #[test]
    fn panic_and_torn_fire_exactly_once() {
        let spec = parse_faults(Some("panic:worker@step=5;torn-save@ckpt=2"))
            .unwrap()
            .unwrap();
        let plan = FaultPlan::new(spec, 42);
        assert!(!plan.worker_panic_due(4));
        assert!(plan.worker_panic_due(5));
        assert!(!plan.worker_panic_due(5));
        assert!(!plan.torn_save_due(1));
        assert!(plan.torn_save_due(2));
        assert!(!plan.torn_save_due(2));
    }

    #[test]
    fn repeat_panic_fires_per_attempt_until_its_count_is_spent() {
        let spec = parse_faults(Some("repeat-panic:worker@step=4,count=2")).unwrap().unwrap();
        let plan = FaultPlan::new(spec, 42);
        assert!(!plan.worker_panic_due(3), "wrong step never fires");
        assert!(plan.worker_panic_due(4), "attempt 1 fires");
        assert!(plan.worker_panic_due(4), "attempt 2 (a rewind replay) refires");
        assert!(!plan.worker_panic_due(4), "the count is spent");
        assert!(!plan.worker_panic_due(4));
        assert_eq!(plan.panics_fired(), 2);
    }

    #[test]
    fn stall_fires_exactly_once_per_plan() {
        let spec = parse_faults(Some("stall:step@step=3")).unwrap().unwrap();
        let plan = FaultPlan::new(spec, 42);
        assert!(!plan.stall_due(2));
        assert!(plan.stall_due(3));
        assert!(!plan.stall_due(3), "one-shot within a plan");
        assert_eq!(plan.stalls_fired(), 1);
        // A fresh plan (a new fleet slice) refires: stalls persist
        // across retries by construction.
        let spec = parse_faults(Some("stall:step@step=3")).unwrap().unwrap();
        assert!(FaultPlan::new(spec, 42).stall_due(3));
    }

    #[test]
    fn bitflip_stream_is_deterministic_and_coordinate_keyed() {
        let spec = parse_faults(Some("bitflip:block@p=1")).unwrap().unwrap();
        let plan = FaultPlan::new(spec.clone(), 7);
        let a = plan.bitflip_stream(0, 1, 2, 0, 3).expect("p=1 always hits");
        let b = plan.bitflip_stream(0, 1, 2, 0, 3).expect("p=1 always hits");
        let (mut a, mut b) = (a, b);
        assert_eq!(a.next_u64(), b.next_u64(), "same coordinates, same stream");
        let plan2 = FaultPlan::new(spec, 8);
        let mut c = plan2.bitflip_stream(0, 1, 2, 0, 3).unwrap();
        let mut a2 = plan.bitflip_stream(0, 1, 2, 0, 3).unwrap();
        assert_ne!(a2.next_u64(), c.next_u64(), "seed changes the stream");
    }

    #[test]
    fn tiny_probability_mostly_misses() {
        let spec = parse_faults(Some("bitflip:block@p=1e-9")).unwrap().unwrap();
        let plan = FaultPlan::new(spec, 7);
        for b in 0..64 {
            assert!(plan.bitflip_stream(0, 0, 1, 0, b).is_none());
        }
        assert_eq!(plan.bitflips_fired(), 0);
    }

    #[test]
    fn worker_panic_arm_is_thread_local_and_one_shot() {
        assert!(!take_worker_panic());
        arm_worker_panic();
        assert!(take_worker_panic());
        assert!(!take_worker_panic());
        arm_worker_panic();
        let other = std::thread::spawn(take_worker_panic).join().unwrap();
        assert!(!other, "arming must not leak across threads");
        assert!(take_worker_panic());
    }
}
