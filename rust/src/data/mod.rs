//! Data pipeline: synthetic byte-level corpora standing in for the
//! Nemotron-4 / Nemotron-H training sets (see DESIGN.md §2
//! substitutions), the out-of-distribution eval-task suite standing in
//! for the downstream benchmarks, and the batch loader.

pub mod loader;
pub mod synthetic;
pub mod tasks;

pub use loader::BatchLoader;
pub use synthetic::{CorpusProfile, SyntheticCorpus};
pub use tasks::{EvalSuite, EvalTask};
