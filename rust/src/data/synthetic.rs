//! Synthetic byte-level corpora with controllable "quality".
//!
//! The paper's two training configurations differ in data quality
//! (Nemotron-4 vs the higher-quality Nemotron-H); the observable effect
//! in §4.1.3 is that higher-quality data drives tensors into wider
//! dynamic ranges (more BF16 fallbacks: 2.62% → 6.38% per-block). We
//! model "quality" as the *structure* of a second-order Markov source:
//!
//! * profile 1 ("nemotron4-like"): a flatter transition matrix — noisier
//!   text, higher entropy, weaker long-range structure.
//! * profile 2 ("nemotronh-like"): a sharper, more deterministic
//!   transition matrix with embedded vocabulary patterns — lower entropy,
//!   more learnable structure (and lower achievable loss, matching the
//!   paper's loss gap 1.80 vs 1.41).

use crate::util::rng::Rng;

/// Which corpus profile to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusProfile {
    /// Noisier, higher-entropy stream (configuration 1).
    Nemotron4Like,
    /// Structured, lower-entropy stream (configuration 2).
    NemotronHLike,
}

impl CorpusProfile {
    pub fn from_id(id: u8) -> CorpusProfile {
        match id {
            2 => CorpusProfile::NemotronHLike,
            _ => CorpusProfile::Nemotron4Like,
        }
    }
}

/// The dynamic state of a [`SyntheticCorpus`] stream — everything that
/// evolves as tokens are drawn. Together with the construction
/// parameters (profile, vocab, seed — which also derive the static
/// pattern dictionary), this is sufficient to resume the stream
/// bitwise: `restore(new(profile, vocab, seed), state)` continues the
/// exact token sequence. This is what the data-loader position section
/// of a training checkpoint carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorpusState {
    /// Raw `util::rng` stream state.
    pub rng_state: u64,
    /// Second-order Markov context (last two tokens).
    pub context: (u8, u8),
    /// Unconsumed tail of an injected pattern (stack order).
    pub pending: Vec<u8>,
}

/// A deterministic infinite token stream over a byte vocabulary.
pub struct SyntheticCorpus {
    vocab: usize,
    /// Sharpness of the Markov transitions (higher = lower entropy).
    sharpness: f32,
    /// Pattern dictionary injected into the stream (profile 2).
    patterns: Vec<Vec<u8>>,
    pattern_prob: f32,
    rng: Rng,
    state: (u8, u8),
    pending: Vec<u8>,
}

impl SyntheticCorpus {
    pub fn new(profile: CorpusProfile, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 16 && vocab <= 256, "byte-level vocab expected");
        // Sharpness/pattern rates tuned so both corpora are genuinely
        // learnable at the testbed scale (losses drop well below the
        // ln(256)≈5.55 uniform floor) while preserving the Table-1
        // contrast: profile 2 is markedly lower-entropy / more
        // structured, reaching lower loss (paper: 1.41 vs 1.80).
        let (sharpness, pattern_prob) = match profile {
            CorpusProfile::Nemotron4Like => (5.0, 0.30),
            CorpusProfile::NemotronHLike => (9.0, 0.55),
        };
        // A small dictionary of multi-byte "words" (shared across
        // profiles so eval tasks transfer; profile 2 uses them heavily).
        let mut dict_rng = Rng::new(seed ^ 0xD1C7);
        let patterns = (0..32)
            .map(|_| {
                let len = dict_rng.usize_in(3, 8);
                (0..len).map(|_| dict_rng.usize_in(0, vocab - 1) as u8).collect()
            })
            .collect();
        SyntheticCorpus {
            vocab,
            sharpness,
            patterns,
            pattern_prob,
            rng: Rng::new(seed),
            state: (0, 0),
            pending: Vec::new(),
        }
    }

    /// Snapshot the dynamic stream state (see [`CorpusState`]).
    pub fn state(&self) -> CorpusState {
        CorpusState {
            rng_state: self.rng.state(),
            context: self.state,
            pending: self.pending.clone(),
        }
    }

    /// Restore a snapshot taken with [`SyntheticCorpus::state`]. The
    /// corpus must have been constructed with the same (profile, vocab,
    /// seed) triple — the pattern dictionary is seed-derived and is not
    /// part of the dynamic state.
    pub fn set_state(&mut self, s: &CorpusState) {
        self.rng.set_state(s.rng_state);
        self.state = s.context;
        self.pending = s.pending.clone();
    }

    /// Deterministic pseudo-random transition logits for a context pair.
    /// (A hash-derived Markov chain: no table storage, fully
    /// reproducible across runs and languages.)
    fn next_token(&mut self) -> u8 {
        if let Some(t) = self.pending.pop() {
            return t;
        }
        if self.rng.f32() < self.pattern_prob {
            let idx = self.rng.usize_in(0, self.patterns.len() - 1);
            let mut p = self.patterns[idx].clone();
            p.reverse(); // pending is a stack
            let first = p.pop().unwrap();
            self.pending = p;
            return first;
        }
        // Sample from softmax(sharpness * h(context, token)) without
        // materializing the whole distribution: Gumbel-max trick.
        let (a, b) = self.state;
        let mut best = 0u8;
        let mut best_score = f32::NEG_INFINITY;
        // Sample 24 candidate tokens; deterministic hash scores + Gumbel
        // noise give a softmax-like distribution with tunable sharpness.
        for _ in 0..24 {
            let t = self.rng.usize_in(0, self.vocab - 1) as u8;
            let h = hash3(a, b, t);
            let logits = self.sharpness * (h as f32 / u32::MAX as f32);
            let gumbel = -(-self.rng.f64().max(1e-12).ln()).ln() as f32;
            let score = logits + gumbel;
            if score > best_score {
                best_score = score;
                best = t;
            }
        }
        best
    }

    /// Fill `out` with the next tokens of the stream.
    pub fn fill(&mut self, out: &mut [i32]) {
        for o in out.iter_mut() {
            let t = self.next_token();
            self.state = (self.state.1, t);
            *o = t as i32;
        }
    }

    /// Empirical bits-per-token entropy estimate over a sample (used by
    /// tests to verify the profile contrast and by `report table1`).
    pub fn entropy_estimate(&mut self, sample: usize) -> f64 {
        let mut counts = vec![0u64; self.vocab];
        let mut buf = vec![0i32; sample];
        self.fill(&mut buf);
        for t in &buf {
            counts[*t as usize] += 1;
        }
        let n = sample as f64;
        counts
            .iter()
            .filter(|c| **c > 0)
            .map(|c| {
                let p = *c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

fn hash3(a: u8, b: u8, c: u8) -> u32 {
    let mut x = (a as u32) << 16 | (b as u32) << 8 | c as u32;
    x = x.wrapping_mul(0x9E3779B1);
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EBCA6B);
    x ^= x >> 13;
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, 256, 9);
        let mut b = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, 256, 9);
        let mut x = vec![0i32; 512];
        let mut y = vec![0i32; 512];
        a.fill(&mut x);
        b.fill(&mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = SyntheticCorpus::new(CorpusProfile::NemotronHLike, 256, 3);
        let mut buf = vec![0i32; 4096];
        c.fill(&mut buf);
        assert!(buf.iter().all(|t| (0..256).contains(t)));
        // Not degenerate: more than 32 distinct symbols.
        let mut seen = std::collections::BTreeSet::<i32>::new();
        seen.extend(buf.iter());
        assert!(seen.len() > 32, "only {} distinct tokens", seen.len());
    }

    #[test]
    fn profile2_has_lower_entropy() {
        let mut c1 = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, 256, 7);
        let mut c2 = SyntheticCorpus::new(CorpusProfile::NemotronHLike, 256, 7);
        let e1 = c1.entropy_estimate(20000);
        let e2 = c2.entropy_estimate(20000);
        assert!(
            e2 < e1 - 0.1,
            "profile 2 should be lower-entropy: {e2:.3} vs {e1:.3}"
        );
    }

    #[test]
    fn state_snapshot_resumes_stream_bitwise() {
        let mut a = SyntheticCorpus::new(CorpusProfile::NemotronHLike, 256, 13);
        let mut warm = vec![0i32; 777]; // odd length: likely mid-pattern
        a.fill(&mut warm);
        let snap = a.state();
        let mut rest = vec![0i32; 512];
        a.fill(&mut rest);
        // A fresh corpus with the same seed, fast-forwarded via the
        // snapshot, continues the exact same stream.
        let mut b = SyntheticCorpus::new(CorpusProfile::NemotronHLike, 256, 13);
        b.set_state(&snap);
        let mut rest_b = vec![0i32; 512];
        b.fill(&mut rest_b);
        assert_eq!(rest, rest_b);
        // And the snapshot round-trips through itself.
        assert_eq!(b.state(), a.state());
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, 256, 1);
        let mut b = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, 256, 2);
        let mut x = vec![0i32; 256];
        let mut y = vec![0i32; 256];
        a.fill(&mut x);
        b.fill(&mut y);
        assert_ne!(x, y);
    }
}
