//! The out-of-distribution eval-task suite — the testbed substitute for
//! the paper's downstream benchmarks (MMLU, PIQA, HellaSwag, ...).
//!
//! Each task is a synthetic sequence *grammar* different from the
//! training distribution; a model that merely memorizes the training
//! Markov statistics scores poorly, while one that learned general
//! sequence structure transfers. This reproduces the signal the paper
//! uses downstream scores for: detecting generalization gaps that
//! training/validation loss miss (the Three-Way overfitting finding,
//! §4.2).
//!
//! Scoring = next-token accuracy on the *predictable* positions of each
//! grammar (like-for-like with multiple-choice accuracy: chance level is
//! low, task knowledge lifts it).

use crate::util::rng::Rng;

/// A synthetic eval task: generates (sequence, scored-position mask).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalTask {
    /// `copy`: random prefix, delimiter, then the prefix repeated.
    /// Scores the repeated half.
    Copy,
    /// `cycle`: a short motif tiled to the sequence length; scores all
    /// positions after the first period.
    Cycle,
    /// `sorted`: monotonically non-decreasing byte runs; scores
    /// within-run positions.
    SortedRuns,
    /// `arith`: arithmetic byte progressions (x, x+d, x+2d, ...);
    /// scores positions ≥ 2.
    Arithmetic,
    /// `heldout`: held-out stream from the training distribution
    /// (the "validation-like" member of the suite).
    HeldOut,
}

impl EvalTask {
    pub const ALL: [EvalTask; 5] = [
        EvalTask::Copy,
        EvalTask::Cycle,
        EvalTask::SortedRuns,
        EvalTask::Arithmetic,
        EvalTask::HeldOut,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EvalTask::Copy => "copy",
            EvalTask::Cycle => "cycle",
            EvalTask::SortedRuns => "sorted",
            EvalTask::Arithmetic => "arith",
            EvalTask::HeldOut => "heldout",
        }
    }

    /// Generate one example: tokens (len `seq`) and a 0/1 mask marking
    /// the positions whose *next-token* prediction is scored.
    pub fn generate(self, seq: usize, vocab: usize, rng: &mut Rng) -> (Vec<i32>, Vec<f32>) {
        let mut toks = vec![0i32; seq];
        let mut mask = vec![0f32; seq];
        match self {
            EvalTask::Copy => {
                let half = seq / 2;
                for i in 0..half {
                    toks[i] = rng.usize_in(0, vocab - 1) as i32;
                }
                for i in half..seq {
                    toks[i] = toks[i - half];
                    // Predicting toks[i] from position i-1: score it.
                    if i > half {
                        mask[i - 1] = 1.0;
                    }
                }
            }
            EvalTask::Cycle => {
                let period = rng.usize_in(2, 8);
                let motif: Vec<i32> =
                    (0..period).map(|_| rng.usize_in(0, vocab - 1) as i32).collect();
                for i in 0..seq {
                    toks[i] = motif[i % period];
                    if i >= period && i + 1 < seq {
                        mask[i] = 1.0; // next token is determined
                    }
                }
            }
            EvalTask::SortedRuns => {
                let mut i = 0;
                while i < seq {
                    let run = rng.usize_in(4, 12).min(seq - i);
                    let start = rng.usize_in(0, vocab.saturating_sub(run * 2).max(1) - 1);
                    for j in 0..run {
                        toks[i + j] = ((start + j) % vocab) as i32;
                        // Within a run the successor is start+j+1: score
                        // interior positions.
                        if j >= 1 && j + 1 < run {
                            mask[i + j] = 1.0;
                        }
                    }
                    i += run;
                }
            }
            EvalTask::Arithmetic => {
                let mut i = 0;
                while i < seq {
                    let run = rng.usize_in(4, 10).min(seq - i);
                    let start = rng.usize_in(0, vocab - 1);
                    let d = rng.usize_in(1, 5);
                    for j in 0..run {
                        toks[i + j] = ((start + j * d) % vocab) as i32;
                        if j >= 2 && j + 1 < run {
                            mask[i + j] = 1.0;
                        }
                    }
                    i += run;
                }
            }
            EvalTask::HeldOut => {
                // Filled by the caller from a held-out corpus stream; here
                // produce a uniform stream as placeholder and score all.
                for t in toks.iter_mut() {
                    *t = rng.usize_in(0, vocab - 1) as i32;
                }
                for m in mask[..seq - 1].iter_mut() {
                    *m = 1.0;
                }
            }
        }
        (toks, mask)
    }
}

/// A fixed eval suite: deterministic examples per task, so scores are
/// comparable across checkpoints and recipes.
pub struct EvalSuite {
    pub seq: usize,
    pub vocab: usize,
    pub examples_per_task: usize,
    pub seed: u64,
}

impl EvalSuite {
    pub fn new(seq: usize, vocab: usize, examples_per_task: usize, seed: u64) -> Self {
        EvalSuite { seq, vocab, examples_per_task, seed }
    }

    /// Materialize all examples for a task.
    pub fn examples(&self, task: EvalTask) -> Vec<(Vec<i32>, Vec<f32>)> {
        let mut rng = Rng::new(self.seed ^ (task as u64).wrapping_mul(0xABCD_EF01));
        (0..self.examples_per_task).map(|_| task.generate(self.seq, self.vocab, &mut rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_task_is_predictable() {
        let mut rng = Rng::new(1);
        let (toks, mask) = EvalTask::Copy.generate(64, 256, &mut rng);
        let half = 32;
        for i in half..64 {
            assert_eq!(toks[i], toks[i - half]);
        }
        // Masked positions exist and every masked position's next token
        // is determined by the prefix.
        assert!(mask.iter().sum::<f32>() > 0.0);
        for i in 0..63 {
            if mask[i] == 1.0 {
                assert_eq!(toks[i + 1], toks[i + 1 - half]);
            }
        }
    }

    #[test]
    fn cycle_task_periodicity() {
        let mut rng = Rng::new(2);
        let (toks, mask) = EvalTask::Cycle.generate(64, 256, &mut rng);
        assert!(mask.iter().sum::<f32>() > 10.0);
        // Find the period by matching the motif.
        for p in 2..=8 {
            if (0..64 - p).all(|i| toks[i] == toks[i + p]) {
                return; // periodic as claimed
            }
        }
        panic!("no period found");
    }

    #[test]
    fn masked_positions_in_range() {
        let mut rng = Rng::new(3);
        for task in EvalTask::ALL {
            let (toks, mask) = task.generate(48, 256, &mut rng);
            assert_eq!(toks.len(), 48);
            assert_eq!(mask.len(), 48);
            assert!(toks.iter().all(|t| (0..256).contains(t)));
            assert!(mask.iter().all(|m| *m == 0.0 || *m == 1.0));
            // Last position never scored (no next token).
            assert_eq!(mask[47], 0.0);
        }
    }

    #[test]
    fn suite_is_deterministic() {
        let s = EvalSuite::new(32, 256, 4, 99);
        let a = s.examples(EvalTask::Arithmetic);
        let b = s.examples(EvalTask::Arithmetic);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
    }
}
