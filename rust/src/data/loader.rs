//! Batch loader: turns a [`SyntheticCorpus`] stream into fixed-shape
//! token batches for the train step, with a held-out validation split
//! (disjoint seed stream), double-buffered prefetch on a std thread,
//! and a checkpointable cursor.
//!
//! Every delivered batch is tagged with the corpus state *after* it was
//! generated, so [`BatchLoader::cursor`] always describes the position
//! of the last consumed batch — independent of how far the prefetch
//! thread has run ahead. [`BatchLoader::resume`] reopens the stream at
//! such a cursor bitwise: the next batch it yields is exactly the batch
//! the original loader would have yielded next.

use super::synthetic::{CorpusProfile, CorpusState, SyntheticCorpus};
use std::cell::RefCell;
use std::sync::mpsc;

/// One batch of token ids, shape `[batch, seq]` flattened row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// The checkpointable position of a [`BatchLoader`]: the corpus state
/// after the last consumed batch plus the number of batches consumed so
/// far (a telemetry counter; the state alone determines the stream).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoaderCursor {
    pub state: CorpusState,
    pub batches: u64,
}

/// Streaming batch producer with background prefetch.
pub struct BatchLoader {
    rx: mpsc::Receiver<(Batch, CorpusState)>,
    _handle: std::thread::JoinHandle<()>,
    pub batch: usize,
    pub seq: usize,
    /// Position of the last consumed batch (interior-mutable so the
    /// blocking `next_batch(&self)` API stays unchanged; the loader is
    /// single-consumer by construction).
    cursor: RefCell<LoaderCursor>,
}

impl BatchLoader {
    /// `split_seed_offset` separates train (0) from validation (1)
    /// streams deterministically.
    pub fn new(
        profile: CorpusProfile,
        vocab: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        split_seed_offset: u64,
    ) -> Self {
        let corpus =
            SyntheticCorpus::new(profile, vocab, seed.wrapping_add(split_seed_offset * 0x5eed));
        Self::spawn(corpus, batch, seq, 0)
    }

    /// Reopen a stream at a checkpointed [`LoaderCursor`]. The
    /// (profile, vocab, seed, split) quadruple must match the loader
    /// the cursor was taken from — the cursor carries only the dynamic
    /// stream state, not the seed-derived pattern dictionary.
    pub fn resume(
        profile: CorpusProfile,
        vocab: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        split_seed_offset: u64,
        cursor: &LoaderCursor,
    ) -> Self {
        let mut corpus =
            SyntheticCorpus::new(profile, vocab, seed.wrapping_add(split_seed_offset * 0x5eed));
        corpus.set_state(&cursor.state);
        Self::spawn(corpus, batch, seq, cursor.batches)
    }

    fn spawn(mut corpus: SyntheticCorpus, batch: usize, seq: usize, batches: u64) -> Self {
        let start = LoaderCursor { state: corpus.state(), batches };
        let (tx, rx) = mpsc::sync_channel::<(Batch, CorpusState)>(4); // shallow prefetch queue
        let handle = std::thread::spawn(move || loop {
            let mut tokens = vec![0i32; batch * seq];
            corpus.fill(&mut tokens);
            let state = corpus.state();
            if tx.send((Batch { tokens, batch, seq }, state)).is_err() {
                return; // consumer dropped
            }
        });
        BatchLoader { rx, _handle: handle, batch, seq, cursor: RefCell::new(start) }
    }

    /// Blocking fetch of the next batch; advances the cursor.
    pub fn next_batch(&self) -> Batch {
        let (b, state) = self.rx.recv().expect("loader thread died");
        let mut cur = self.cursor.borrow_mut();
        cur.batches += 1;
        cur.state = state;
        b
    }

    /// The position of the last consumed batch (the data-loader section
    /// of a training checkpoint).
    pub fn cursor(&self) -> LoaderCursor {
        self.cursor.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_shape_and_content() {
        let l = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 4, 16, 42, 0);
        let b = l.next_batch();
        assert_eq!(b.tokens.len(), 64);
        assert!(b.tokens.iter().all(|t| (0..256).contains(t)));
    }

    #[test]
    fn train_and_val_streams_differ() {
        let tr = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 2, 32, 42, 0);
        let va = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 2, 32, 42, 1);
        assert_ne!(tr.next_batch(), va.next_batch());
    }

    #[test]
    fn same_seed_reproduces() {
        let a = BatchLoader::new(CorpusProfile::NemotronHLike, 256, 2, 16, 7, 0);
        let b = BatchLoader::new(CorpusProfile::NemotronHLike, 256, 2, 16, 7, 0);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn cursor_resume_continues_stream_bitwise() {
        let a = BatchLoader::new(CorpusProfile::NemotronHLike, 256, 3, 17, 99, 0);
        for _ in 0..5 {
            a.next_batch();
        }
        let cur = a.cursor();
        assert_eq!(cur.batches, 5);
        // Resumed loader yields exactly the batches the original yields
        // next — regardless of how far `a`'s prefetch thread ran ahead.
        let b = BatchLoader::resume(CorpusProfile::NemotronHLike, 256, 3, 17, 99, 0, &cur);
        for _ in 0..4 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
        assert_eq!(b.cursor().batches, 9);
        assert_eq!(a.cursor(), b.cursor());
    }

    #[test]
    fn fresh_cursor_is_stream_origin() {
        let a = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 2, 8, 5, 0);
        let cur = a.cursor();
        assert_eq!(cur.batches, 0);
        // Resuming at the origin replays the stream from the start.
        let b = BatchLoader::resume(CorpusProfile::Nemotron4Like, 256, 2, 8, 5, 0, &cur);
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
