//! Batch loader: turns a [`SyntheticCorpus`] stream into fixed-shape
//! token batches for the train step, with a held-out validation split
//! (disjoint seed stream) and double-buffered prefetch on a std thread.

use super::synthetic::{CorpusProfile, SyntheticCorpus};
use std::sync::mpsc;

/// One batch of token ids, shape `[batch, seq]` flattened row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Streaming batch producer with background prefetch.
pub struct BatchLoader {
    rx: mpsc::Receiver<Batch>,
    _handle: std::thread::JoinHandle<()>,
    pub batch: usize,
    pub seq: usize,
}

impl BatchLoader {
    /// `split_seed_offset` separates train (0) from validation (1)
    /// streams deterministically.
    pub fn new(
        profile: CorpusProfile,
        vocab: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        split_seed_offset: u64,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Batch>(4); // shallow prefetch queue
        let handle = std::thread::spawn(move || {
            let mut corpus =
                SyntheticCorpus::new(profile, vocab, seed.wrapping_add(split_seed_offset * 0x5eed));
            loop {
                let mut tokens = vec![0i32; batch * seq];
                corpus.fill(&mut tokens);
                if tx.send(Batch { tokens, batch, seq }).is_err() {
                    return; // consumer dropped
                }
            }
        });
        BatchLoader { rx, _handle: handle, batch, seq }
    }

    /// Blocking fetch of the next batch.
    pub fn next_batch(&self) -> Batch {
        self.rx.recv().expect("loader thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_shape_and_content() {
        let l = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 4, 16, 42, 0);
        let b = l.next_batch();
        assert_eq!(b.tokens.len(), 64);
        assert!(b.tokens.iter().all(|t| (0..256).contains(t)));
    }

    #[test]
    fn train_and_val_streams_differ() {
        let tr = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 2, 32, 42, 0);
        let va = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, 2, 32, 42, 1);
        assert_ne!(tr.next_batch(), va.next_batch());
    }

    #[test]
    fn same_seed_reproduces() {
        let a = BatchLoader::new(CorpusProfile::NemotronHLike, 256, 2, 16, 7, 0);
        let b = BatchLoader::new(CorpusProfile::NemotronHLike, 256, 2, 16, 7, 0);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
