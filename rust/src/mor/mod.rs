//! The Mixture-of-Representations framework (§3) — the paper's core
//! contribution — plus the pluggable decision-policy layer
//! ([`policy`]), the concrete recipes evaluated in §4, and the
//! statistics machinery behind Figures 10–19.

pub mod framework;
pub mod policy;
pub mod recipes;
pub mod stats;

pub use framework::{MorFramework, MorOutcome};
pub use policy::{
    BlockChoice, BlockProps, DecisionCtx, DecisionPolicy, MetricDrivenPolicy, MorThresholdPolicy,
    PolicyRef, StaticAssignmentPolicy, TensorClass, TensorScope,
};
pub use recipes::{Recipe, RecipeKind, SubTensorMode};
pub use stats::{Histogram, StatsCollector, TensorKey, HIST_BINS};
