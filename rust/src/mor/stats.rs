//! The statistics machinery behind §4.1.3: per-tensor relative-error
//! histograms (Figures 11–19), BF16 fallback percentages (Figure 10),
//! and the heatmap CSV/ASCII renderers.
//!
//! Binning follows the paper exactly: each bin covers 0.5% of relative
//! error; the first bin is `< 0.5%`, the last is `>= 5.5%`. One
//! mini-batch contributes one count per tensor; rows are normalized to
//! [0,1] when rendered; histograms reset every `reset_every` steps so
//! drift over training is visible (Figure 14).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Number of histogram bins (11 half-percent bins + overflow bin).
pub const HIST_BINS: usize = 12;

/// A relative-error histogram with the paper's 0.5%-wide bins.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    pub counts: [u64; HIST_BINS],
}

impl Histogram {
    /// Bin index for a relative error value (fraction, not percent).
    pub fn bin_of(relerr: f64) -> usize {
        let pct = relerr * 100.0;
        if pct < 0.0 {
            0
        } else {
            ((pct / 0.5) as usize).min(HIST_BINS - 1)
        }
    }

    pub fn add(&mut self, relerr: f64) {
        self.counts[Self::bin_of(relerr)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Row-normalized counts (0 if empty).
    pub fn normalized(&self) -> [f64; HIST_BINS] {
        let t = self.total();
        let mut out = [0.0; HIST_BINS];
        if t > 0 {
            for (o, c) in out.iter_mut().zip(self.counts.iter()) {
                *o = *c as f64 / t as f64;
            }
        }
        out
    }

    /// Mass at or above a threshold (fraction in bins right of the
    /// `th` percent line) — the "to the right of the blue line" share.
    pub fn mass_above(&self, th_pct: f64) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        let cut = ((th_pct / 0.5).round() as usize).min(HIST_BINS);
        self.counts[cut..].iter().sum::<u64>() as f64 / t as f64
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }
}

/// Canonical tensor identity in the heatmaps' y-axis naming scheme:
/// `decoder.layer.{layer}.{module}.{linear}.{tensor}[.{direction}]`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TensorKey {
    pub layer: usize,
    /// "self_attention" or "mlp".
    pub module: &'static str,
    /// "linear_qkv", "linear_proj", "fc1", "fc2".
    pub linear: &'static str,
    /// "input", "weight", "grad".
    pub tensor: &'static str,
    /// Partition direction for per-channel stats: "row" or "col"
    /// (empty for direction-agnostic partitions).
    pub direction: &'static str,
}

impl TensorKey {
    pub fn new(
        layer: usize,
        linear_index: usize,
        tensor: &'static str,
        direction: &'static str,
    ) -> TensorKey {
        // Linear index convention shared with the artifact ABI:
        // 0 = linear_qkv, 1 = linear_proj, 2 = fc1, 3 = fc2.
        let (module, linear) = match linear_index {
            0 => ("self_attention", "linear_qkv"),
            1 => ("self_attention", "linear_proj"),
            2 => ("mlp", "fc1"),
            3 => ("mlp", "fc2"),
            _ => panic!("linear index out of range: {linear_index}"),
        };
        TensorKey { layer, module, linear, tensor, direction }
    }

    /// Compact integer identity `(layer, linear, tensor, direction)` —
    /// the checkpoint encoding of a key (the string fields are all
    /// `'static` vocabulary, so indices round-trip losslessly).
    pub fn codes(&self) -> (u32, u8, u8, u8) {
        let linear = match self.linear {
            "linear_qkv" => 0u8,
            "linear_proj" => 1,
            "fc1" => 2,
            "fc2" => 3,
            other => panic!("unknown linear {other:?}"),
        };
        let tensor = match self.tensor {
            "input" => 0u8,
            "weight" => 1,
            "grad" => 2,
            other => panic!("unknown tensor {other:?}"),
        };
        let direction = match self.direction {
            "" => 0u8,
            "row" => 1,
            "col" => 2,
            other => panic!("unknown direction {other:?}"),
        };
        (self.layer as u32, linear, tensor, direction)
    }

    /// Inverse of [`TensorKey::codes`]; `None` on out-of-vocabulary
    /// indices (corrupt checkpoint).
    pub fn from_codes(layer: u32, linear: u8, tensor: u8, direction: u8) -> Option<TensorKey> {
        if linear > 3 {
            return None;
        }
        let tensor = match tensor {
            0 => "input",
            1 => "weight",
            2 => "grad",
            _ => return None,
        };
        let direction = match direction {
            0 => "",
            1 => "row",
            2 => "col",
            _ => return None,
        };
        Some(TensorKey::new(layer as usize, linear as usize, tensor, direction))
    }

    pub fn name(&self) -> String {
        if self.direction.is_empty() {
            format!(
                "decoder.layer.{}.{}.{}.{}",
                self.layer, self.module, self.linear, self.tensor
            )
        } else {
            format!(
                "decoder.layer.{}.{}.{}.{}.{}",
                self.layer, self.module, self.linear, self.tensor, self.direction
            )
        }
    }
}

/// One window's worth of stats for one tensor.
#[derive(Debug, Clone, Default)]
pub struct TensorWindow {
    pub hist: Histogram,
    /// Mini-batches where the tensor (or a block share) fell back.
    pub fallback_count: u64,
    /// Mini-batches observed.
    pub steps: u64,
    /// Mean fraction of elements left in BF16 (sub-tensor recipes).
    pub bf16_fraction_sum: f64,
}

impl TensorWindow {
    pub fn record(&mut self, relerr: f64, fell_back: bool, bf16_fraction: f64) {
        self.hist.add(relerr);
        self.fallback_count += fell_back as u64;
        self.steps += 1;
        self.bf16_fraction_sum += bf16_fraction;
    }

    pub fn fallback_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.fallback_count as f64 / self.steps as f64
        }
    }

    pub fn mean_bf16_fraction(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.bf16_fraction_sum / self.steps as f64
        }
    }
}

/// Collector for a whole training run: (window, tensor) → stats, with
/// periodic histogram resets (Figure 14's y-axis is the window index).
#[derive(Debug, Clone)]
pub struct StatsCollector {
    pub reset_every: u64,
    windows: BTreeMap<(u64, TensorKey), TensorWindow>,
    /// Running totals across the entire run (Figure 10's aggregate).
    totals: BTreeMap<TensorKey, TensorWindow>,
    step: u64,
}

impl StatsCollector {
    pub fn new(reset_every: u64) -> Self {
        StatsCollector {
            reset_every: reset_every.max(1),
            windows: BTreeMap::new(),
            totals: BTreeMap::new(),
            step: 0,
        }
    }

    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// The step the collector is currently recording at.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Every `(window, key) → stats` entry, in BTreeMap (canonical)
    /// order — the checkpointable body of the collector.
    pub fn window_entries(&self) -> impl Iterator<Item = (&(u64, TensorKey), &TensorWindow)> {
        self.windows.iter()
    }

    /// Every `key → running-total` entry, in canonical order.
    pub fn total_entries(&self) -> impl Iterator<Item = (&TensorKey, &TensorWindow)> {
        self.totals.iter()
    }

    /// Rebuild a collector from checkpointed entries — the exact
    /// inverse of iterating `window_entries`/`total_entries`. A
    /// restored collector continues recording as if it had never
    /// stopped: same windows, same totals, same aggregate percentages.
    pub fn restore(
        reset_every: u64,
        step: u64,
        windows: Vec<((u64, TensorKey), TensorWindow)>,
        totals: Vec<(TensorKey, TensorWindow)>,
    ) -> StatsCollector {
        StatsCollector {
            reset_every: reset_every.max(1),
            windows: windows.into_iter().collect(),
            totals: totals.into_iter().collect(),
            step,
        }
    }

    pub fn window_of(&self, step: u64) -> u64 {
        step / self.reset_every
    }

    /// Record one tensor's decision for the current step.
    pub fn record(&mut self, key: TensorKey, relerr: f64, fell_back: bool, bf16_fraction: f64) {
        let w = self.window_of(self.step);
        self.windows
            .entry((w, key.clone()))
            .or_default()
            .record(relerr, fell_back, bf16_fraction);
        self.totals.entry(key).or_default().record(relerr, fell_back, bf16_fraction);
    }

    /// Aggregate BF16 fallback percentage over every recorded tensor
    /// (Figure 10's headline number, e.g. 1.62% for per-channel cfg 1).
    pub fn overall_fallback_pct(&self) -> f64 {
        let (mut fb, mut n) = (0u64, 0u64);
        for w in self.totals.values() {
            fb += w.fallback_count;
            n += w.steps;
        }
        if n == 0 {
            0.0
        } else {
            fb as f64 / n as f64 * 100.0
        }
    }

    /// Mean BF16 element share (sub-tensor recipes' efficiency number).
    pub fn overall_bf16_element_pct(&self) -> f64 {
        let (mut s, mut n) = (0.0f64, 0u64);
        for w in self.totals.values() {
            s += w.bf16_fraction_sum;
            n += w.steps;
        }
        if n == 0 {
            0.0
        } else {
            s / n as f64 * 100.0
        }
    }

    pub fn tensors(&self) -> Vec<&TensorKey> {
        self.totals.keys().collect()
    }

    pub fn total_for(&self, key: &TensorKey) -> Option<&TensorWindow> {
        self.totals.get(key)
    }

    pub fn window_for(&self, window: u64, key: &TensorKey) -> Option<&TensorWindow> {
        self.windows.get(&(window, key.clone()))
    }

    pub fn num_windows(&self) -> u64 {
        self.windows.keys().map(|(w, _)| *w + 1).max().unwrap_or(0)
    }

    /// Heatmap CSV: one row per (window, tensor), normalized bins —
    /// the raw data behind Figures 11–19.
    pub fn heatmap_csv(&self) -> String {
        let mut s = String::from("window,tensor,steps,fallback_rate");
        for b in 0..HIST_BINS {
            let lo = b as f64 * 0.5;
            if b == HIST_BINS - 1 {
                let _ = write!(s, ",bin_ge{lo:.1}pct");
            } else {
                let _ = write!(s, ",bin_{lo:.1}pct");
            }
        }
        s.push('\n');
        for ((w, key), win) in &self.windows {
            let _ = write!(s, "{w},{},{},{:.6}", key.name(), win.steps, win.fallback_rate());
            for v in win.hist.normalized() {
                let _ = write!(s, ",{v:.6}");
            }
            s.push('\n');
        }
        s
    }

    /// ASCII heatmap for a set of tensors in the final window — the
    /// terminal rendering of a Figure 12/13-style panel. The blue
    /// threshold line is drawn as `|` at `th_pct`.
    pub fn ascii_heatmap(&self, keys: &[TensorKey], th_pct: f64) -> String {
        const SHADES: [char; 9] = [' ', '.', ':', '-', '=', '+', '*', '#', '@'];
        let last = self.num_windows().saturating_sub(1);
        let cut = (th_pct / 0.5).round() as usize;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<56} |{}|  (bins of 0.5% relerr; '|' = {th_pct}% threshold)",
            "tensor", "0.0 ──────────────▶ ≥5.5%"
        );
        for key in keys {
            let win = self
                .window_for(last, key)
                .cloned()
                .or_else(|| self.totals.get(key).cloned())
                .unwrap_or_default();
            let norm = win.hist.normalized();
            let mut row = String::new();
            for (b, v) in norm.iter().enumerate() {
                if b == cut {
                    row.push('|');
                }
                let shade = SHADES[((v * (SHADES.len() - 1) as f64).ceil() as usize)
                    .min(SHADES.len() - 1)];
                row.push(shade);
                row.push(shade);
            }
            let fb = win.fallback_rate() * 100.0;
            let _ = writeln!(out, "{:<56} {}  fb={fb:5.1}%", key.name(), row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_edges_match_paper() {
        assert_eq!(Histogram::bin_of(0.0), 0);
        assert_eq!(Histogram::bin_of(0.004999), 0); // < 0.5%
        assert_eq!(Histogram::bin_of(0.005), 1); // [0.5, 1.0)
        assert_eq!(Histogram::bin_of(0.0449), 8);
        assert_eq!(Histogram::bin_of(0.045), 9); // the threshold bin
        assert_eq!(Histogram::bin_of(0.055), 11); // >= 5.5% overflow
        assert_eq!(Histogram::bin_of(5.0), 11);
    }

    #[test]
    fn mass_above_threshold() {
        let mut h = Histogram::default();
        h.add(0.01); // bin 2
        h.add(0.05); // bin 10
        h.add(0.06); // bin 11
        h.add(0.002); // bin 0
        assert_eq!(h.total(), 4);
        assert_eq!(h.mass_above(4.5), 0.5);
        assert_eq!(h.mass_above(0.0), 1.0);
    }

    #[test]
    fn normalized_sums_to_one() {
        let mut h = Histogram::default();
        for i in 0..100 {
            h.add(i as f64 * 0.0007);
        }
        let n = h.normalized();
        assert!((n.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tensor_key_naming() {
        let k = TensorKey::new(3, 3, "input", "");
        assert_eq!(k.name(), "decoder.layer.3.mlp.fc2.input");
        let k = TensorKey::new(0, 0, "grad", "row");
        assert_eq!(k.name(), "decoder.layer.0.self_attention.linear_qkv.grad.row");
    }

    #[test]
    fn windows_reset() {
        let mut c = StatsCollector::new(10);
        let key = TensorKey::new(0, 2, "weight", "");
        c.set_step(5);
        c.record(key.clone(), 0.01, false, 0.0);
        c.set_step(15);
        c.record(key.clone(), 0.06, true, 1.0);
        assert_eq!(c.num_windows(), 2);
        assert_eq!(c.window_for(0, &key).unwrap().hist.total(), 1);
        assert_eq!(c.window_for(1, &key).unwrap().fallback_count, 1);
        assert_eq!(c.total_for(&key).unwrap().steps, 2);
        assert_eq!(c.overall_fallback_pct(), 50.0);
        assert_eq!(c.overall_bf16_element_pct(), 50.0);
    }

    #[test]
    fn key_codes_roundtrip() {
        for layer in [0usize, 3, 11] {
            for linear in 0..4usize {
                for tensor in ["input", "weight", "grad"] {
                    for dir in ["", "row", "col"] {
                        let k = TensorKey::new(layer, linear, tensor, dir);
                        let (l, li, t, d) = k.codes();
                        assert_eq!(TensorKey::from_codes(l, li, t, d), Some(k));
                    }
                }
            }
        }
        assert_eq!(TensorKey::from_codes(0, 4, 0, 0), None);
        assert_eq!(TensorKey::from_codes(0, 0, 3, 0), None);
        assert_eq!(TensorKey::from_codes(0, 0, 0, 3), None);
    }

    #[test]
    fn restore_rebuilds_collector_exactly() {
        let mut c = StatsCollector::new(10);
        let k1 = TensorKey::new(0, 1, "weight", "");
        let k2 = TensorKey::new(1, 2, "grad", "row");
        for i in 0..25u64 {
            c.set_step(i);
            c.record(k1.clone(), 0.001 * i as f64, i % 5 == 0, 0.1);
            c.record(k2.clone(), 0.06, true, 1.0);
        }
        let back = StatsCollector::restore(
            c.reset_every,
            c.step(),
            c.window_entries().map(|(k, w)| (k.clone(), w.clone())).collect(),
            c.total_entries().map(|(k, w)| (k.clone(), w.clone())).collect(),
        );
        assert_eq!(back.step(), c.step());
        assert_eq!(back.heatmap_csv(), c.heatmap_csv());
        assert_eq!(back.overall_fallback_pct(), c.overall_fallback_pct());
        assert_eq!(back.overall_bf16_element_pct(), c.overall_bf16_element_pct());
        assert_eq!(back.num_windows(), c.num_windows());
    }

    #[test]
    fn csv_and_ascii_render() {
        let mut c = StatsCollector::new(100);
        let key = TensorKey::new(1, 3, "input", "");
        for i in 0..50 {
            c.set_step(i);
            c.record(key.clone(), 0.002 * (i % 30) as f64, i % 30 >= 23, 0.0);
        }
        let csv = c.heatmap_csv();
        assert!(csv.starts_with("window,tensor,steps,fallback_rate,bin_0.0pct"));
        assert!(csv.contains("decoder.layer.1.mlp.fc2.input"));
        let art = c.ascii_heatmap(&[key], 4.5);
        assert!(art.contains("decoder.layer.1.mlp.fc2.input"));
        assert!(art.contains('|'));
    }
}
