//! Algorithm 2, verbatim: per block, walk an ordered list of types from
//! most to least aggressive; accept the first whose metric passes; the
//! final type is the unconditional fallback (BF16, "leave the block in
//! its original precision").
//!
//! The engine is generic over the metric: recipes plug in Eq. (2)
//! (tensor-level threshold), Eq. (3) (two-way / three-way M1) and
//! Eq. (4) (M2 range check). Keeping the walk generic means new type
//! lists — e.g. `[NVFP4, E4M3, BF16]` — reuse the identical decision
//! logic, which is how the paper frames future work.

use crate::formats::ReprType;

/// An ordered list of candidate representations, most aggressive first.
/// The last entry is the fallback and needs no metric.
#[derive(Debug, Clone)]
pub struct MorFramework {
    types: Vec<ReprType>,
}

impl MorFramework {
    /// Build a framework; panics on an empty list (there must always be
    /// a fallback type).
    pub fn new(types: Vec<ReprType>) -> Self {
        assert!(!types.is_empty(), "MoR type list cannot be empty");
        MorFramework { types }
    }

    /// The paper's tensor-level list.
    pub fn e4m3_bf16() -> Self {
        Self::new(vec![ReprType::E4M3, ReprType::Bf16])
    }

    /// The paper's three-way sub-tensor list.
    pub fn e4m3_e5m2_bf16() -> Self {
        Self::new(vec![ReprType::E4M3, ReprType::E5M2, ReprType::Bf16])
    }

    pub fn types(&self) -> &[ReprType] {
        &self.types
    }

    pub fn fallback(&self) -> ReprType {
        *self.types.last().unwrap()
    }

    /// Algorithm 2 for one block: `accept(type, block_index)` answers the
    /// metric question `M_t(b, A)`; the first accepted type wins, else
    /// the fallback.
    pub fn select_block<F: FnMut(ReprType, usize) -> bool>(
        &self,
        block: usize,
        mut accept: F,
    ) -> ReprType {
        for &t in &self.types[..self.types.len() - 1] {
            if accept(t, block) {
                return t;
            }
        }
        self.fallback()
    }

    /// Run the walk for every block of a partition.
    pub fn select_all<F: FnMut(ReprType, usize) -> bool>(
        &self,
        num_blocks: usize,
        mut accept: F,
    ) -> Vec<ReprType> {
        (0..num_blocks).map(|b| self.select_block(b, &mut accept)).collect()
    }
}

/// The outcome of applying a MoR recipe to one tensor.
#[derive(Debug, Clone)]
pub struct MorOutcome {
    /// Fake-quantized tensor, blocks mixed per `block_types`.
    pub out: crate::tensor::Tensor,
    /// Chosen representation per partition block.
    pub block_types: Vec<ReprType>,
    /// Global mean relative error of the *candidate* E4M3 quantization
    /// (the number the paper's histograms bin, whether or not E4M3 won).
    pub e4m3_relerr: f64,
    /// Fraction of elements left in BF16.
    pub bf16_fraction: f64,
    /// Scale metadata bits spent (GAM accounting, §2).
    pub metadata_bits: u64,
}

impl MorOutcome {
    /// Whether the entire tensor fell back to BF16.
    pub fn full_fallback(&self) -> bool {
        self.block_types.iter().all(|t| *t == ReprType::Bf16)
    }

    /// Fraction of blocks per chosen type, ordered [e4m3, e5m2, bf16, nvfp4].
    pub fn type_fractions(&self) -> [f64; 4] {
        let mut counts = [0usize; 4];
        for t in &self.block_types {
            let i = match t {
                ReprType::E4M3 => 0,
                ReprType::E5M2 => 1,
                ReprType::Bf16 => 2,
                ReprType::NvFp4 => 3,
            };
            counts[i] += 1;
        }
        let n = self.block_types.len().max(1) as f64;
        [counts[0] as f64 / n, counts[1] as f64 / n, counts[2] as f64 / n, counts[3] as f64 / n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_accepted_type_wins() {
        let fw = MorFramework::e4m3_e5m2_bf16();
        assert_eq!(fw.select_block(0, |t, _| t == ReprType::E4M3), ReprType::E4M3);
        assert_eq!(fw.select_block(0, |t, _| t == ReprType::E5M2), ReprType::E5M2);
        assert_eq!(fw.select_block(0, |_, _| false), ReprType::Bf16);
    }

    #[test]
    fn fallback_never_queried() {
        let fw = MorFramework::e4m3_bf16();
        let mut asked = Vec::new();
        fw.select_block(3, |t, b| {
            asked.push((t, b));
            false
        });
        assert_eq!(asked, vec![(ReprType::E4M3, 3)]);
    }

    #[test]
    fn select_all_is_per_block() {
        let fw = MorFramework::e4m3_bf16();
        let types = fw.select_all(4, |_, b| b % 2 == 0);
        assert_eq!(
            types,
            vec![ReprType::E4M3, ReprType::Bf16, ReprType::E4M3, ReprType::Bf16]
        );
    }

    #[test]
    #[should_panic(expected = "cannot be empty")]
    fn empty_list_panics() {
        MorFramework::new(vec![]);
    }

    #[test]
    fn type_fractions_sum_to_one() {
        let o = MorOutcome {
            out: crate::tensor::Tensor::zeros(&[1, 1]),
            block_types: vec![ReprType::E4M3, ReprType::E4M3, ReprType::Bf16, ReprType::E5M2],
            e4m3_relerr: 0.0,
            bf16_fraction: 0.25,
            metadata_bits: 0,
        };
        let f = o.type_fractions();
        assert_eq!(f[0], 0.5);
        assert_eq!(f[1], 0.25);
        assert_eq!(f[2], 0.25);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(!o.full_fallback());
    }
}
