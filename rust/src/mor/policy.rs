//! Pluggable precision-assignment policies: the decision layer of the
//! MoR engine, extracted behind the [`DecisionPolicy`] trait.
//!
//! MoR's dynamic, property-aware representation choice is one point in
//! a design space. This module makes the choice a first-class,
//! swappable component: a policy observes per-tensor / per-block
//! properties (candidate relative errors, amax dynamic range) plus the
//! tensor's identity and step context, and answers the two questions
//! the quantization paths ask —
//!
//! * **tensor level**: "may this whole tensor be stored in `format`?"
//!   ([`DecisionPolicy::accept_tensor`]);
//! * **sub-tensor level**: "which representation does this block get?"
//!   ([`DecisionPolicy::choose_block`]).
//!
//! Built-in policies:
//!
//! * [`MorThresholdPolicy`] — the paper's logic (Algorithm 2 metrics
//!   M1/M2 at block level, the relerr-threshold test at tensor level),
//!   **bitwise-identical** to the pre-trait decisions. The default.
//! * [`MetricDrivenPolicy`] — accepts any candidate whose measured
//!   relative error is within a single global budget, in the spirit of
//!   metric-driven mixed-precision selection (arXiv 2408.02897); it
//!   ignores the per-block M1/M2 comparisons in favor of the absolute
//!   budget.
//! * [`StaticAssignmentPolicy`] — a fixed per-tensor-class table
//!   (input/weight/grad), the classic static assignment baseline
//!   (arXiv 2301.13464): no runtime properties consulted at all.
//!
//! A policy flows through the stack exactly like
//! [`crate::util::par::Parallelism`]: process default ([`global`] /
//! [`set_global`], resolved from `MOR_POLICY` by [`auto`]), per-run
//! override (`TrainerOptions::policy`, `Runtime::with_policy`), and an
//! explicit parameter on the context-taking entry points
//! (`Recipe::apply_ctx`, `mor_quantize_plan_policy`). Checkpoints pin
//! the active policy ([`DecisionPolicy::pin`]) so a resume under a
//! different policy errors instead of silently diverging.

use crate::formats::ReprType;
use crate::quant::error::{dynamic_range_fits_e5m2, RelErrAccum};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// Shared, thread-safe handle to a policy — the unit that flows
/// through `TrainerOptions`, `Runtime` and the session API.
pub type PolicyRef = Arc<dyn DecisionPolicy>;

/// Which of the three quantized tensor roles a decision concerns.
/// Matches `model::naming::TENSOR_NAMES` order (`input`, `weight`,
/// `grad`) so `index()` doubles as the StepStats slot coordinate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TensorClass {
    /// Forward activations entering a linear.
    #[default]
    Input,
    /// Linear weights.
    Weight,
    /// Backward upstream gradients.
    Grad,
}

impl TensorClass {
    /// Slot in per-class tables; the `TENSOR_NAMES` index.
    pub fn index(self) -> usize {
        match self {
            TensorClass::Input => 0,
            TensorClass::Weight => 1,
            TensorClass::Grad => 2,
        }
    }

    /// Stable lowercase name (CSV logs, `static=` policy specs).
    pub fn name(self) -> &'static str {
        match self {
            TensorClass::Input => "input",
            TensorClass::Weight => "weight",
            TensorClass::Grad => "grad",
        }
    }
}

/// Identity and step context of one quantization decision. `Default`
/// gives the anonymous scope the no-context entry points
/// (`Recipe::apply`) use: a standalone input tensor at step 0.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecisionCtx {
    /// Tensor role (input / weight / grad).
    pub class: TensorClass,
    /// Transformer layer index (0 for standalone tensors).
    pub layer: usize,
    /// GEMM pass the quantization feeds: 0 = forward-layout operand,
    /// 1 = the transposed backward operand.
    pub direction: usize,
    /// Optimizer step (1-based inside training; 0 standalone).
    pub step: u64,
    /// Whether the recipe's type list offers E5M2 between E4M3 and the
    /// BF16 fallback (the three-way sub-tensor recipe).
    pub three_way: bool,
}

/// The per-tensor part of a [`DecisionCtx`]: everything that is known
/// before the direction/recipe details. The host trainer threads one
/// `TensorScope` per quantized tensor down to the plan builder.
#[derive(Debug, Clone, Copy, Default)]
pub struct TensorScope {
    pub class: TensorClass,
    pub layer: usize,
    pub step: u64,
}

impl TensorScope {
    pub fn new(class: TensorClass, layer: usize, step: u64) -> TensorScope {
        TensorScope { class, layer, step }
    }

    /// Complete the scope into a decision context.
    pub fn ctx(self, direction: usize, three_way: bool) -> DecisionCtx {
        DecisionCtx {
            class: self.class,
            layer: self.layer,
            direction,
            step: self.step,
            three_way,
        }
    }
}

/// Measured properties of one partition block, as produced by the
/// candidate fake-quantizations: the E4M3 and E5M2 error accumulators
/// and the block's `(amax, smallest nonzero |x|)` dynamic range.
#[derive(Debug, Clone, Copy)]
pub struct BlockProps<'a> {
    /// Relative-error accumulator of the E4M3 candidate (metric M1 lhs).
    pub e4m3_err: &'a RelErrAccum,
    /// Relative-error accumulator of the E5M2 candidate (metric M1 rhs).
    pub e5m2_err: &'a RelErrAccum,
    /// `(amax, min nonzero |x|)` of the block's source values (metric
    /// M2 input); `None` when the block is all zeros.
    pub range: (f32, Option<f32>),
}

/// A block-level verdict. `E5m2` is only honored by three-way recipes;
/// the quantization paths coerce it to `Fallback` otherwise, so a
/// policy never has to know which recipe is asking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockChoice {
    /// Store the block in FP8 E4M3.
    E4m3,
    /// Store the block in FP8 E5M2 (three-way recipes only).
    E5m2,
    /// Keep the block at input precision (BF16 fallback).
    Fallback,
}

/// A precision-assignment policy. Implementations must be pure
/// functions of their inputs and configuration — the bitwise
/// determinism contracts (parallel ≡ serial, resume ≡ continuous)
/// extend over the policy layer.
pub trait DecisionPolicy: Send + Sync + std::fmt::Debug {
    /// Canonical spec string: `parse_policy(describe()) == self`.
    fn describe(&self) -> String;

    /// Stable identity + configuration fingerprint, pinned into
    /// `MORCKPT2` checkpoints (`opt/policy`): resuming under a policy
    /// with a different pin is an error.
    fn pin(&self) -> u64;

    /// Tensor-level question: may the whole tensor be stored as
    /// `format`, given its measured mean relative error `relerr` and
    /// the run's configured threshold `th`? Walked most-aggressive
    /// format first; rejecting every candidate keeps input precision.
    fn accept_tensor(&self, ctx: &DecisionCtx, format: ReprType, relerr: f64, th: f64) -> bool;

    /// Sub-tensor question: which representation does this block get?
    fn choose_block(&self, ctx: &DecisionCtx, block: &BlockProps) -> BlockChoice;
}

/// The paper's decision logic, bitwise-identical to the pre-trait
/// implementation: tensor level accepts when `relerr < th`; block
/// level runs metric M1 (E4M3 wins when its accumulated relative
/// error is strictly below E5M2's) and, for three-way recipes, metric
/// M2 (E5M2 when the block's dynamic range fits the format).
#[derive(Debug, Clone, Copy, Default)]
pub struct MorThresholdPolicy;

impl DecisionPolicy for MorThresholdPolicy {
    fn describe(&self) -> String {
        "threshold".to_string()
    }

    fn pin(&self) -> u64 {
        1
    }

    fn accept_tensor(&self, _ctx: &DecisionCtx, _format: ReprType, relerr: f64, th: f64) -> bool {
        relerr < th
    }

    fn choose_block(&self, ctx: &DecisionCtx, block: &BlockProps) -> BlockChoice {
        // Metric M1: accumulated relative error, strict comparison —
        // the exact pre-trait expression (sum vs sum, both f64).
        if block.e4m3_err.sum < block.e5m2_err.sum {
            return BlockChoice::E4m3;
        }
        // Metric M2 (three-way only): dynamic-range containment.
        if ctx.three_way && dynamic_range_fits_e5m2(block.range.0, block.range.1) {
            return BlockChoice::E5m2;
        }
        BlockChoice::Fallback
    }
}

/// Relerr-budget policy (arXiv 2408.02897 spirit): one global relative
/// error budget; any candidate within budget is accepted, preferring
/// the more aggressive format. Ignores the run threshold and the
/// relative M1 comparison — the budget is absolute.
#[derive(Debug, Clone, Copy)]
pub struct MetricDrivenPolicy {
    /// Mean relative error a representation must stay within.
    pub budget: f64,
}

impl MetricDrivenPolicy {
    pub const DEFAULT_BUDGET: f64 = 0.03;
}

impl Default for MetricDrivenPolicy {
    fn default() -> Self {
        MetricDrivenPolicy { budget: Self::DEFAULT_BUDGET }
    }
}

impl DecisionPolicy for MetricDrivenPolicy {
    fn describe(&self) -> String {
        format!("metric={}", self.budget)
    }

    fn pin(&self) -> u64 {
        2 | ((self.budget as f32).to_bits() as u64) << 8
    }

    fn accept_tensor(&self, _ctx: &DecisionCtx, _format: ReprType, relerr: f64, _th: f64) -> bool {
        relerr < self.budget
    }

    fn choose_block(&self, ctx: &DecisionCtx, block: &BlockProps) -> BlockChoice {
        if block.e4m3_err.mean() < self.budget {
            return BlockChoice::E4m3;
        }
        if ctx.three_way && block.e5m2_err.mean() < self.budget {
            return BlockChoice::E5m2;
        }
        BlockChoice::Fallback
    }
}

/// Static per-tensor-class assignment (arXiv 2301.13464 spirit): a
/// fixed `input/weight/grad → format` table, no runtime properties
/// consulted. The baseline every dynamic policy is judged against.
#[derive(Debug, Clone, Copy)]
pub struct StaticAssignmentPolicy {
    /// Formats indexed by [`TensorClass::index`]: input, weight, grad.
    pub table: [ReprType; 3],
}

impl Default for StaticAssignmentPolicy {
    /// The classic FP8-training assignment: E4M3 forward operands,
    /// E5M2 for the wider-range gradients.
    fn default() -> Self {
        StaticAssignmentPolicy { table: [ReprType::E4M3, ReprType::E4M3, ReprType::E5M2] }
    }
}

impl StaticAssignmentPolicy {
    fn assigned(&self, ctx: &DecisionCtx) -> ReprType {
        self.table[ctx.class.index()]
    }
}

impl DecisionPolicy for StaticAssignmentPolicy {
    fn describe(&self) -> String {
        format!(
            "static={},{},{}",
            self.table[0].name(),
            self.table[1].name(),
            self.table[2].name()
        )
    }

    fn pin(&self) -> u64 {
        let code = |t: ReprType| match t {
            ReprType::E4M3 => 0u64,
            ReprType::E5M2 => 1,
            ReprType::Bf16 => 2,
            ReprType::NvFp4 => 3,
        };
        3 | (code(self.table[0]) | code(self.table[1]) << 2 | code(self.table[2]) << 4) << 8
    }

    fn accept_tensor(&self, ctx: &DecisionCtx, format: ReprType, _relerr: f64, _th: f64) -> bool {
        self.assigned(ctx) == format
    }

    fn choose_block(&self, ctx: &DecisionCtx, _block: &BlockProps) -> BlockChoice {
        match self.assigned(ctx) {
            ReprType::E4M3 => BlockChoice::E4m3,
            // E5M2 downgrades to the fallback under two-way recipes —
            // the format simply isn't on offer.
            ReprType::E5M2 if ctx.three_way => BlockChoice::E5m2,
            _ => BlockChoice::Fallback,
        }
    }
}

/// A composing wrapper the numeric guard uses to demote tensors to the
/// BF16 fallback for a bounded number of steps: any `(class, layer)`
/// pair with an active quarantine entry is forced to input precision
/// (tensor level rejects every FP8 candidate, block level picks
/// `Fallback`), everything else delegates to the wrapped policy.
///
/// Identity (`describe`/`pin`) is the *inner* policy's — quarantine is
/// run-dynamic state, checkpointed by the guard alongside its own
/// state, not part of the configured policy identity. The entry map is
/// only mutated between steps (the guard runs after each step), so
/// decisions within a step read a frozen map and the bitwise
/// determinism contracts hold.
#[derive(Debug)]
pub struct QuarantinePolicy {
    inner: PolicyRef,
    /// `(TensorClass::index, layer) → first step the quarantine has
    /// expired at`, in the 1-based `DecisionCtx::step` domain: the
    /// pair is quarantined while `ctx.step < until`.
    until: RwLock<HashMap<(usize, usize), u64>>,
}

impl QuarantinePolicy {
    pub fn new(inner: PolicyRef) -> Arc<QuarantinePolicy> {
        Arc::new(QuarantinePolicy { inner, until: RwLock::new(HashMap::new()) })
    }

    /// Quarantine `(class_idx, layer)` until `until_step` (exclusive,
    /// 1-based). Extensions max-merge with any existing entry.
    pub fn quarantine(&self, class_idx: usize, layer: usize, until_step: u64) {
        let mut map = self.until.write().unwrap();
        let e = map.entry((class_idx, layer)).or_insert(0);
        *e = (*e).max(until_step);
    }

    /// Active entries as sorted `(class_idx, layer, until_step)` rows —
    /// the guard's checkpoint codec input.
    pub fn active_entries(&self) -> Vec<(usize, usize, u64)> {
        let map = self.until.read().unwrap();
        let mut out: Vec<_> = map.iter().map(|(&(c, l), &u)| (c, l, u)).collect();
        out.sort_unstable();
        out
    }

    /// Replace the entry map (guard state restore / rewind).
    pub fn restore_entries(&self, entries: &[(usize, usize, u64)]) {
        let mut map = self.until.write().unwrap();
        map.clear();
        for &(c, l, u) in entries {
            map.insert((c, l), u);
        }
    }

    fn quarantined(&self, ctx: &DecisionCtx) -> bool {
        let map = self.until.read().unwrap();
        map.get(&(ctx.class.index(), ctx.layer)).is_some_and(|&u| ctx.step < u)
    }
}

impl DecisionPolicy for QuarantinePolicy {
    fn describe(&self) -> String {
        self.inner.describe()
    }

    fn pin(&self) -> u64 {
        self.inner.pin()
    }

    fn accept_tensor(&self, ctx: &DecisionCtx, format: ReprType, relerr: f64, th: f64) -> bool {
        if self.quarantined(ctx) {
            return false;
        }
        self.inner.accept_tensor(ctx, format, relerr, th)
    }

    fn choose_block(&self, ctx: &DecisionCtx, block: &BlockProps) -> BlockChoice {
        if self.quarantined(ctx) {
            return BlockChoice::Fallback;
        }
        self.inner.choose_block(ctx, block)
    }
}

/// The grammar every spec error repeats.
const SPEC_GRAMMAR: &str = "threshold, metric[=BUDGET] or static[=INPUT,WEIGHT,GRAD]";

/// Strictly parse a `--policy` / `MOR_POLICY` spec with the knob
/// conventions of [`crate::util::env`]: `Ok(None)` when unset,
/// `Ok(Some(policy))` for a valid spec, and a clear error otherwise
/// (the caller prefixes the flag/env name). Accepted specs:
/// `threshold`, `metric`, `metric=0.05`, `static`,
/// `static=e4m3,e4m3,e5m2` (three formats for input, weight, grad).
pub fn parse_policy(raw: Option<&str>) -> Result<Option<PolicyRef>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err(format!("is set but empty; use {SPEC_GRAMMAR}, or unset it"));
    }
    let (head, arg) = match trimmed.split_once('=') {
        Some((h, a)) => (h.trim(), Some(a.trim())),
        None => (trimmed, None),
    };
    match (head, arg) {
        ("threshold", None) => Ok(Some(Arc::new(MorThresholdPolicy))),
        ("threshold", Some(_)) => {
            Err(format!("threshold takes no argument, got {trimmed:?}"))
        }
        ("metric", None) => Ok(Some(Arc::new(MetricDrivenPolicy::default()))),
        ("metric", Some(v)) => match v.parse::<f64>() {
            Ok(b) if b.is_finite() && b > 0.0 => {
                Ok(Some(Arc::new(MetricDrivenPolicy { budget: b })))
            }
            _ => Err(format!("metric budget must be a positive finite number, got {v:?}")),
        },
        ("static", None) => Ok(Some(Arc::new(StaticAssignmentPolicy::default()))),
        ("static", Some(v)) => {
            let parts: Vec<&str> = v.split(',').map(str::trim).collect();
            let parsed: Option<Vec<ReprType>> =
                parts.iter().map(|p| ReprType::parse(p)).collect();
            match parsed.as_deref() {
                Some([i, w, g]) => {
                    Ok(Some(Arc::new(StaticAssignmentPolicy { table: [*i, *w, *g] })))
                }
                _ => Err(format!(
                    "static assignment needs three formats INPUT,WEIGHT,GRAD from \
                     e4m3/e5m2/bf16/nvfp4, got {v:?}"
                )),
            }
        }
        _ => Err(format!("must be {SPEC_GRAMMAR}, got {trimmed:?}")),
    }
}

/// Resolve the `MOR_POLICY` env knob: the named policy when set, the
/// default [`MorThresholdPolicy`] otherwise.
///
/// # Panics
/// When `MOR_POLICY` is set but malformed — the same loud-failure
/// contract as `MOR_THREADS` and the other knobs.
pub fn auto() -> PolicyRef {
    match parse_policy(crate::util::env::var("MOR_POLICY").as_deref()) {
        Ok(Some(p)) => p,
        Ok(None) => Arc::new(MorThresholdPolicy),
        Err(msg) => panic!("MOR_POLICY {msg}"),
    }
}

static GLOBAL: Mutex<Option<PolicyRef>> = Mutex::new(None);

/// Process-wide default policy, used by the no-argument entry points
/// (`Recipe::apply`, `mor_quantize_plan`) and as the default for new
/// `Runtime`s. Lazily initialized from [`auto`].
pub fn global() -> PolicyRef {
    GLOBAL.lock().unwrap().get_or_insert_with(auto).clone()
}

/// Override the process-wide default (CLI `--policy`). Per-run
/// configuration should prefer `TrainerOptions::policy` /
/// `Runtime::with_policy` over mutating this.
pub fn set_global(p: PolicyRef) {
    *GLOBAL.lock().unwrap() = Some(p);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accum(sum: f64, count: u64) -> RelErrAccum {
        RelErrAccum { sum, count }
    }

    #[test]
    fn threshold_policy_reproduces_m1_m2() {
        let p = MorThresholdPolicy;
        let two_way = DecisionCtx { three_way: false, ..Default::default() };
        let three_way = DecisionCtx { three_way: true, ..Default::default() };

        // M1 wins: strict less-than on the accumulated sums.
        let b = BlockProps {
            e4m3_err: &accum(0.1, 4),
            e5m2_err: &accum(0.2, 4),
            range: (1.0, Some(1.0)),
        };
        assert_eq!(p.choose_block(&two_way, &b), BlockChoice::E4m3);
        assert_eq!(p.choose_block(&three_way, &b), BlockChoice::E4m3);

        // M1 ties lose (strict), M2 rescues only the three-way recipe.
        let tied = BlockProps {
            e4m3_err: &accum(0.2, 4),
            e5m2_err: &accum(0.2, 4),
            range: (1.0, Some(0.5)),
        };
        assert_eq!(p.choose_block(&two_way, &tied), BlockChoice::Fallback);
        assert_eq!(p.choose_block(&three_way, &tied), BlockChoice::E5m2);

        // Range too wide for E5M2: fallback either way.
        let wide = BlockProps {
            e4m3_err: &accum(0.3, 4),
            e5m2_err: &accum(0.2, 4),
            range: (1e30, Some(1e-30)),
        };
        assert_eq!(p.choose_block(&three_way, &wide), BlockChoice::Fallback);

        // Tensor level: the bare threshold test.
        assert!(p.accept_tensor(&two_way, ReprType::E4M3, 0.01, 0.045));
        assert!(!p.accept_tensor(&two_way, ReprType::E4M3, 0.05, 0.045));
        assert!(!p.accept_tensor(&two_way, ReprType::E4M3, 0.045, 0.045), "strict <");
    }

    #[test]
    fn metric_policy_uses_absolute_budget() {
        let p = MetricDrivenPolicy { budget: 0.05 };
        let three_way = DecisionCtx { three_way: true, ..Default::default() };
        // E4M3 over budget, E5M2 within: picks E5M2 even though M1
        // would have picked E4M3 (0.24 < 0.25).
        let b = BlockProps {
            e4m3_err: &accum(0.24, 4), // mean 0.06 > budget
            e5m2_err: &accum(0.16, 4), // mean 0.04 < budget
            range: (1.0, Some(1.0)),
        };
        assert_eq!(p.choose_block(&three_way, &b), BlockChoice::E5m2);
        let two_way = DecisionCtx { three_way: false, ..Default::default() };
        assert_eq!(p.choose_block(&two_way, &b), BlockChoice::Fallback);
        // Tensor level ignores the run threshold entirely.
        assert!(p.accept_tensor(&two_way, ReprType::E4M3, 0.04, 0.0));
        assert!(!p.accept_tensor(&two_way, ReprType::E4M3, 0.06, 1.0));
    }

    #[test]
    fn static_policy_ignores_properties() {
        let p = StaticAssignmentPolicy::default();
        let junk = BlockProps {
            e4m3_err: &accum(f64::MAX, 1),
            e5m2_err: &accum(0.0, 1),
            range: (f32::MAX, Some(f32::MIN_POSITIVE)),
        };
        let weight = DecisionCtx {
            class: TensorClass::Weight,
            three_way: true,
            ..Default::default()
        };
        let grad3 = DecisionCtx { class: TensorClass::Grad, three_way: true, ..Default::default() };
        let grad2 =
            DecisionCtx { class: TensorClass::Grad, three_way: false, ..Default::default() };
        assert_eq!(p.choose_block(&weight, &junk), BlockChoice::E4m3);
        assert_eq!(p.choose_block(&grad3, &junk), BlockChoice::E5m2);
        // E5M2 is not on offer in a two-way recipe: fallback.
        assert_eq!(p.choose_block(&grad2, &junk), BlockChoice::Fallback);
        assert!(p.accept_tensor(&weight, ReprType::E4M3, 1e9, 0.0));
        assert!(!p.accept_tensor(&weight, ReprType::NvFp4, 0.0, 1.0));
    }

    #[test]
    fn parse_roundtrips_describe() {
        for spec in ["threshold", "metric=0.03", "metric=0.125", "static=e4m3,e4m3,e5m2",
            "static=nvfp4,e4m3,bf16"]
        {
            let p = parse_policy(Some(spec)).unwrap().unwrap();
            assert_eq!(p.describe(), spec, "describe() must round-trip through parse");
            let again = parse_policy(Some(&p.describe())).unwrap().unwrap();
            assert_eq!(again.pin(), p.pin(), "pin stable across a parse round-trip");
        }
        // Bare names resolve to the defaults.
        assert_eq!(parse_policy(Some("metric")).unwrap().unwrap().describe(), "metric=0.03");
        assert_eq!(
            parse_policy(Some("static")).unwrap().unwrap().describe(),
            "static=e4m3,e4m3,e5m2"
        );
        assert!(parse_policy(None).unwrap().is_none());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "", "  ", "thresh", "metric=", "metric=-1", "metric=0", "metric=nan",
            "metric=inf", "static=e4m3", "static=e4m3,e4m3", "static=e4m3,e4m3,fp64",
            "static=e4m3,e4m3,e5m2,e5m2", "threshold=1", "dynamic",
        ] {
            assert!(parse_policy(Some(bad)).is_err(), "spec {bad:?} must be rejected");
        }
    }

    #[test]
    fn pins_are_distinct_and_configuration_sensitive() {
        let th = MorThresholdPolicy.pin();
        let m1 = MetricDrivenPolicy { budget: 0.03 }.pin();
        let m2 = MetricDrivenPolicy { budget: 0.05 }.pin();
        let s1 = StaticAssignmentPolicy::default().pin();
        let s2 = StaticAssignmentPolicy { table: [ReprType::E4M3; 3] }.pin();
        let pins = [th, m1, m2, s1, s2];
        for (i, a) in pins.iter().enumerate() {
            for (j, b) in pins.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b, "pins {i} and {j} collide");
                }
            }
        }
    }

    #[test]
    fn quarantine_wrapper_is_transparent_until_armed() {
        let qp = QuarantinePolicy::new(Arc::new(MorThresholdPolicy));
        let ctx = DecisionCtx {
            class: TensorClass::Grad,
            layer: 1,
            step: 5,
            three_way: true,
            ..Default::default()
        };
        let good = BlockProps {
            e4m3_err: &accum(0.1, 4),
            e5m2_err: &accum(0.2, 4),
            range: (1.0, Some(1.0)),
        };
        // Transparent with no entries: identity and decisions delegate.
        assert_eq!(qp.describe(), "threshold");
        assert_eq!(qp.pin(), MorThresholdPolicy.pin());
        assert!(qp.accept_tensor(&ctx, ReprType::E4M3, 0.01, 0.045));
        assert_eq!(qp.choose_block(&ctx, &good), BlockChoice::E4m3);

        // Quarantined while step < until, for the keyed pair only.
        qp.quarantine(TensorClass::Grad.index(), 1, 8);
        assert!(!qp.accept_tensor(&ctx, ReprType::E4M3, 0.01, 0.045));
        assert_eq!(qp.choose_block(&ctx, &good), BlockChoice::Fallback);
        let other_layer = DecisionCtx { layer: 2, ..ctx };
        assert_eq!(qp.choose_block(&other_layer, &good), BlockChoice::E4m3);
        let expired = DecisionCtx { step: 8, ..ctx };
        assert_eq!(qp.choose_block(&expired, &good), BlockChoice::E4m3);

        // Extensions max-merge; restore replaces wholesale.
        qp.quarantine(TensorClass::Grad.index(), 1, 6);
        assert_eq!(qp.active_entries(), vec![(2, 1, 8)]);
        qp.restore_entries(&[(0, 0, 3)]);
        assert_eq!(qp.active_entries(), vec![(0, 0, 3)]);
        assert_eq!(qp.choose_block(&ctx, &good), BlockChoice::E4m3);
    }

    /// The process default resolves to the threshold policy (directly
    /// or via `MOR_POLICY=threshold`). Deliberately *not* a set/get
    /// mutation test: unit tests run concurrently and several recipe
    /// tests read the global through `Recipe::apply`, so flipping it
    /// here would race them (`set_global` is covered by the CLI path
    /// and the policy_equivalence integration suite).
    #[test]
    fn global_defaults_to_threshold() {
        assert_eq!(global().describe(), "threshold");
        assert_eq!(global().pin(), MorThresholdPolicy.pin());
    }
}
