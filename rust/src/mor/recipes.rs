//! The concrete MoR recipes evaluated in §4, built on the generic
//! framework walk:
//!
//! * **Tensor-level** (§3.1): one decision for the whole tensor —
//!   `[E4M3, BF16]`, accept E4M3 iff the global mean relative error over
//!   non-zero elements (aggregated across the partition's blocks, Fig. 2)
//!   is below `th_E4M3` (4.5% default, 5.0% ablation).
//! * **Sub-tensor Two-Way** (§3.2 Alg. 2): per 128×128 block,
//!   `[E4M3, BF16]` with metric M1 (Eq. 3: E4M3's relerr sum beats
//!   E5M2's); E5M2 is only a benchmark, never selected.
//! * **Sub-tensor Three-Way** (§3.2 Alg. 1): `[E4M3, E5M2, BF16]` with
//!   M1 for E4M3 and M2 (Eq. 4 range check) for E5M2.
//! * **Baseline**: no quantization (the BF16 reference run).
//! * **NVFP4 extension**: `[NVFP4, E4M3, BF16]` tensor-level walk — the
//!   future-work direction §5 sketches, included for the ablation bench.

use super::framework::{MorFramework, MorOutcome};
use super::policy::{self, BlockChoice, BlockProps, DecisionCtx, DecisionPolicy};
use crate::formats::ReprType;
use crate::quant::fake_quant::fake_quantize_with;
use crate::quant::partition::Partition;
use crate::scaling::ScalingAlgo;
use crate::tensor::Tensor;
use crate::util::par::{self, Parallelism};

/// Everything one recipe application needs beyond the tensor itself:
/// the parallelism handle, the decision policy, and the decision
/// context (tensor identity / step; the recipe fills in `three_way`).
/// This is the single real entry-point parameter — `apply`,
/// `apply_with` and the batch variants are thin wrappers that fill in
/// the process-global defaults, so new per-application inputs extend
/// this struct instead of multiplying `*_with` constructors.
#[derive(Clone, Copy)]
pub struct ApplyCtx<'a> {
    /// Execution engine for the underlying fake-quant passes.
    pub par: &'a Parallelism,
    /// The precision-assignment policy consulted for every decision.
    pub policy: &'a dyn DecisionPolicy,
    /// Identity/step context forwarded to the policy. `three_way` is
    /// overridden per recipe kind.
    pub decision: DecisionCtx,
}

impl<'a> ApplyCtx<'a> {
    /// A context with an anonymous decision scope (standalone tensor).
    pub fn new(par: &'a Parallelism, policy: &'a dyn DecisionPolicy) -> ApplyCtx<'a> {
        ApplyCtx { par, policy, decision: DecisionCtx::default() }
    }

    /// This context with an explicit decision scope.
    pub fn with_decision(mut self, decision: DecisionCtx) -> ApplyCtx<'a> {
        self.decision = decision;
        self
    }
}

/// Sub-tensor selection mode (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SubTensorMode {
    TwoWay,
    ThreeWay,
}

/// Which recipe to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RecipeKind {
    /// BF16 baseline — quantization disabled.
    Baseline,
    /// §3.1 tensor-level MoR.
    TensorLevel { threshold: f64 },
    /// §3.2 sub-tensor MoR at the partition's block granularity.
    SubTensor { mode: SubTensorMode },
    /// Extension: tensor-level walk over [NVFP4, E4M3, BF16].
    NvFp4TensorLevel { threshold_fp4: f64, threshold_e4m3: f64 },
}

/// A fully-specified recipe: kind + partition + scaling algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Recipe {
    pub kind: RecipeKind,
    pub partition: Partition,
    pub scaling: ScalingAlgo,
}

impl Recipe {
    /// The paper's default tensor-level recipe (128×128 blocks, GAM,
    /// th = 4.5%).
    pub fn paper_default() -> Recipe {
        Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.045 },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        }
    }

    pub fn baseline() -> Recipe {
        Recipe {
            kind: RecipeKind::Baseline,
            partition: Partition::Tensor,
            scaling: ScalingAlgo::Gam,
        }
    }

    /// Stable name used in CSV logs / CLI (matches artifact variants).
    pub fn name(&self) -> String {
        match self.kind {
            RecipeKind::Baseline => "baseline".into(),
            RecipeKind::TensorLevel { threshold } => format!(
                "mor_tensor_{}_{}_th{:.1}",
                self.partition.name(),
                self.scaling.name(),
                threshold * 100.0
            ),
            RecipeKind::SubTensor { mode } => format!(
                "mor_subtensor_{}_{}",
                match mode {
                    SubTensorMode::TwoWay => "two_way",
                    SubTensorMode::ThreeWay => "three_way",
                },
                self.partition.name()
            ),
            RecipeKind::NvFp4TensorLevel { .. } => {
                format!("mor_nvfp4_{}", self.partition.name())
            }
        }
    }

    /// Apply the recipe to one tensor, producing the mixed-representation
    /// fake-quantized output plus decision telemetry. Uses the
    /// process-global [`Parallelism`] and decision policy.
    pub fn apply(&self, x: &Tensor) -> MorOutcome {
        let (cfg, pol) = (par::global(), policy::global());
        self.apply_ctx(x, &ApplyCtx::new(&cfg, pol.as_ref()))
    }

    /// [`Recipe::apply`] with an explicit [`Parallelism`] (process-global
    /// decision policy).
    pub fn apply_with(&self, x: &Tensor, cfg: &Parallelism) -> MorOutcome {
        let pol = policy::global();
        self.apply_ctx(x, &ApplyCtx::new(cfg, pol.as_ref()))
    }

    /// The real single-tensor entry point: apply the recipe under an
    /// explicit [`ApplyCtx`] (parallelism + policy + decision scope).
    pub fn apply_ctx(&self, x: &Tensor, ctx: &ApplyCtx) -> MorOutcome {
        match self.kind {
            RecipeKind::Baseline => baseline(x),
            RecipeKind::TensorLevel { threshold } => {
                tensor_level(x, self.partition, self.scaling, threshold, ctx)
            }
            RecipeKind::SubTensor { mode } => {
                sub_tensor(x, self.partition, self.scaling, mode, ctx)
            }
            RecipeKind::NvFp4TensorLevel { threshold_fp4, threshold_e4m3 } => {
                nvfp4_tensor_level(
                    x,
                    self.partition,
                    self.scaling,
                    threshold_fp4,
                    threshold_e4m3,
                    ctx,
                )
            }
        }
    }

    /// The per-step MoR decision sweep: apply the recipe to every
    /// tensor of a mini-batch on the shared pool with **weighted
    /// scheduling** — items dispatch largest-tensor-first (element
    /// count as the cost estimate), so a mixed-size batch no longer
    /// strands its giant tensor behind a queue of tiny ones. Each item
    /// stays chunk-parallel *inside* its application too (nested
    /// sections share the pool deadlock-free), replacing the old
    /// serial-inside-one-worker scheme whose tail latency was the
    /// largest tensor run single-threaded.
    ///
    /// Outcome order matches input order and each outcome is
    /// bit-identical to a standalone [`Recipe::apply`] — weighted
    /// dispatch reorders only *scheduling*, never the canonical result
    /// merge.
    pub fn apply_batch(&self, xs: &[&Tensor]) -> Vec<MorOutcome> {
        let (cfg, pol) = (par::global(), policy::global());
        self.apply_batch_ctx(xs, &ApplyCtx::new(&cfg, pol.as_ref()))
    }

    /// [`Recipe::apply_batch`] with an explicit [`Parallelism`]
    /// (process-global decision policy).
    pub fn apply_batch_with(&self, xs: &[&Tensor], cfg: &Parallelism) -> Vec<MorOutcome> {
        let pol = policy::global();
        self.apply_batch_ctx(xs, &ApplyCtx::new(cfg, pol.as_ref()))
    }

    /// The real batch entry point: [`Recipe::apply_batch`] under an
    /// explicit [`ApplyCtx`].
    pub fn apply_batch_ctx(&self, xs: &[&Tensor], ctx: &ApplyCtx) -> Vec<MorOutcome> {
        if ctx.par.threads <= 1 || xs.len() <= 1 {
            return xs.iter().map(|x| self.apply_ctx(x, ctx)).collect();
        }
        let weights: Vec<usize> = xs.iter().map(|x| x.len()).collect();
        // Pooled engines share one bounded worker set, so nesting is
        // free; the scoped-thread spawn engine has no such bound —
        // items × chunks would oversubscribe — so it keeps the old
        // serial-inside-each-item scheme (bitwise identical either
        // way, by the engine contract).
        let inner_par = match ctx.par.engine() {
            par::Engine::Spawn => Parallelism::serial(),
            _ => ctx.par.clone(),
        };
        let inner = ApplyCtx { par: &inner_par, ..*ctx };
        par::par_map_weighted(ctx.par, &weights, |i| self.apply_ctx(xs[i], &inner))
    }
}

fn baseline(x: &Tensor) -> MorOutcome {
    MorOutcome {
        out: x.clone(),
        block_types: vec![ReprType::Bf16],
        e4m3_relerr: 0.0,
        bf16_fraction: 1.0,
        metadata_bits: 0,
    }
}

/// §3.1 — one global decision from the aggregated relative error.
fn tensor_level(
    x: &Tensor,
    partition: Partition,
    scaling: ScalingAlgo,
    th: f64,
    ctx: &ApplyCtx,
) -> MorOutcome {
    let cfg = ctx.par;
    let fq = fake_quantize_with(x, ReprType::E4M3, partition, scaling, cfg);
    let relerr = fq.global_err.mean();
    let fw = MorFramework::e4m3_bf16();
    let nblocks = fq.block_err.len();
    let dctx = DecisionCtx { three_way: false, ..ctx.decision };
    let choice = fw.select_block(0, |t, _| {
        t == ReprType::E4M3 && ctx.policy.accept_tensor(&dctx, t, relerr, th)
    });
    if choice == ReprType::E4M3 {
        let metadata_bits = fq.scales.metadata_bits();
        MorOutcome {
            out: fq.out,
            block_types: vec![ReprType::E4M3; nblocks],
            e4m3_relerr: relerr,
            bf16_fraction: 0.0,
            metadata_bits,
        }
    } else {
        let bf = fake_quantize_with(x, ReprType::Bf16, Partition::Tensor, scaling, cfg);
        MorOutcome {
            out: bf.out,
            block_types: vec![ReprType::Bf16; nblocks],
            e4m3_relerr: relerr,
            bf16_fraction: 1.0,
            metadata_bits: 0,
        }
    }
}

/// §3.2 — per-block walk; blocks mix representations inside one tensor.
fn sub_tensor(
    x: &Tensor,
    partition: Partition,
    scaling: ScalingAlgo,
    mode: SubTensorMode,
    ctx: &ApplyCtx,
) -> MorOutcome {
    let cfg = ctx.par;
    let (rows, cols) = x.as_2d();
    let _ = rows;
    // The two candidate quantizations are independent; overlap them on
    // the pool (each stays internally chunk-parallel and deterministic).
    let (fq_e4m3, fq_e5m2) = par::join2(
        cfg,
        || fake_quantize_with(x, ReprType::E4M3, partition, scaling, cfg),
        || fake_quantize_with(x, ReprType::E5M2, partition, scaling, cfg),
    );
    let nblocks = fq_e4m3.block_err.len();
    let three_way = mode == SubTensorMode::ThreeWay;
    let fw = match mode {
        SubTensorMode::TwoWay => MorFramework::e4m3_bf16(),
        SubTensorMode::ThreeWay => MorFramework::e4m3_e5m2_bf16(),
    };
    // One policy verdict per block (the default MorThresholdPolicy
    // runs metric M1 / Eq. 3, then M2 / Eq. 4 for three-way recipes —
    // bitwise-identical to the pre-policy inline walk). An `E5m2`
    // verdict under a two-way recipe is coerced to the fallback: the
    // format is not on offer.
    let dctx = DecisionCtx { three_way, ..ctx.decision };
    let choices: Vec<BlockChoice> = (0..nblocks)
        .map(|b| {
            let props = BlockProps {
                e4m3_err: &fq_e4m3.block_err[b],
                e5m2_err: &fq_e5m2.block_err[b],
                range: fq_e4m3.block_range[b],
            };
            match ctx.policy.choose_block(&dctx, &props) {
                BlockChoice::E5m2 if !three_way => BlockChoice::Fallback,
                c => c,
            }
        })
        .collect();
    let block_types = fw.select_all(nblocks, |t, b| match t {
        ReprType::E4M3 => choices[b] == BlockChoice::E4m3,
        ReprType::E5M2 => choices[b] == BlockChoice::E5m2,
        _ => false,
    });

    // Assemble the mixed-representation output and count BF16 elements.
    let mut out = Tensor::zeros(x.shape());
    let blocks = partition.blocks(x.as_2d().0, cols);
    let mut bf16_elems = 0usize;
    for (i, (b, t)) in blocks.iter().zip(block_types.iter()).enumerate() {
        let _ = i;
        for idx in b.indices(cols) {
            out.data_mut()[idx] = match t {
                ReprType::E4M3 => fq_e4m3.out.data()[idx],
                ReprType::E5M2 => fq_e5m2.out.data()[idx],
                _ => crate::formats::bf16::quantize_dequantize(x.data()[idx]),
            };
        }
        if *t == ReprType::Bf16 {
            bf16_elems += b.len();
        }
    }
    let metadata_bits = block_types
        .iter()
        .filter(|t| **t != ReprType::Bf16)
        .count() as u64
        * scaling.block_metadata_bits() as u64
        + if scaling == ScalingAlgo::Gam { 23 } else { 0 };
    MorOutcome {
        out,
        block_types,
        e4m3_relerr: fq_e4m3.global_err.mean(),
        bf16_fraction: bf16_elems as f64 / x.len().max(1) as f64,
        metadata_bits,
    }
}

/// Extension: `[NVFP4, E4M3, BF16]` tensor-level walk with per-type
/// thresholds on the global mean relative error.
fn nvfp4_tensor_level(
    x: &Tensor,
    partition: Partition,
    scaling: ScalingAlgo,
    th_fp4: f64,
    th_e4m3: f64,
    ctx: &ApplyCtx,
) -> MorOutcome {
    let cfg = ctx.par;
    let (fq4, fq8) = par::join2(
        cfg,
        || {
            let sub = Partition::SubChannelRows { len: 16 };
            fake_quantize_with(x, ReprType::NvFp4, sub, scaling, cfg)
        },
        || fake_quantize_with(x, ReprType::E4M3, partition, scaling, cfg),
    );
    let fw = MorFramework::new(vec![ReprType::NvFp4, ReprType::E4M3, ReprType::Bf16]);
    let dctx = DecisionCtx { three_way: false, ..ctx.decision };
    let choice = fw.select_block(0, |t, _| match t {
        ReprType::NvFp4 => ctx.policy.accept_tensor(&dctx, t, fq4.global_err.mean(), th_fp4),
        ReprType::E4M3 => ctx.policy.accept_tensor(&dctx, t, fq8.global_err.mean(), th_e4m3),
        _ => false,
    });
    let nblocks = fq8.block_err.len();
    let (out, bf16_fraction, metadata_bits) = match choice {
        ReprType::NvFp4 => (fq4.out, 0.0, fq4.scales.metadata_bits()),
        ReprType::E4M3 => (fq8.out, 0.0, fq8.scales.metadata_bits()),
        _ => (
            fake_quantize_with(x, ReprType::Bf16, Partition::Tensor, scaling, cfg).out,
            1.0,
            0,
        ),
    };
    MorOutcome {
        out,
        block_types: vec![choice; nblocks],
        e4m3_relerr: fq8.global_err.mean(),
        bf16_fraction,
        metadata_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    fn smooth_tensor(seed: u64) -> Tensor {
        // Narrow dynamic range → quantizes well to E4M3.
        Tensor::normal(&[16, 16], 1.0, seed)
    }

    fn wild_tensor(seed: u64) -> Tensor {
        // Values spanning ~12 binades → high relative error under any
        // single-scale FP8 quantization.
        let mut t = Tensor::normal(&[16, 16], 1.0, seed);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 13) as i32 - 6);
        }
        t
    }

    fn medium_range_tensor(seed: u64) -> Tensor {
        // Dynamic range ~10^6 per block: wide enough that E4M3 flushes
        // the small values (losing Eq. 3 to E5M2), narrow enough to fit
        // E5M2's normal range (passing Eq. 4).
        let mut t = Tensor::normal(&[16, 16], 1.0, seed);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 7) as i32 - 3);
        }
        t
    }

    #[test]
    fn tensor_level_accepts_smooth() {
        let r = Recipe::paper_default().apply(&smooth_tensor(1));
        assert_eq!(r.bf16_fraction, 0.0);
        assert!(r.block_types.iter().all(|t| *t == ReprType::E4M3));
        assert!(r.e4m3_relerr < 0.045);
    }

    #[test]
    fn tensor_level_rejects_wild() {
        let x = wild_tensor(2);
        let r = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.045 },
            partition: Partition::Tensor, // single scale: worst case
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        assert_eq!(r.bf16_fraction, 1.0);
        assert!(r.full_fallback());
        assert!(r.e4m3_relerr >= 0.045, "relerr {}", r.e4m3_relerr);
    }

    #[test]
    fn baseline_is_identity() {
        let x = smooth_tensor(3);
        let r = Recipe::baseline().apply(&x);
        assert_eq!(r.out, x);
        assert_eq!(r.bf16_fraction, 1.0);
        assert_eq!(r.metadata_bits, 0);
    }

    #[test]
    fn two_way_never_selects_e5m2() {
        let x = wild_tensor(4);
        let r = Recipe {
            kind: RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
            partition: Partition::Block { r: 4, c: 4 },
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        assert!(r.block_types.iter().all(|t| *t != ReprType::E5M2));
    }

    #[test]
    fn three_way_can_select_e5m2() {
        // Blocks with moderate dynamic range where E5M2's wider exponent
        // wins Eq. 3 but the range still fits Eq. 4.
        let x = medium_range_tensor(5);
        let r = Recipe {
            kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
            partition: Partition::Block { r: 4, c: 4 },
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        let f = r.type_fractions();
        assert!(f[1] > 0.0, "expected some E5M2 blocks, got {:?}", f);
    }

    #[test]
    fn threshold_monotonicity() {
        // Raising the threshold can only move tensors from BF16 to E4M3.
        let x = Tensor::normal(&[32, 32], 1.0, 6);
        let strict = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 1e-6 },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        let loose = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.5 },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        }
        .apply(&x);
        assert_eq!(strict.bf16_fraction, 1.0);
        assert_eq!(loose.bf16_fraction, 0.0);
    }

    /// Property: the recipe output never degrades a kept-BF16 element
    /// beyond bf16 rounding, and quantized outputs are finite.
    #[test]
    fn prop_outcome_wellformed() {
        prop(80, |g: &mut Gen| {
            let x = Tensor::from_vec(
                &[8, 12],
                (0..96).map(|_| g.f32_in(-8.0, 8.0)).collect(),
            );
            let recipe = Recipe {
                kind: *g.choose(&[
                    RecipeKind::TensorLevel { threshold: 0.045 },
                    RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
                    RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
                ]),
                partition: *g.choose(&[
                    Partition::Tensor,
                    Partition::Block { r: 4, c: 4 },
                    Partition::ChannelRows,
                ]),
                scaling: *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0]),
            };
            let r = recipe.apply(&x);
            assert!(r.out.data().iter().all(|v| v.is_finite()));
            assert!((0.0..=1.0).contains(&r.bf16_fraction));
            let f = r.type_fractions();
            assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            true
        });
    }

    /// The explicit-context entry point honors a non-default policy,
    /// and the wrapper quadruplet all route through it unchanged.
    #[test]
    fn apply_ctx_swaps_policy() {
        use crate::mor::policy::{MorThresholdPolicy, StaticAssignmentPolicy};
        let x = wild_tensor(8);
        let recipe = Recipe {
            kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
            partition: Partition::Block { r: 4, c: 4 },
            scaling: ScalingAlgo::Gam,
        };
        let cfg = Parallelism::serial();
        // Static input→E4M3: every block pinned to E4M3 regardless of
        // the measured errors the wild tensor produces.
        let all_e4m3 = StaticAssignmentPolicy { table: [ReprType::E4M3; 3] };
        let r = recipe.apply_ctx(&x, &ApplyCtx::new(&cfg, &all_e4m3));
        assert!(r.block_types.iter().all(|t| *t == ReprType::E4M3));
        assert_eq!(r.bf16_fraction, 0.0);
        // The default-policy wrappers and an explicit threshold-policy
        // context agree exactly (the process default is the threshold
        // policy unless a test overrode it — pass it explicitly).
        let via_ctx = recipe.apply_ctx(&x, &ApplyCtx::new(&cfg, &MorThresholdPolicy));
        let via_with = recipe.apply_with(&x, &cfg);
        assert_eq!(via_ctx.block_types, via_with.block_types);
        for (a, b) in via_ctx.out.data().iter().zip(via_with.out.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Property: two-way and three-way agree on blocks where E4M3 wins M1.
    #[test]
    fn prop_two_three_way_agree_on_e4m3_blocks() {
        prop(40, |g: &mut Gen| {
            let mut x = Tensor::normal(&[12, 12], 1.0, g.next_u64());
            for (i, v) in x.data_mut().iter_mut().enumerate() {
                *v *= (10.0f32).powi((i % 7) as i32 - 3);
            }
            let part = Partition::Block { r: 4, c: 4 };
            let two = Recipe {
                kind: RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
                partition: part,
                scaling: ScalingAlgo::Gam,
            }
            .apply(&x);
            let three = Recipe {
                kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
                partition: part,
                scaling: ScalingAlgo::Gam,
            }
            .apply(&x);
            for (a, b) in two.block_types.iter().zip(three.block_types.iter()) {
                if *a == ReprType::E4M3 {
                    assert_eq!(*b, ReprType::E4M3);
                }
                if *b == ReprType::Bf16 {
                    assert_eq!(*a, ReprType::Bf16);
                }
            }
            true
        });
    }
}
