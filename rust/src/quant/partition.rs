//! Partition strategies over a 2-D tensor (§3 of the paper): the set of
//! blocks B that MoR quantizes and scores independently.
//!
//! * `Tensor` — one block, the whole tensor.
//! * `Block{r,c}` — r×c tiles (128×128 default, 64×64 ablation).
//! * `ChannelRows` / `ChannelCols` — one block per row / per column. The
//!   paper's "per-channel" picks rows or columns *based on the dot
//!   product dimension*: the contracting dimension of the GEMM the tensor
//!   feeds. [`Partition::channel_for_contraction`] encodes that rule.
//! * `SubChannelRows{len}` — 1×len sub-channel segments (MX-style 1×32,
//!   NVFP4-style 1×16).

/// Half-open 2-D index region \[r0, r1) × \[c0, c1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRegion {
    pub r0: usize,
    pub r1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl BlockRegion {
    pub fn len(&self) -> usize {
        (self.r1 - self.r0) * (self.c1 - self.c0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate flat (row-major) indices of this region within a tensor of
    /// `cols` columns.
    pub fn indices(&self, cols: usize) -> impl Iterator<Item = usize> + '_ {
        let (c0, c1) = (self.c0, self.c1);
        (self.r0..self.r1).flat_map(move |r| (c0..c1).map(move |c| r * cols + c))
    }
}

/// A partition strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Partition {
    Tensor,
    Block { r: usize, c: usize },
    ChannelRows,
    ChannelCols,
    SubChannelRows { len: usize },
}

impl Partition {
    /// The paper's default 128×128 per-block strategy.
    pub const BLOCK128: Partition = Partition::Block { r: 128, c: 128 };
    /// The 64×64 ablation.
    pub const BLOCK64: Partition = Partition::Block { r: 64, c: 64 };

    /// Per-channel partition aligned with the dot-product dimension:
    /// if the tensor contracts along its columns (first GEMM operand,
    /// `x[m,k] @ w[k,n]` → x contracts along cols) use rows as blocks;
    /// if it contracts along rows (second operand) use columns.
    pub fn channel_for_contraction(contracts_along_cols: bool) -> Partition {
        if contracts_along_cols {
            Partition::ChannelRows
        } else {
            Partition::ChannelCols
        }
    }

    /// Stable name for manifests / CLI.
    pub fn name(self) -> String {
        match self {
            Partition::Tensor => "tensor".into(),
            Partition::Block { r, c } => format!("block{r}x{c}"),
            Partition::ChannelRows => "channel_rows".into(),
            Partition::ChannelCols => "channel_cols".into(),
            Partition::SubChannelRows { len } => format!("subchannel{len}"),
        }
    }

    pub fn parse(s: &str) -> Option<Partition> {
        match s {
            "tensor" => Some(Partition::Tensor),
            "channel_rows" => Some(Partition::ChannelRows),
            "channel_cols" => Some(Partition::ChannelCols),
            _ => {
                if let Some(rest) = s.strip_prefix("block") {
                    let (r, c) = rest.split_once('x')?;
                    Some(Partition::Block { r: r.parse().ok()?, c: c.parse().ok()? })
                } else if let Some(rest) = s.strip_prefix("subchannel") {
                    Some(Partition::SubChannelRows { len: rest.parse().ok()? })
                } else {
                    None
                }
            }
        }
    }

    /// Enumerate the blocks covering a `rows`×`cols` tensor, row-major
    /// over the block grid. Ragged edges produce smaller blocks.
    pub fn blocks(self, rows: usize, cols: usize) -> Vec<BlockRegion> {
        match self {
            Partition::Tensor => {
                vec![BlockRegion { r0: 0, r1: rows, c0: 0, c1: cols }]
            }
            Partition::Block { r, c } => {
                let mut out = Vec::with_capacity(rows.div_ceil(r) * cols.div_ceil(c));
                for br in 0..rows.div_ceil(r) {
                    for bc in 0..cols.div_ceil(c) {
                        out.push(BlockRegion {
                            r0: br * r,
                            r1: ((br + 1) * r).min(rows),
                            c0: bc * c,
                            c1: ((bc + 1) * c).min(cols),
                        });
                    }
                }
                out
            }
            Partition::ChannelRows => (0..rows)
                .map(|r| BlockRegion { r0: r, r1: r + 1, c0: 0, c1: cols })
                .collect(),
            Partition::ChannelCols => (0..cols)
                .map(|c| BlockRegion { r0: 0, r1: rows, c0: c, c1: c + 1 })
                .collect(),
            Partition::SubChannelRows { len } => {
                let mut out = Vec::new();
                for r in 0..rows {
                    for bc in 0..cols.div_ceil(len) {
                        out.push(BlockRegion {
                            r0: r,
                            r1: r + 1,
                            c0: bc * len,
                            c1: ((bc + 1) * len).min(cols),
                        });
                    }
                }
                out
            }
        }
    }

    /// Number of blocks without materializing them.
    pub fn num_blocks(self, rows: usize, cols: usize) -> usize {
        match self {
            Partition::Tensor => 1,
            Partition::Block { r, c } => rows.div_ceil(r) * cols.div_ceil(c),
            Partition::ChannelRows => rows,
            Partition::ChannelCols => cols,
            Partition::SubChannelRows { len } => rows * cols.div_ceil(len),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    #[test]
    fn names_roundtrip() {
        for p in [
            Partition::Tensor,
            Partition::BLOCK128,
            Partition::BLOCK64,
            Partition::ChannelRows,
            Partition::ChannelCols,
            Partition::SubChannelRows { len: 32 },
        ] {
            assert_eq!(Partition::parse(&p.name()), Some(p));
        }
        assert_eq!(Partition::parse("bogus"), None);
    }

    #[test]
    fn block_counts() {
        assert_eq!(Partition::BLOCK128.num_blocks(256, 384), 2 * 3);
        assert_eq!(Partition::BLOCK128.num_blocks(100, 100), 1);
        assert_eq!(Partition::Tensor.num_blocks(999, 7), 1);
        assert_eq!(Partition::ChannelRows.num_blocks(5, 9), 5);
        assert_eq!(Partition::ChannelCols.num_blocks(5, 9), 9);
        assert_eq!(Partition::SubChannelRows { len: 4 }.num_blocks(3, 10), 9);
    }

    #[test]
    fn channel_for_contraction_rule() {
        assert_eq!(Partition::channel_for_contraction(true), Partition::ChannelRows);
        assert_eq!(Partition::channel_for_contraction(false), Partition::ChannelCols);
    }

    /// Property: every partition's blocks exactly tile the tensor —
    /// disjoint and covering.
    #[test]
    fn prop_blocks_tile_exactly() {
        prop(200, |g: &mut Gen| {
            let rows = g.usize_in(1, 50);
            let cols = g.usize_in(1, 50);
            let (br, bc, sl) = (g.usize_in(1, 20), g.usize_in(1, 20), g.usize_in(1, 16));
            let p = *g.choose(&[
                Partition::Tensor,
                Partition::Block { r: br, c: bc },
                Partition::ChannelRows,
                Partition::ChannelCols,
                Partition::SubChannelRows { len: sl },
            ]);
            let blocks = p.blocks(rows, cols);
            assert_eq!(blocks.len(), p.num_blocks(rows, cols));
            let mut seen = vec![false; rows * cols];
            for b in &blocks {
                assert!(!b.is_empty(), "{p:?} produced empty block {b:?}");
                for idx in b.indices(cols) {
                    assert!(!seen[idx], "{p:?} double-covers index {idx}");
                    seen[idx] = true;
                }
            }
            assert!(seen.iter().all(|s| *s), "{p:?} leaves holes");
            true
        });
    }

    #[test]
    fn ragged_edge_blocks() {
        let p = Partition::Block { r: 3, c: 3 };
        let blocks = p.blocks(4, 5);
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[3], BlockRegion { r0: 3, r1: 4, c0: 3, c1: 5 });
    }
}
