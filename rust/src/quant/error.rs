//! The relative-error metrics of §3.1–3.2.
//!
//! * Eqs. (1)–(2): mean relative quantization error over the **non-zero**
//!   elements of a tensor — the tensor-level MoR acceptance metric
//!   (`error < th_E4M3`).
//! * Eq. (3): per-block *sums* of relative error, compared between E4M3
//!   and E5M2 — the sub-tensor metric M1.
//! * Eq. (4): block dynamic-range check against E5M2's normal range —
//!   the sub-tensor metric M2.

use crate::formats::fp8::{Fp8Format, E5M2};

/// Streaming accumulator for relative error over non-zero elements.
/// Local (per-block) errors aggregate into the global tensor error by
/// summing accumulators — exactly the "aggregate the local errors into
/// the global quantization error" step of §3.1 / Fig. 2.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RelErrAccum {
    /// Σ |x - Q(x)| / |x| over non-zero x.
    pub sum: f64,
    /// Count of non-zero elements (n in Eq. 1).
    pub count: u64,
}

impl RelErrAccum {
    pub fn add(&mut self, x: f32, q: f32) {
        if x != 0.0 {
            self.sum += (((x - q) / x).abs()) as f64;
            self.count += 1;
        }
    }

    pub fn merge(&mut self, other: RelErrAccum) {
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Mean relative error (Eq. 2); zero for tensors with no non-zero
    /// elements (an all-zero tensor quantizes losslessly).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Eq. (1)–(2): mean relative error between `x` and its quantization `q`
/// over non-zero elements.
pub fn mean_relative_error(x: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), q.len());
    let mut acc = RelErrAccum::default();
    for (a, b) in x.iter().zip(q.iter()) {
        acc.add(*a, *b);
    }
    acc.mean()
}

/// Eq. (3) left/right side: Σ over non-zero elements of |x - Q(x)|/|x|
/// for one block (a *sum*, not a mean — per the paper's metric M1).
pub fn block_relerr_sum(x: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), q.len());
    let mut acc = RelErrAccum::default();
    for (a, b) in x.iter().zip(q.iter()) {
        acc.add(*a, *b);
    }
    acc.sum
}

/// Eq. (4), metric M2: does the block's dynamic range (amax over non-zero
/// amin) fit within E5M2's *normal* range 57344 / 2^-14?
pub fn dynamic_range_fits_e5m2(amax: f32, amin_nonzero: Option<f32>) -> bool {
    const RATIO: f32 = E5M2::MAX / E5M2::MIN_NORMAL; // 57344 / 2^-14
    match amin_nonzero {
        None => true, // all-zero block: trivially representable
        Some(amin) => amax / amin < RATIO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    #[test]
    fn mean_ignores_zeros() {
        // x = [0, 2, 4]; q = [0, 1, 4]. Non-zero relerrs: 0.5, 0.0.
        let e = mean_relative_error(&[0.0, 2.0, 4.0], &[0.0, 1.0, 4.0]);
        assert_eq!(e, 0.25);
    }

    #[test]
    fn all_zero_tensor_has_zero_error() {
        assert_eq!(mean_relative_error(&[0.0; 8], &[0.0; 8]), 0.0);
    }

    #[test]
    fn sum_vs_mean() {
        let x = [1.0f32, 2.0, 0.0];
        let q = [0.9f32, 1.8, 0.0];
        let s = block_relerr_sum(&x, &q);
        let m = mean_relative_error(&x, &q);
        assert!((s - 0.2).abs() < 1e-6);
        assert!((m - 0.1).abs() < 1e-6);
    }

    #[test]
    fn accum_merge_equals_whole() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.3).collect();
        let q: Vec<f32> = x.iter().map(|v| v * 0.99).collect();
        let whole = mean_relative_error(&x, &q);
        let mut a = RelErrAccum::default();
        let mut b = RelErrAccum::default();
        for i in 0..50 {
            a.add(x[i], q[i]);
        }
        for i in 50..100 {
            b.add(x[i], q[i]);
        }
        a.merge(b);
        assert!((a.mean() - whole).abs() < 1e-12);
    }

    #[test]
    fn dynamic_range_boundary() {
        // Exactly at the ratio fails (strict <), just below passes.
        let ratio = 57344.0f32 / 6.103515625e-5;
        assert!(!dynamic_range_fits_e5m2(ratio, Some(1.0)));
        assert!(dynamic_range_fits_e5m2(ratio * 0.999, Some(1.0)));
        assert!(dynamic_range_fits_e5m2(1.0, Some(1.0)));
        assert!(dynamic_range_fits_e5m2(5.0, None));
    }

    /// Property: relative error is scale-invariant (relerr(kx, kq) ==
    /// relerr(x, q)) — the reason the paper can use it as a
    /// representation-independent invariance.
    #[test]
    fn prop_scale_invariance() {
        prop(300, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-4.0, 4.0)).collect();
            let q: Vec<f32> = x.iter().map(|v| v * g.f32_in(0.9, 1.1)).collect();
            let k = g.f32_log_uniform(1e-3, 1e3);
            let xk: Vec<f32> = x.iter().map(|v| v * k).collect();
            let qk: Vec<f32> = q.iter().map(|v| v * k).collect();
            let e1 = mean_relative_error(&x, &q);
            let e2 = mean_relative_error(&xk, &qk);
            (e1 - e2).abs() < 1e-5 * (1.0 + e1)
        });
    }
}
