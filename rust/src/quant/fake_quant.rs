//! The fake-quantization pipeline of Fig. 4: per block, **scale → cast to
//! the target format → cast back → de-scale**, leaving the tensor in its
//! original precision but carrying the target format's information loss.
//!
//! This is the host mirror of the Pallas kernel
//! (`python/compile/kernels/fake_quant.py`); the integration tests hold
//! the two bit-equal on shared inputs.
//!
//! Execution is parallel over partition blocks via the chunked engine in
//! [`crate::util::par`]: blocks are independent by construction (they
//! tile the tensor disjointly), per-block error accumulators come back
//! in canonical partition order and are merged serially, so the result
//! is **bit-identical to the serial path** for any thread count
//! (pinned by `rust/tests/parallel_equivalence.rs`).

use super::error::RelErrAccum;
use super::partition::{BlockRegion, Partition};
use crate::formats::fp8::{Fp8Format, Rounding, E4M3, E5M2};
use crate::formats::{bf16, ReprType};
use crate::kernels::qdq as qdq_kernel;
use crate::scaling::{compute_scales_with, GroupScales, ScalingAlgo};
use crate::tensor::Tensor;
use crate::util::par::{self, DisjointWriter, KernelMode, Parallelism};

/// Result of fake-quantizing one tensor under one (type, partition,
/// scaling) configuration.
#[derive(Debug, Clone)]
pub struct FakeQuantResult {
    /// The quantize–dequantized tensor (same shape/precision as input).
    pub out: Tensor,
    /// Per-block scales + group metadata.
    pub scales: GroupScales,
    /// Per-block relative-error accumulators (Eq. 3 numerators), in
    /// partition block order.
    pub block_err: Vec<RelErrAccum>,
    /// Global accumulator (merge of all blocks) — Eq. (1)–(2).
    pub global_err: RelErrAccum,
    /// Per-block (amax, non-zero amin) for metric M2 (Eq. 4).
    pub block_range: Vec<(f32, Option<f32>)>,
}

fn qdq(t: ReprType, x: f32) -> f32 {
    match t {
        ReprType::E4M3 => E4M3::quantize_dequantize(x, Rounding::Saturate),
        ReprType::E5M2 => E5M2::quantize_dequantize(x, Rounding::Saturate),
        ReprType::Bf16 => bf16::quantize_dequantize(x),
        ReprType::NvFp4 => crate::formats::fp4::e2m1_quantize_dequantize(x),
    }
}

/// Phase-B body for one block under the **kernel** engine: the block's
/// contiguous row segments run through the slice-level LUT QDQ, then
/// the error accumulator replays the written values in the same
/// row-major order the scalar loop uses. Bit-identical to
/// [`qdq_block_scalar`] (the LUT round-trip is exactly value-preserving
/// and f64 error accumulation order is unchanged).
///
/// # Safety contract
/// `sink` covers the whole output tensor and `b` is disjoint from every
/// concurrently processed block (partition tiling).
fn qdq_block_kernel(
    target: ReprType,
    xd: &[f32],
    b: &BlockRegion,
    cols: usize,
    s: f32,
    sink: &DisjointWriter<f32>,
    simd: bool,
) -> RelErrAccum {
    let mut acc = RelErrAccum::default();
    let width = b.c1 - b.c0;
    for r in b.r0..b.r1 {
        let start = r * cols + b.c0;
        let src = &xd[start..start + width];
        // Safety: partition blocks tile the tensor disjointly.
        let dst = unsafe { sink.slice_mut(start, width) };
        if simd {
            qdq_kernel::qdq_segment_scaled_simd(target, src, dst, s);
        } else {
            qdq_kernel::qdq_segment_scaled(target, src, dst, s);
        }
        for (v, q) in src.iter().zip(dst.iter()) {
            acc.add(*v, *q);
        }
    }
    acc
}

/// Phase-B body for one block under the **scalar** oracle: the original
/// per-element loop.
fn qdq_block_scalar(
    target: ReprType,
    xd: &[f32],
    b: &BlockRegion,
    cols: usize,
    s: f32,
    sink: &DisjointWriter<f32>,
) -> RelErrAccum {
    let mut acc = RelErrAccum::default();
    // De-scale by *division* (not multiply-by-reciprocal): this is
    // what the compiled kernel does, and the two differ in the last
    // f32 ulp — the cross-language tests require bit-equality.
    for idx in b.indices(cols) {
        let v = xd[idx];
        let q = qdq(target, v * s) / s;
        // Safety: partition blocks tile the tensor disjointly.
        unsafe { sink.write(idx, q) };
        acc.add(v, q);
    }
    acc
}

/// Per-block range scan: (amax, non-zero amin).
fn block_range_of(xd: &[f32], b: &BlockRegion, cols: usize) -> (f32, Option<f32>) {
    let mut amax = 0.0f32;
    let mut amin = f32::INFINITY;
    for idx in b.indices(cols) {
        let a = xd[idx].abs();
        amax = amax.max(a);
        if a != 0.0 {
            amin = amin.min(a);
        }
    }
    (amax, if amin.is_finite() { Some(amin) } else { None })
}

/// Fake-quantize `x` to `target` under `partition` + `scaling`, with the
/// process-global [`Parallelism`].
pub fn fake_quantize(
    x: &Tensor,
    target: ReprType,
    partition: Partition,
    scaling: ScalingAlgo,
) -> FakeQuantResult {
    fake_quantize_with(x, target, partition, scaling, &par::global())
}

/// Fake-quantize with an explicit [`Parallelism`] (benches and the
/// parallel≡serial equivalence tests).
///
/// The group for GAM is the entire tensor (the configuration the paper
/// uses throughout §4); blocks follow the partition. BF16 needs no
/// scaling (its range covers f32 training tensors), so the pipeline
/// degenerates to a bf16 round-trip with identity scales.
pub fn fake_quantize_with(
    x: &Tensor,
    target: ReprType,
    partition: Partition,
    scaling: ScalingAlgo,
    cfg: &Parallelism,
) -> FakeQuantResult {
    let (rows, cols) = x.as_2d();
    let blocks = partition.blocks(rows, cols);
    let xd = x.data();
    // Tiny tensors stay serial (the min-block-size cutoff).
    let cfg = cfg.gate(x.len());

    if target == ReprType::Bf16 {
        let mut out = x.clone();
        // BF16's round trip is branch-free bit manipulation, so both
        // kernel-layer modes run the same segment loop here.
        let kernel = cfg.kernel() != KernelMode::Scalar;
        let per_block: Vec<(RelErrAccum, (f32, Option<f32>))> = {
            let sink = DisjointWriter::new(out.data_mut());
            par::par_map(&cfg, blocks.len(), |bi| {
                let b = &blocks[bi];
                let mut acc = RelErrAccum::default();
                let mut amax = 0.0f32;
                let mut amin = f32::INFINITY;
                if kernel {
                    // Slice engine: per-row-segment bf16 round trip,
                    // then the stats replay in the same element order.
                    let width = b.c1 - b.c0;
                    for r in b.r0..b.r1 {
                        let start = r * cols + b.c0;
                        let src = &xd[start..start + width];
                        // Safety: partition blocks tile disjointly.
                        let dst = unsafe { sink.slice_mut(start, width) };
                        qdq_kernel::bf16_segment(src, dst);
                        for (v, q) in src.iter().zip(dst.iter()) {
                            acc.add(*v, *q);
                            let a = v.abs();
                            amax = amax.max(a);
                            if a != 0.0 {
                                amin = amin.min(a);
                            }
                        }
                    }
                } else {
                    for idx in b.indices(cols) {
                        let q = bf16::quantize_dequantize(xd[idx]);
                        // Safety: partition blocks tile the tensor disjointly.
                        unsafe { sink.write(idx, q) };
                        acc.add(xd[idx], q);
                        let a = xd[idx].abs();
                        amax = amax.max(a);
                        if a != 0.0 {
                            amin = amin.min(a);
                        }
                    }
                }
                (acc, (amax, if amin.is_finite() { Some(amin) } else { None }))
            })
        };
        let mut global = RelErrAccum::default();
        let mut block_err = Vec::with_capacity(blocks.len());
        let mut block_range = Vec::with_capacity(blocks.len());
        for (acc, range) in per_block {
            global.merge(acc);
            block_err.push(acc);
            block_range.push(range);
        }
        let scales = compute_scales_with(scaling, bf16::MAX, x.amax(), &[], &cfg);
        return FakeQuantResult { out, scales, block_err, global_err: global, block_range };
    }

    // Phase A — per-block amaxes (and M2 ranges) in partition order.
    let block_range: Vec<(f32, Option<f32>)> =
        par::par_map(&cfg, blocks.len(), |bi| block_range_of(xd, &blocks[bi], cols));
    let block_amaxes: Vec<f32> = block_range.iter().map(|r| r.0).collect();

    let q_amax = target.max_finite();
    let scales = compute_scales_with(scaling, q_amax, x.amax(), &block_amaxes, &cfg);

    // Phase B — scale, cast, de-scale per block; disjoint writes into
    // the output, per-block accumulators merged in canonical order.
    // The kernel engine runs the slice-level LUT QDQ per block row
    // segment (AVX2 lanes under `KernelMode::Simd`); the scalar oracle
    // keeps the per-element loop. Identical bits every way (parity
    // pinned in tests and `parallel_equivalence.rs`).
    let kernel = cfg.kernel() != KernelMode::Scalar;
    let simd = cfg.kernel() == KernelMode::Simd;
    let mut out = Tensor::zeros(x.shape());
    let block_err: Vec<RelErrAccum> = {
        let sink = DisjointWriter::new(out.data_mut());
        par::par_map(&cfg, blocks.len(), |bi| {
            let b = &blocks[bi];
            let s = scales.blocks[bi].scale;
            if kernel {
                qdq_block_kernel(target, xd, b, cols, s, &sink, simd)
            } else {
                qdq_block_scalar(target, xd, b, cols, s, &sink)
            }
        })
    };
    let mut global = RelErrAccum::default();
    for acc in &block_err {
        global.merge(*acc);
    }
    FakeQuantResult { out, scales, block_err, global_err: global, block_range }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    fn relerr_bound_for(t: ReprType) -> f64 {
        match t {
            // Half-ulp of the mantissa width, doubled for the (up to one
            // binade) scale slack of GAM/E8M0, plus subnormal effects near
            // the block minimum. Generous analytic bounds:
            ReprType::E4M3 => 0.07,  // 2^-4 ≈ 6.25%
            ReprType::E5M2 => 0.14,  // 2^-3 = 12.5%
            ReprType::Bf16 => 0.004, // 2^-8
            ReprType::NvFp4 => 0.5,
        }
    }

    #[test]
    fn exact_values_have_zero_error() {
        // Powers of two within a narrow range quantize exactly to E4M3
        // under amax scaling when amax itself is a power of two.
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 4.0, 0.5]);
        let r = fake_quantize(&x, ReprType::E4M3, Partition::Tensor, ScalingAlgo::AmaxFp32);
        assert_eq!(r.global_err.mean(), 0.0);
        assert_eq!(r.out, x);
    }

    #[test]
    fn bf16_target_is_roundtrip() {
        let x = Tensor::uniform(&[8, 8], 3.0, 11);
        let r = fake_quantize(&x, ReprType::Bf16, Partition::BLOCK128, ScalingAlgo::Gam);
        for (a, b) in x.data().iter().zip(r.out.data()) {
            assert_eq!(*b, bf16::quantize_dequantize(*a));
        }
        assert!(r.global_err.mean() < relerr_bound_for(ReprType::Bf16));
    }

    #[test]
    fn saturation_never_occurs_with_gam() {
        // A tensor with huge dynamic range; GAM must still keep every
        // scaled value <= 448 (no inf/nan in the output).
        let x = Tensor::from_vec(&[1, 6], vec![1e-8, 3e4, -2e4, 5.0, -1e-6, 2.9e4]);
        for p in [Partition::Tensor, Partition::Block { r: 1, c: 2 }] {
            let r = fake_quantize(&x, ReprType::E4M3, p, ScalingAlgo::Gam);
            for v in r.out.data() {
                assert!(v.is_finite(), "saturated: {v}");
            }
        }
    }

    #[test]
    fn finer_partitions_reduce_error() {
        // A tensor whose rows live at very different magnitudes: channel
        // partition must beat tensor partition on mean relative error.
        let mut data = Vec::new();
        for r in 0..8 {
            let mag = (10.0f32).powi(r - 4);
            for c in 0..16 {
                data.push(mag * (1.0 + 0.05 * c as f32) * if c % 2 == 0 { 1.0 } else { -1.0 });
            }
        }
        let x = Tensor::from_vec(&[8, 16], data);
        let e_tensor = fake_quantize(&x, ReprType::E4M3, Partition::Tensor, ScalingAlgo::Gam)
            .global_err
            .mean();
        let e_chan = fake_quantize(&x, ReprType::E4M3, Partition::ChannelRows, ScalingAlgo::Gam)
            .global_err
            .mean();
        assert!(
            e_chan < e_tensor,
            "channel {e_chan} should beat tensor {e_tensor}"
        );
    }

    /// The kernel engine (LUT QDQ over row segments) is bit-identical
    /// to the scalar oracle for every target/partition/scaling combo —
    /// the correctness backbone of the whole kernel layer.
    #[test]
    fn prop_kernel_engine_matches_scalar_oracle_bitwise() {
        prop(120, |g: &mut Gen| {
            let rows = g.usize_in(1, 30);
            let cols = g.usize_in(1, 30);
            let x = Tensor::from_vec(
                &[rows, cols],
                (0..rows * cols)
                    .map(|_| g.f32_in(-1.0, 1.0) * g.f32_log_uniform(1e-5, 1e4))
                    .collect(),
            );
            let t = *g.choose(&[
                ReprType::E4M3,
                ReprType::E5M2,
                ReprType::Bf16,
                ReprType::NvFp4,
            ]);
            let (br, bc) = (g.usize_in(1, 9), g.usize_in(1, 9));
            let p = *g.choose(&[
                Partition::Tensor,
                Partition::Block { r: br, c: bc },
                Partition::ChannelRows,
                Partition::ChannelCols,
                Partition::SubChannelRows { len: 1 + br % 5 },
            ]);
            let s = *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0]);
            let scalar = Parallelism::serial().with_kernel(KernelMode::Scalar);
            let a = fake_quantize_with(&x, t, p, s, &scalar);
            for mode in [KernelMode::Blocked, KernelMode::Simd] {
                let kernel = Parallelism::serial().with_kernel(mode);
                let b = fake_quantize_with(&x, t, p, s, &kernel);
                for (i, (u, v)) in a.out.data().iter().zip(b.out.data()).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "{t} {p:?} {s:?} {mode:?} element {i}");
                }
                assert_eq!(a.block_err, b.block_err);
                assert_eq!(a.global_err, b.global_err);
                assert_eq!(a.block_range, b.block_range);
                assert_eq!(a.scales.blocks, b.scales.blocks);
            }
            true
        });
    }

    /// Property: fake-quant output is finite and the global error is the
    /// merge of block errors, for all (type, partition, scaling) combos.
    #[test]
    fn prop_fakequant_wellformed() {
        prop(150, |g: &mut Gen| {
            let rows = g.usize_in(1, 24);
            let cols = g.usize_in(1, 24);
            let x = Tensor::from_vec(
                &[rows, cols],
                (0..rows * cols).map(|_| g.f32_in(-10.0, 10.0)).collect(),
            );
            let t = *g.choose(&[ReprType::E4M3, ReprType::E5M2, ReprType::Bf16]);
            let (br, bc) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let p = *g.choose(&[
                Partition::Tensor,
                Partition::Block { r: br, c: bc },
                Partition::ChannelRows,
                Partition::ChannelCols,
            ]);
            let s = *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0]);
            let r = fake_quantize(&x, t, p, s);
            assert!(r.out.data().iter().all(|v| v.is_finite()));
            let mut merged = RelErrAccum::default();
            for b in &r.block_err {
                merged.merge(*b);
            }
            assert!((merged.mean() - r.global_err.mean()).abs() < 1e-12);
            assert!(r.global_err.mean() < relerr_bound_for(t), "err {}", r.global_err.mean());
            true
        });
    }

    /// Property: zeros are preserved exactly (scale * 0 = 0 round-trips).
    #[test]
    fn prop_zeros_preserved() {
        prop(100, |g: &mut Gen| {
            let n = g.usize_in(4, 32);
            let mut data: Vec<f32> = (0..n).map(|_| g.f32_in(-5.0, 5.0)).collect();
            for i in (0..n).step_by(3) {
                data[i] = 0.0;
            }
            let x = Tensor::from_vec(&[1, n], data);
            let r = fake_quantize(&x, ReprType::E4M3, Partition::Tensor, ScalingAlgo::Gam);
            for (a, b) in x.data().iter().zip(r.out.data()) {
                if *a == 0.0 {
                    assert_eq!(*b, 0.0);
                }
            }
            true
        });
    }

    /// Property: per-tensor partition error >= per-channel error for the
    /// same scaling algo (finer granularity can only help on average).
    #[test]
    fn prop_granularity_ordering_blockwise_amax() {
        prop(60, |g: &mut Gen| {
            // Rows at different magnitudes to create range pressure.
            let rows = g.usize_in(2, 10);
            let cols = g.usize_in(2, 24);
            let mut data = Vec::with_capacity(rows * cols);
            let base = g.f32_log_uniform(1e-4, 1.0);
            for r in 0..rows {
                // Alternate rows ~18 binades apart: under the per-tensor
                // scale the small rows land in E4M3's flush-to-zero
                // region (relative error ≈ 1), while per-channel scaling
                // keeps them normal. (Relative error is scale-invariant
                // for *normal* values, so a modest spread would not
                // separate the strategies.)
                let mag = if r % 2 == 0 { base } else { base * 3e5 };
                for _ in 0..cols {
                    data.push(mag * g.f32_in(-1.0, 1.0));
                }
            }
            let x = Tensor::from_vec(&[rows, cols], data);
            let e_t = fake_quantize(&x, ReprType::E4M3, Partition::Tensor, ScalingAlgo::AmaxFp32)
                .global_err
                .sum;
            let e_c =
                fake_quantize(&x, ReprType::E4M3, Partition::ChannelRows, ScalingAlgo::AmaxFp32)
                    .global_err
                    .sum;
            // Allow tiny numeric slack: equality happens when rows share
            // magnitudes.
            e_c <= e_t * 1.02 + 1e-9
        });
    }
}
