//! Host-side quantization substrate: partition strategies (§3), the
//! fake-quantization pipeline (Fig. 4), and the relative-error metrics
//! (Eqs. 1–4) that drive MoR decisions.
//!
//! This is the bit-exact host mirror of the Pallas/JAX compute path; the
//! integration tests in `rust/tests/integration_quant.rs` run both on the
//! same inputs and require element-wise agreement.

pub mod error;
pub mod fake_quant;
pub mod partition;

pub use error::{block_relerr_sum, dynamic_range_fits_e5m2, mean_relative_error, RelErrAccum};
pub use fake_quant::{fake_quantize, FakeQuantResult};
pub use partition::{BlockRegion, Partition};
