//! The paper-reproduction report harness: one entry point per table and
//! figure of the evaluation section (`repro report <exp>`). See
//! DESIGN.md §5 for the experiment index.
//!
//! Runs are cached on disk: an experiment re-uses an existing run's
//! metrics/stats CSVs when present (delete `runs/` or pass `--fresh` to
//! recompute).

pub mod figures;
pub mod policies;
pub mod runs;
pub mod tables;

use crate::model::config::{ModelConfig, TrainConfig};
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::PathBuf;

/// Shared context for all report commands.
pub struct ReportCtx {
    pub runtime: Runtime,
    pub model: ModelConfig,
    /// Steps per training run (scaled-down stand-in for 1T tokens).
    pub steps: u64,
    pub out_dir: PathBuf,
    pub fresh: bool,
    pub quiet: bool,
    /// In-memory memoization of completed runs, shared across the
    /// experiments of one `report all` invocation (each training run is
    /// executed once with suite + stats and reused everywhere).
    pub(crate) run_cache:
        std::cell::RefCell<std::collections::HashMap<String, std::rc::Rc<runs::Run>>>,
}

impl ReportCtx {
    pub fn new(
        artifacts_dir: &std::path::Path,
        model: ModelConfig,
        steps: u64,
        out_dir: PathBuf,
    ) -> Result<ReportCtx> {
        // Shared auto-backend policy (PJRT when compiled artifacts
        // exist, else the host backend) — every experiment is runnable
        // without Python artifacts. The CLI resolves `--backend`
        // itself and uses `with_runtime`.
        Ok(Self::with_runtime(Runtime::auto(artifacts_dir, model)?, steps, out_dir))
    }

    /// Build a context around an already-selected runtime/backend.
    pub fn with_runtime(runtime: Runtime, steps: u64, out_dir: PathBuf) -> ReportCtx {
        let model = runtime.model;
        ReportCtx {
            runtime,
            model,
            steps,
            out_dir,
            fresh: false,
            quiet: false,
            run_cache: Default::default(),
        }
    }

    pub fn config(&self, id: u8) -> TrainConfig {
        match id {
            2 => TrainConfig::config2(self.steps),
            _ => TrainConfig::config1(self.steps),
        }
    }

    /// Dispatch an experiment by its paper id.
    pub fn run_experiment(&self, exp: &str) -> Result<()> {
        match exp {
            "table1" => tables::table1(self),
            "table2" => tables::table2(self),
            "table3" => tables::table3(self),
            "table4" => tables::table4(self),
            "fig5" => figures::loss_curves(self, 1),
            "fig6" => figures::loss_curves(self, 2),
            "fig7" => figures::suite_over_training(self),
            "fig8" => figures::ablation_loss_curves(self),
            "fig9" => figures::ablation_suite(self),
            "fig10" => figures::fallback_percentages(self),
            "fig11" => figures::heatmap_annotation(self),
            "fig12" => figures::heatmap_block(self, 1, false),
            "fig13" => figures::heatmap_block(self, 1, true),
            "fig14" => figures::heatmap_over_time(self),
            "fig15" => figures::heatmap_block(self, 2, false),
            "fig16" => figures::heatmap_block(self, 2, true),
            "fig17" => figures::heatmap_tensor_strategy(self),
            "fig18" => figures::heatmap_channel(self, false),
            "fig19" => figures::heatmap_channel(self, true),
            "fig20" => figures::subtensor_loss_curves(self),
            "fig21" => figures::subtensor_suite(self),
            // Beyond the paper: decision-policy comparison sweep
            // (threshold vs metric-budget vs static assignment).
            "policies" => policies::policies(self),
            "all" => {
                for e in [
                    "table1", "fig5", "fig6", "fig7", "table2", "fig8", "fig9", "table3",
                    "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
                    "fig18", "fig19", "fig20", "fig21", "table4",
                ] {
                    println!("\n================ {e} ================");
                    self.run_experiment(e)?;
                }
                Ok(())
            }
            _ => anyhow::bail!(
                "unknown experiment {exp:?} (try table1..4, fig5..fig21, policies, all)"
            ),
        }
    }
}
