//! Figure regenerators: ASCII renderings + CSV exports of every figure
//! in the paper's evaluation section (Figures 5–21).

use super::runs::{self, Run};
use super::ReportCtx;
use crate::coordinator::logging::ascii_chart;
use crate::mor::stats::TensorKey;
use anyhow::Result;

fn loss_series(runs: &[std::rc::Rc<Run>]) -> Vec<(String, Vec<(f64, f64)>)> {
    runs.iter()
        .map(|r| {
            (
                r.label.clone(),
                r.records
                    .iter()
                    .map(|rec| (rec.step as f64, rec.train_loss as f64))
                    .collect(),
            )
        })
        .collect()
}

fn val_series(runs: &[std::rc::Rc<Run>]) -> Vec<(String, Vec<(f64, f64)>)> {
    runs.iter()
        .map(|r| {
            (
                r.label.clone(),
                r.records
                    .iter()
                    .filter(|rec| rec.val_loss.is_finite())
                    .map(|rec| (rec.step as f64, rec.val_loss as f64))
                    .collect(),
            )
        })
        .collect()
}

fn norm_series(runs: &[std::rc::Rc<Run>]) -> Vec<(String, Vec<(f64, f64)>)> {
    runs.iter()
        .map(|r| {
            (
                r.label.clone(),
                r.records
                    .iter()
                    .map(|rec| (rec.step as f64, rec.param_norm as f64))
                    .collect(),
            )
        })
        .collect()
}

fn print_run_panels(title: &str, runs: &[std::rc::Rc<Run>]) {
    println!("{}", ascii_chart(&format!("{title} — training loss"), &loss_series(runs), 100, 20));
    println!("{}", ascii_chart(&format!("{title} — validation loss"), &val_series(runs), 100, 16));
    let chart = ascii_chart(&format!("{title} — parameter L2 norm"), &norm_series(runs), 100, 12);
    println!("{chart}");
}

/// Figures 5 / 6: loss + param-norm curves, partition strategies.
pub fn loss_curves(ctx: &ReportCtx, config_id: u8) -> Result<()> {
    let runs = runs::partition_runs(ctx, config_id, false)?;
    let fig = if config_id == 1 { 5 } else { 6 };
    print_run_panels(&format!("Figure {fig} (configuration {config_id})"), &runs);
    Ok(())
}

/// Figure 7: eval-suite accuracy over training, both configs.
pub fn suite_over_training(ctx: &ReportCtx) -> Result<()> {
    for config_id in [1u8, 2] {
        let runs = runs::partition_runs(ctx, config_id, true)?;
        let series: Vec<(String, Vec<(f64, f64)>)> = runs
            .iter()
            .map(|r| {
                (
                    r.label.clone(),
                    r.suite_history
                        .iter()
                        .map(|(s, sc)| (*s as f64, sc.mean_accuracy() as f64))
                        .collect(),
                )
            })
            .collect();
        println!(
            "{}",
            ascii_chart(
                &format!(
                    "Figure 7({config_id}) — eval-suite accuracy over training (MMLU substitute)"
                ),
                &series,
                100,
                16
            )
        );
    }
    Ok(())
}

/// Figure 8: ablation loss curves (config 1).
pub fn ablation_loss_curves(ctx: &ReportCtx) -> Result<()> {
    let mut all = Vec::new();
    for (label, artifact, th) in runs::ABLATION_VARIANTS {
        all.push(runs::run_variant(ctx, label, artifact, 1, th, false, false)?);
    }
    print_run_panels("Figure 8 (ablations, configuration 1)", &all);
    Ok(())
}

/// Figure 9: ablation eval-suite trajectories.
pub fn ablation_suite(ctx: &ReportCtx) -> Result<()> {
    let mut series = Vec::new();
    for (label, artifact, th) in runs::ABLATION_VARIANTS {
        let r = runs::run_variant(ctx, label, artifact, 1, th, true, false)?;
        series.push((
            r.label.clone(),
            r.suite_history
                .iter()
                .map(|(s, sc)| (*s as f64, sc.mean_accuracy() as f64))
                .collect(),
        ));
    }
    println!("{}", ascii_chart("Figure 9 — ablation eval-suite accuracy", &series, 100, 16));
    Ok(())
}

/// Figure 10: BF16 fallback percentages per strategy × config.
pub fn fallback_percentages(ctx: &ReportCtx) -> Result<()> {
    println!("Figure 10: percentage of tensors that fall back to BF16");
    println!("{:<12} {:>14} {:>14}", "strategy", "config 1", "config 2");
    for (label, artifact) in &runs::PARTITION_VARIANTS[1..] {
        let mut row = format!("{label:<12}");
        for config_id in [1u8, 2] {
            let r = runs::run_variant(ctx, label, artifact, config_id, 0.045, false, false)?;
            row.push_str(&format!(" {:>13.2}%", r.mean_fallback_pct()));
        }
        println!("{row}");
    }
    println!("(paper shape: channel < block < tensor; config2 > config1)");
    Ok(())
}

/// Figure 11: the histogram/heatmap annotation scheme.
pub fn heatmap_annotation(ctx: &ReportCtx) -> Result<()> {
    let _ = ctx;
    println!("Figure 11: relative-error histogram layout");
    println!("  x-axis: 12 bins of 0.5% relative error; first bin <0.5%, last bin >=5.5%");
    println!("  '|' marks the E4M3 threshold (4.5%): mass left of it quantizes to E4M3,");
    println!("  mass right of it falls back to BF16.");
    println!("  y-axis: decoder.layer.<n>.<module>.<linear>.<tensor>[.<direction>]");
    println!("  rows normalized to [0,1]; darker glyph = denser bin ( . : - = + * # @ )");
    Ok(())
}

fn layer_keys(
    layers: &[usize],
    tensors: &[&'static str],
    per_channel: bool,
) -> Vec<TensorKey> {
    let mut keys = Vec::new();
    for &l in layers {
        for linear in 0..4 {
            for &t in tensors {
                if per_channel {
                    for d in ["row", "col"] {
                        keys.push(TensorKey::new(l, linear, t, d));
                    }
                } else {
                    keys.push(TensorKey::new(l, linear, t, ""));
                }
            }
        }
    }
    keys
}

fn heatmap_for(
    ctx: &ReportCtx,
    label: &str,
    artifact: &str,
    config_id: u8,
    backward: bool,
    per_channel: bool,
    title: &str,
) -> Result<()> {
    let r = runs::run_variant(ctx, label, artifact, config_id, 0.045, false, true)?;
    let stats = r.stats.as_ref().expect("need_stats run must carry stats");
    let n = ctx.model.n_layers;
    let layers: Vec<usize> = if n <= 6 {
        (0..n).collect()
    } else {
        (0..3).chain(n - 3..n).collect()
    };
    let tensors: &[&'static str] = if backward { &["grad"] } else { &["input", "weight"] };
    let keys = layer_keys(&layers, tensors, per_channel);
    println!("{title}");
    println!("{}", stats.ascii_heatmap(&keys, 4.5));
    Ok(())
}

/// Figures 12/13 (config 1) and 15/16 (config 2): per-block heatmaps.
pub fn heatmap_block(ctx: &ReportCtx, config_id: u8, backward: bool) -> Result<()> {
    let fig = match (config_id, backward) {
        (1, false) => 12,
        (1, true) => 13,
        (2, false) => 15,
        _ => 16,
    };
    heatmap_for(
        ctx,
        "block",
        "train_mor_tensor_block",
        config_id,
        backward,
        false,
        &format!(
            "Figure {fig}: per-block MoR heatmap, {} pass, configuration {config_id}",
            if backward { "backward" } else { "forward" }
        ),
    )
}

/// Figure 14: first-layer histograms over training windows.
pub fn heatmap_over_time(ctx: &ReportCtx) -> Result<()> {
    let r = runs::run_variant(ctx, "block", "train_mor_tensor_block", 1, 0.045, false, true)?;
    let stats = r.stats.as_ref().unwrap();
    println!("Figure 14: first transformer block, histogram per training window");
    for key in [
        TensorKey::new(0, 3, "input", ""), // FC2 activation — the outlier
        TensorKey::new(0, 2, "grad", ""),  // FC1 gradient — the outlier
    ] {
        println!("tensor {}:", key.name());
        for w in 0..stats.num_windows() {
            if let Some(win) = stats.window_for(w, &key) {
                let norm = win.hist.normalized();
                let row: String = norm
                    .iter()
                    .map(|v| {
                        const SHADES: [char; 9] = [' ', '.', ':', '-', '=', '+', '*', '#', '@'];
                        SHADES[((v * 8.0).ceil() as usize).min(8)]
                    })
                    .collect();
                println!("  window {w:>2} |{row}|  fb={:.0}%", win.fallback_rate() * 100.0);
            }
        }
    }
    println!("(paper shape: relative error drifts right as training progresses)");
    Ok(())
}

/// Figure 17: per-tensor-strategy heatmap (middle layers).
pub fn heatmap_tensor_strategy(ctx: &ReportCtx) -> Result<()> {
    let r = runs::run_variant(ctx, "tensor", "train_mor_tensor_tensor", 1, 0.045, false, true)?;
    let stats = r.stats.as_ref().unwrap();
    let n = ctx.model.n_layers;
    let mid: Vec<usize> = (n / 3..(n / 3 + 3).min(n)).collect();
    println!("Figure 17: per-tensor strategy heatmap (middle layers, fwd+bwd)");
    let keys = layer_keys(&mid, &["input", "weight", "grad"], false);
    println!("{}", stats.ascii_heatmap(&keys, 4.5));
    Ok(())
}

/// Figures 18/19: per-channel heatmaps with row/col direction resolved.
pub fn heatmap_channel(ctx: &ReportCtx, backward: bool) -> Result<()> {
    heatmap_for(
        ctx,
        "channel",
        "train_mor_tensor_channel",
        1,
        backward,
        true,
        &format!(
            "Figure {}: per-channel heatmap ({} pass), row vs col partitions",
            if backward { 19 } else { 18 },
            if backward { "backward" } else { "forward" }
        ),
    )
}

/// Figure 20: sub-tensor loss curves.
pub fn subtensor_loss_curves(ctx: &ReportCtx) -> Result<()> {
    let mut all = Vec::new();
    for (label, artifact) in runs::SUBTENSOR_VARIANTS {
        all.push(runs::run_variant(ctx, label, artifact, 1, 0.045, false, false)?);
    }
    print_run_panels("Figure 20 (sub-tensor MoR, configuration 1)", &all);
    Ok(())
}

/// Figure 21: sub-tensor eval-suite trajectories.
pub fn subtensor_suite(ctx: &ReportCtx) -> Result<()> {
    let mut series = Vec::new();
    for (label, artifact) in runs::SUBTENSOR_VARIANTS {
        let r = runs::run_variant(ctx, label, artifact, 1, 0.045, true, false)?;
        series.push((
            r.label.clone(),
            r.suite_history
                .iter()
                .map(|(s, sc)| (*s as f64, sc.mean_accuracy() as f64))
                .collect(),
        ));
    }
    println!("{}", ascii_chart("Figure 21 — sub-tensor eval-suite accuracy", &series, 100, 16));
    Ok(())
}
