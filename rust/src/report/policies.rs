//! The policy comparison harness (`repro report policies`): one CLI
//! invocation sweeps decision policy × training task × recipe format
//! and tabulates quality (final train/val loss), decision behaviour
//! (BF16-fallback %, % of operands kept in FP8) and step latency.
//!
//! Every combination is an independent, fully-serial training run with
//! its own host [`Runtime`] (the policy layer is host-only; the PJRT
//! backend bakes the threshold decisions into its artifacts). The
//! sweep is the fleet scheduler's first real client: the 12 runs are
//! submitted as weighted tenants through
//! [`crate::coordinator::scheduler::run_fleet`], which multiplexes
//! them over the chunked engine with the same largest-first fair-share
//! machinery tensor work gets — results are bit-identical to the
//! serial sweep for any thread count. The [`super::runs`] cache is
//! deliberately bypassed: its keys do not carry a policy dimension.

use super::ReportCtx;
use crate::coordinator::scheduler::{self, run_fleet, FleetOptions, Tenant};
use crate::coordinator::trainer::TrainerOptions;
use crate::mor::policy;
use crate::util::par::Parallelism;
use anyhow::{anyhow, Context, Result};

/// The compared policy specs (parsed by [`policy::parse_policy`]):
/// the paper's dynamic threshold logic, the absolute relerr-budget
/// baseline, and the classic static per-class assignment.
pub const POLICY_VARIANTS: [(&str, &str); 3] = [
    ("threshold", "threshold"),
    ("metric", "metric=0.03"),
    ("static", "static=e4m3,e4m3,e5m2"),
];

/// The compared tasks: the §4.1 tensor-level recipe and the §4.2
/// three-way sub-tensor recipe (weight = relative cost estimate for
/// the sweep scheduler — sub-tensor runs fake-quantize two candidates).
pub const TASK_VARIANTS: [(&str, &str, usize); 2] = [
    ("tensor", "train_mor_tensor_block", 1),
    ("subtensor3", "train_mor_subtensor_three_way", 2),
];

/// One sweep result row.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: String,
    pub task: String,
    pub config_id: u8,
    pub final_train_loss: f32,
    pub final_val_loss: f32,
    pub fallback_pct: f32,
    /// Share of quantization decisions that kept an FP8 representation
    /// (the complement of the fallback share).
    pub fp8_pct: f32,
    pub mean_step_ms: f32,
}

impl PolicyRow {
    pub fn csv_line(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{}",
            self.policy,
            self.task,
            self.config_id,
            self.final_train_loss,
            self.final_val_loss,
            self.fallback_pct,
            self.fp8_pct,
            self.mean_step_ms
        )
    }
}

/// Execute the full policy × task × config sweep and return the rows
/// in declaration order (policy-major, then task, then config).
pub fn policy_sweep(ctx: &ReportCtx) -> Result<Vec<PolicyRow>> {
    let mut combos: Vec<(&str, &str, &str, &str, u8, usize)> = Vec::new();
    for (plabel, spec) in POLICY_VARIANTS {
        for (tlabel, artifact, tweight) in TASK_VARIANTS {
            for config_id in [1u8, 2] {
                combos.push((plabel, spec, tlabel, artifact, config_id, tweight));
            }
        }
    }

    let steps = ctx.steps;
    let sweep_dir = ctx.out_dir.join("policies");
    // Combination-level parallelism: each run is fully serial inside,
    // so any outer thread count reproduces the serial sweep bitwise.
    let outer = ctx.runtime.parallelism().clone();

    let tenants: Vec<Tenant> = combos
        .iter()
        .map(|&(plabel, spec, tlabel, artifact, config_id, tweight)| {
            // Every spec parses before any run starts.
            let policy = policy::parse_policy(Some(spec))
                .map_err(|msg| anyhow!("policy spec {spec:?} {msg}"))?
                .expect("non-empty spec parses to a policy");
            let cfg = match config_id {
                2 => crate::model::config::TrainConfig::config2(steps),
                _ => crate::model::config::TrainConfig::config1(steps),
            };
            let id = format!("{plabel}/{tlabel}/config{config_id}");
            let mut opts = TrainerOptions::new(
                artifact,
                steps,
                sweep_dir.join(plabel).join(format!("{tlabel}_config{config_id}")),
            );
            opts.quiet = true;
            opts.val_every = (steps / 4).max(1);
            opts.parallelism = Some(Parallelism::serial());
            opts.policy = Some(policy);
            Ok(Tenant::new(&id, ctx.model, cfg, opts)
                .with_weight(tweight * config_id as usize))
        })
        .collect::<Result<_>>()?;

    // Uninterrupted runs (quantum 0), as many resident as the pool has
    // threads (overridable via MOR_MAX_RUNS).
    let mut fleet_opts = FleetOptions::new(outer);
    fleet_opts.max_runs = scheduler::auto_max_runs(fleet_opts.max_runs);
    let fleet = run_fleet(&tenants, &fleet_opts)?;

    fleet
        .tenants
        .iter()
        .zip(&combos)
        .map(|(report, &(plabel, spec, tlabel, _, config_id, _))| {
            if let Some(e) = &report.error {
                return Err(anyhow!("{e}"))
                    .with_context(|| format!("policy sweep run {}", report.id));
            }
            let outcome = report
                .outcome
                .as_ref()
                .expect("a completed tenant carries its outcome");
            let n = outcome.records.len().max(1) as f32;
            let fallback_pct = outcome
                .records
                .iter()
                .map(|r| r.bf16_fallback_rate)
                .sum::<f32>()
                / n
                * 100.0;
            if !ctx.quiet {
                println!(
                    "  [policies] {plabel:<9} {tlabel:<10} config{config_id}: loss {:.4} fb {:.1}%",
                    outcome.final_train_loss, fallback_pct
                );
            }
            let described = policy::parse_policy(Some(spec))
                .expect("spec validated at tenant build time")
                .expect("non-empty spec parses to a policy")
                .describe();
            Ok(PolicyRow {
                policy: described,
                task: tlabel.to_string(),
                config_id,
                final_train_loss: outcome.final_train_loss,
                final_val_loss: outcome.final_val_loss,
                fallback_pct,
                fp8_pct: 100.0 - fallback_pct,
                mean_step_ms: outcome.mean_step_ms,
            })
        })
        .collect()
}

/// The `repro report policies` experiment: run the sweep, print the
/// comparison table, and persist `policies.csv` under the report
/// out-dir.
pub fn policies(ctx: &ReportCtx) -> Result<()> {
    println!(
        "Policy comparison: {} policies x {} tasks x 2 configs, {} steps each",
        POLICY_VARIANTS.len(),
        TASK_VARIANTS.len(),
        ctx.steps
    );
    let rows = policy_sweep(ctx)?;

    println!(
        "\n{:<22} {:<10} {:>6} {:>11} {:>9} {:>7} {:>7} {:>8}",
        "policy", "task", "config", "train_loss", "val_loss", "fb%", "fp8%", "step_ms"
    );
    for r in &rows {
        println!(
            "{:<22} {:<10} {:>6} {:>11.4} {:>9.4} {:>7.2} {:>7.2} {:>8.2}",
            r.policy,
            r.task,
            r.config_id,
            r.final_train_loss,
            r.final_val_loss,
            r.fallback_pct,
            r.fp8_pct,
            r.mean_step_ms
        );
    }

    std::fs::create_dir_all(&ctx.out_dir)?;
    let csv_path = ctx.out_dir.join("policies.csv");
    let mut csv = String::from(
        "policy,task,config,final_train_loss,final_val_loss,fallback_pct,fp8_pct,mean_step_ms\n",
    );
    for r in &rows {
        csv.push_str(&r.csv_line());
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv)?;
    println!("\nwrote {}", csv_path.display());
    Ok(())
}
