//! Run management for the report harness: each (artifact, config)
//! training run is executed once and cached under
//! `<out_dir>/runs/<artifact>.<config>.csv` (+ `.stats.csv`).

use super::ReportCtx;
use crate::coordinator::logging::{MetricsLogger, StepRecord};
use crate::coordinator::trainer::{Trainer, TrainerOptions};
use crate::mor::stats::StatsCollector;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// The artifact names of the §4.1.1 partition-strategy comparison.
pub const PARTITION_VARIANTS: [(&str, &str); 4] = [
    ("baseline", "train_baseline"),
    ("block", "train_mor_tensor_block"),
    ("tensor", "train_mor_tensor_tensor"),
    ("channel", "train_mor_tensor_channel"),
];

/// The §4.1.2 ablation variants (config 1 only).
pub const ABLATION_VARIANTS: [(&str, &str, f32); 6] = [
    ("bf16", "train_baseline", 0.045),
    ("block128", "train_mor_tensor_block", 0.045),
    ("block64", "train_mor_tensor_block64", 0.045),
    ("th5.0", "train_mor_tensor_block", 0.050),
    ("amax", "train_mor_tensor_block_amax", 0.045),
    ("e8m0", "train_mor_tensor_block_e8m0", 0.045),
];

/// The §4.2 sub-tensor variants (config 1 only).
pub const SUBTENSOR_VARIANTS: [(&str, &str); 3] = [
    ("bf16", "train_baseline"),
    ("two_way", "train_mor_subtensor_two_way"),
    ("three_way", "train_mor_subtensor_three_way"),
];

/// A completed (or loaded-from-cache) run.
#[derive(Clone)]
pub struct Run {
    pub label: String,
    pub artifact: String,
    pub config_id: u8,
    pub records: Vec<StepRecord>,
    /// Present only when the run executed in this process (stats CSV
    /// reload is not implemented; figures that need `stats` force a
    /// fresh run).
    pub stats: Option<StatsCollector>,
    pub suite_history: Vec<(u64, crate::coordinator::eval::EvalScores)>,
    pub csv_path: PathBuf,
}

impl Run {
    pub fn final_train_loss(&self) -> f32 {
        // Smooth over the last 10 steps to de-noise the tiny-scale runs.
        let n = self.records.len();
        let tail = &self.records[n.saturating_sub(10)..];
        tail.iter().map(|r| r.train_loss).sum::<f32>() / tail.len().max(1) as f32
    }

    pub fn final_val_loss(&self) -> f32 {
        self.records
            .iter()
            .rev()
            .find(|r| r.val_loss.is_finite())
            .map(|r| r.val_loss)
            .unwrap_or(f32::NAN)
    }

    pub fn final_param_norm(&self) -> f32 {
        self.records.last().map(|r| r.param_norm).unwrap_or(f32::NAN)
    }

    pub fn mean_fallback_pct(&self) -> f32 {
        let n = self.records.len().max(1) as f32;
        self.records.iter().map(|r| r.bf16_fallback_rate).sum::<f32>() / n * 100.0
    }
}

/// Execute (or load) one run. Each unique (artifact, config, threshold)
/// executes at most once per process — always with suite evals and
/// stats collection — and is memoized in [`ReportCtx::run_cache`]; the
/// disk CSV serves cross-process reuse for figures that need neither
/// suite nor stats.
pub fn run_variant(
    ctx: &ReportCtx,
    label: &str,
    artifact: &str,
    config_id: u8,
    threshold: f32,
    with_suite: bool,
    need_stats: bool,
) -> Result<std::rc::Rc<Run>> {
    let cfg = ctx.config(config_id);
    let runs_dir = ctx.out_dir.join("runs");
    let csv_path = runs_dir.join(format!("{artifact}.{}.th{threshold}.csv", cfg.name));
    let key = format!("{artifact}.{}.th{threshold}", cfg.name);

    if let Some(run) = ctx.run_cache.borrow().get(&key) {
        if (!need_stats || run.stats.is_some()) && (!with_suite || !run.suite_history.is_empty())
        {
            if run.label == label {
                return Ok(run.clone());
            }
            // Same run requested under a different display label
            // (e.g. "baseline" in Table 2 vs "bf16" in Table 3).
            let mut relabelled = (**run).clone();
            relabelled.label = label.to_string();
            return Ok(std::rc::Rc::new(relabelled));
        }
    }

    let disk_ok = !ctx.fresh && csv_path.exists() && !need_stats && !with_suite;
    if disk_ok {
        let records = MetricsLogger::read(&csv_path)?;
        if records.len() as u64 >= ctx.steps {
            let run = std::rc::Rc::new(Run {
                label: label.to_string(),
                artifact: artifact.to_string(),
                config_id,
                records,
                stats: None,
                suite_history: Vec::new(),
                csv_path,
            });
            // Do NOT memoize disk loads: a later suite/stats request
            // must be able to trigger the full run.
            return Ok(run);
        }
    }

    let trainer = Trainer::new(&ctx.runtime, cfg);
    let mut opts = TrainerOptions::new(artifact, ctx.steps, runs_dir.clone());
    opts.threshold = threshold;
    opts.quiet = ctx.quiet;
    // Always collect suite + stats so every experiment can share this run.
    opts.suite_every = (ctx.steps / 8).max(1);
    opts.stats_window = (ctx.steps / 4).max(1);
    opts.per_channel = artifact.contains("channel");
    let outcome = trainer
        .run(&opts)
        .with_context(|| format!("run {label} ({artifact}, {})", cfg.name))?;
    // Rename the trainer's CSV to the threshold-qualified cache name.
    if outcome.metrics_path != csv_path {
        std::fs::rename(&outcome.metrics_path, &csv_path).ok();
    }
    let run = std::rc::Rc::new(Run {
        label: label.to_string(),
        artifact: artifact.to_string(),
        config_id,
        records: outcome.records,
        stats: Some(outcome.stats),
        suite_history: outcome.suite_history,
        csv_path,
    });
    ctx.run_cache.borrow_mut().insert(key, run.clone());
    Ok(run)
}

/// Run the four §4.1.1 partition variants for one config.
pub fn partition_runs(
    ctx: &ReportCtx,
    config_id: u8,
    with_suite: bool,
) -> Result<Vec<std::rc::Rc<Run>>> {
    PARTITION_VARIANTS
        .iter()
        .map(|(label, artifact)| {
            run_variant(ctx, label, artifact, config_id, 0.045, with_suite, false)
        })
        .collect()
}
