//! Table regenerators: Tables 1–4 of the paper, printed in the same
//! row/column layout (absolute numbers reflect this testbed; the
//! *shape* — who wins, by how much — is the reproduction target).

use super::runs::{self, Run};
use super::ReportCtx;
use crate::data::synthetic::{CorpusProfile, SyntheticCorpus};
use crate::data::tasks::EvalTask;
use anyhow::Result;

/// Table 1: the two training configurations (plus measured corpus
/// entropy, our stand-in for "data quality").
pub fn table1(ctx: &ReportCtx) -> Result<()> {
    let c1 = ctx.config(1);
    let c2 = ctx.config(2);
    let mut e1 = SyntheticCorpus::new(CorpusProfile::Nemotron4Like, ctx.model.vocab_size, 1);
    let mut e2 = SyntheticCorpus::new(CorpusProfile::NemotronHLike, ctx.model.vocab_size, 1);
    println!("Table 1: training configurations (testbed-scaled)");
    println!("{:<24} {:>16} {:>16}", "Parameter", "Configuration 1", "Configuration 2");
    println!("{:<24} {:>16} {:>16}", "Training Data", "synthetic-N4", "synthetic-NH");
    println!(
        "{:<24} {:>16} {:>16}",
        "Corpus entropy (bits)",
        format!("{:.3}", e1.entropy_estimate(20000)),
        format!("{:.3}", e2.entropy_estimate(20000))
    );
    println!("{:<24} {:>16} {:>16}", "Training steps", ctx.steps, ctx.steps);
    println!("{:<24} {:>16} {:>16}", "LR Schedule", "Cosine", "Cosine");
    println!(
        "{:<24} {:>16.1e} {:>16.1e}",
        "Peak Learning Rate", c1.schedule.peak_lr, c2.schedule.peak_lr
    );
    println!(
        "{:<24} {:>16.1e} {:>16.1e}",
        "Final Learning Rate", c1.schedule.final_lr, c2.schedule.final_lr
    );
    println!("{:<24} {:>16} {:>16}", "Batch Size", c1.batch_size, c2.batch_size);
    Ok(())
}

fn print_quality_table(title: &str, runs: &[std::rc::Rc<Run>], scores: &[Vec<(String, f32)>]) {
    println!("{title}");
    print!("{:<18}", "Metric");
    for r in runs {
        print!(" {:>12}", r.label);
    }
    println!();
    print!("{:<18}", "Training Loss");
    for r in runs {
        print!(" {:>12.4}", r.final_train_loss());
    }
    println!();
    print!("{:<18}", "Validation Loss");
    for r in runs {
        print!(" {:>12.4}", r.final_val_loss());
    }
    println!();
    if !scores.is_empty() {
        // One row per eval task (the downstream-benchmark substitutes).
        let task_names: Vec<String> =
            scores[0].iter().map(|(n, _)| n.clone()).collect();
        for (ti, tname) in task_names.iter().enumerate() {
            print!("{:<18}", tname);
            for s in scores {
                print!(" {:>12.2}", s[ti].1);
            }
            println!();
        }
    }
    print!("{:<18}", "BF16 fallback %");
    for r in runs {
        print!(" {:>12.2}", r.mean_fallback_pct());
    }
    println!();
}

fn suite_scores(run: &std::rc::Rc<Run>) -> Vec<(String, f32)> {
    match run.suite_history.last() {
        Some((_, s)) => {
            let mut v: Vec<(String, f32)> = s
                .per_task
                .iter()
                .map(|(n, _, a)| (n.to_string(), *a))
                .collect();
            v.push(("mean_acc".to_string(), s.mean_accuracy()));
            v
        }
        None => EvalTask::ALL
            .iter()
            .map(|t| (t.name().to_string(), f32::NAN))
            .chain(std::iter::once(("mean_acc".to_string(), f32::NAN)))
            .collect(),
    }
}

/// Table 2: partition strategies × both configs, final quality.
pub fn table2(ctx: &ReportCtx) -> Result<()> {
    for config_id in [1u8, 2] {
        let runs = runs::partition_runs(ctx, config_id, true)?;
        let scores: Vec<_> = runs.iter().map(suite_scores).collect();
        print_quality_table(
            &format!("Table 2 (configuration {config_id}): partition strategies"),
            &runs,
            &scores,
        );
        println!();
    }
    Ok(())
}

/// Table 3: the §4.1.2 ablations (config 1).
pub fn table3(ctx: &ReportCtx) -> Result<()> {
    let mut all = Vec::new();
    for (label, artifact, th) in runs::ABLATION_VARIANTS {
        all.push(runs::run_variant(ctx, label, artifact, 1, th, true, false)?);
    }
    let scores: Vec<_> = all.iter().map(suite_scores).collect();
    print_quality_table("Table 3: MoR setting ablations (configuration 1)", &all, &scores);
    Ok(())
}

/// Table 4: sub-tensor recipes (config 1).
pub fn table4(ctx: &ReportCtx) -> Result<()> {
    let mut all = Vec::new();
    for (label, artifact) in runs::SUBTENSOR_VARIANTS {
        all.push(runs::run_variant(ctx, label, artifact, 1, 0.045, true, false)?);
    }
    let scores: Vec<_> = all.iter().map(suite_scores).collect();
    print_quality_table("Table 4: sub-tensor MoR algorithms (configuration 1)", &all, &scores);
    Ok(())
}
