//! `repro` — the MoR reproduction launcher.
//!
//! ```text
//! repro train  --artifact train_mor_tensor_block --config config1 --steps 200
//! repro eval   --ckpt runs/....ckpt
//! repro report table2 [--steps 200] [--model small] [--fresh]
//! repro quant  --artifact quant_e4m3_gam_block   # cross-check vs host mirror
//! repro info
//! ```
//!
//! All subcommands accept `--model {tiny,small,base}` (default small) and
//! `--artifacts <dir>` (default `artifacts/<model>`).

use anyhow::{bail, Context, Result};
use mor::coordinator::eval::eval_suite;
use mor::coordinator::trainer::{Trainer, TrainerOptions};
use mor::data::tasks::EvalSuite;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::model::naming::param_specs;
use mor::mor::policy;
use mor::report::ReportCtx;
use mor::runtime::{PolicyRef, Runtime};
use mor::util::cli::Args;
use mor::util::par::{self, Parallelism};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    par::set_global(parallelism_of(&args));
    if let Some(p) = policy_of(&args) {
        policy::set_global(p);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn model_of(args: &Args) -> Result<ModelConfig> {
    let name = args.get_or("model", "small");
    ModelConfig::preset(name).with_context(|| format!("unknown model preset {name:?}"))
}

fn artifacts_dir(args: &Args, model: &ModelConfig) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts").join(model.name))
}

/// `--threads N` (0 = autodetect) and `--par-min-block N` configure the
/// parallel chunked engine behind every quantization/GEMM hot path.
/// `--par-min-block` is parsed with the same strictness as
/// `MOR_THREADS` — `0`, empty or non-numeric values abort loudly — and
/// falls back to the `MOR_PAR_MIN_BLOCK` env var when the flag is
/// absent (the CI-tuning knob).
fn parallelism_of(args: &Args) -> Parallelism {
    let mut p = match args.usize("threads", 0) {
        0 => Parallelism::auto(),
        n => Parallelism::with_threads(n),
    };
    match par::parse_par_min_block(args.get("par-min-block")) {
        Ok(Some(n)) => p.min_items = n,
        Ok(None) => {
            if let Some(n) = par::env_min_items() {
                p.min_items = n;
            }
        }
        Err(msg) => {
            eprintln!("error: --par-min-block {msg}");
            std::process::exit(2);
        }
    }
    p
}

/// `--policy SPEC` selects the MoR decision policy for every run the
/// process starts. Parsed with the same strictness as the other knobs
/// (a malformed spec aborts loudly); when the flag is absent the
/// `MOR_POLICY` env var is consulted lazily by `policy::global()`, and
/// the default is the paper's threshold policy.
fn policy_of(args: &Args) -> Option<PolicyRef> {
    match policy::parse_policy(args.get("policy")) {
        Ok(opt) => opt,
        Err(msg) => {
            eprintln!("error: --policy {msg}");
            std::process::exit(2);
        }
    }
}

/// `--faults SPEC` installs a deterministic fault-injection schedule
/// for the run (chaos testing; host backend only). Strict like every
/// knob: a malformed spec aborts loudly; when the flag is absent the
/// `MOR_FAULTS` env var is consulted.
fn faults_of(args: &Args) -> Option<mor::faults::FaultSpec> {
    match args.get("faults") {
        Some(raw) => match mor::faults::parse_faults(Some(raw)) {
            Ok(opt) => opt,
            Err(msg) => {
                eprintln!("error: --faults {msg}");
                std::process::exit(2);
            }
        },
        None => mor::faults::auto(),
    }
}

/// `--guard SPEC` arms the numeric guard (skip-step → BF16 quarantine
/// → checkpoint rewind). `on`/`off` or a `k=v` list; malformed specs
/// abort loudly; absent flag falls back to `MOR_GUARD`.
fn guard_of(args: &Args) -> Option<mor::coordinator::guard::GuardConfig> {
    match args.get("guard") {
        Some(raw) => match mor::coordinator::guard::parse_guard(Some(raw)) {
            Ok(opt) => opt,
            Err(msg) => {
                eprintln!("error: --guard {msg}");
                std::process::exit(2);
            }
        },
        None => mor::coordinator::guard::auto(),
    }
}

/// `--ckpt-keep K` caps the checkpoint ring at the newest K files
/// (0/absent = keep everything). Falls back to `MOR_CKPT_KEEP`.
fn ckpt_keep_of(args: &Args) -> u64 {
    let (raw, prefix): (Option<String>, &str) = match args.get("ckpt-keep") {
        Some(v) => (Some(v.to_string()), "--ckpt-keep "),
        None => (mor::util::env::var("MOR_CKPT_KEEP"), "MOR_CKPT_KEEP "),
    };
    match mor::util::env::parse_pos_int(
        raw.as_deref(),
        prefix,
        "positive checkpoint count",
        "unset it to keep every checkpoint",
    ) {
        Ok(Some(n)) => n as u64,
        Ok(None) => 0,
        Err(msg) => {
            eprintln!("error: {msg}");
            std::process::exit(2);
        }
    }
}

/// Select the execution backend: `--backend pjrt` requires compiled
/// artifacts, `--backend host` runs the pure-Rust mirror, and the
/// default `auto` uses PJRT when the manifest exists and falls back to
/// the host backend otherwise. The runtime inherits the process
/// default [`Parallelism`] handle, which `main` already set from the
/// CLI flags — one shared pool for sessions and the no-argument entry
/// points alike.
fn runtime_of(args: &Args, model: ModelConfig) -> Result<Runtime> {
    let dir = artifacts_dir(args, &model);
    match args.get_or("backend", "auto") {
        "host" => Ok(Runtime::host(model)),
        "pjrt" => Runtime::load(&dir, model),
        "auto" => {
            if !dir.join("manifest.txt").exists() {
                eprintln!(
                    "note: no artifacts at {} — using the host execution backend",
                    dir.display()
                );
            }
            Runtime::auto(&dir, model)
        }
        other => bail!("unknown backend {other:?}; try auto/host/pjrt"),
    }
}

/// `--max-runs N` caps how many training runs the fleet scheduler
/// keeps resident per round (absent = the pool's thread count). Falls
/// back to `MOR_MAX_RUNS`.
fn max_runs_of(args: &Args, fallback: usize) -> usize {
    let (raw, prefix): (Option<String>, &str) = match args.get("max-runs") {
        Some(v) => (Some(v.to_string()), "--max-runs "),
        None => (mor::util::env::var("MOR_MAX_RUNS"), "MOR_MAX_RUNS "),
    };
    match mor::util::env::parse_pos_int(
        raw.as_deref(),
        prefix,
        "positive run count",
        "unset it to default to the pool width",
    ) {
        Ok(Some(n)) => n,
        Ok(None) => fallback,
        Err(msg) => {
            eprintln!("error: {msg}");
            std::process::exit(2);
        }
    }
}

/// `--retries N` sets the fleet supervisor's retry budget per tenant
/// per demotion rung. Falls back to `MOR_RETRIES`, then 3.
fn retries_of(args: &Args) -> u32 {
    let (raw, prefix): (Option<String>, &str) = match args.get("retries") {
        Some(v) => (Some(v.to_string()), "--retries "),
        None => (mor::util::env::var("MOR_RETRIES"), "MOR_RETRIES "),
    };
    match mor::util::env::parse_pos_int(
        raw.as_deref(),
        prefix,
        "positive retry count",
        "unset it to default to 3",
    ) {
        Ok(Some(n)) => n as u32,
        Ok(None) => 3,
        Err(msg) => {
            eprintln!("error: {msg}");
            std::process::exit(2);
        }
    }
}

/// `--stall-after N` sets how many consecutive no-progress slices the
/// stall watchdog tolerates. Falls back to `MOR_STALL_AFTER`, then 3.
fn stall_after_of(args: &Args) -> u32 {
    let (raw, prefix): (Option<String>, &str) = match args.get("stall-after") {
        Some(v) => (Some(v.to_string()), "--stall-after "),
        None => (mor::util::env::var("MOR_STALL_AFTER"), "MOR_STALL_AFTER "),
    };
    match mor::util::env::parse_pos_int(
        raw.as_deref(),
        prefix,
        "positive slice count",
        "unset it to default to 3",
    ) {
        Ok(Some(n)) => n as u32,
        Ok(None) => 3,
        Err(msg) => {
            eprintln!("error: {msg}");
            std::process::exit(2);
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("train") => cmd_train(args),
        Some("fleet") => cmd_fleet(args),
        Some("report") => cmd_report(args),
        Some("eval") => cmd_eval(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown command {other:?}; try train/fleet/report/eval/info"),
        None => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
repro — MoR (Mixture of Representations) reproduction launcher

USAGE:
  repro train  --artifact <name> [--config config1|config2] [--steps N]
               [--threshold 0.045] [--model tiny|small|base] [--out runs/]
               [--suite-every N] [--ckpt-every N] [--resume <ckpt>]
               [--auto-resume] [--ckpt-keep K] [--embed-metrics]
               [--quiet] [--policy SPEC] [--faults SPEC] [--guard SPEC]
  repro fleet  --tenants N [--weights W0,W1,...] [--quantum Q] [--max-runs M]
               [--artifact <name>] [--config ...] [--steps N] [--out runs/fleet]
               [--ckpt-every N] [--guard SPEC] [--faults SPEC] [--adaptive]
               [--retries N] [--backoff R] [--stall-after N] [--auto-resume]
  repro eval   [--model ...] [--artifact eval] (evaluates fresh init or --ckpt)
  repro report <table1|table2|table3|table4|fig5..fig21|policies|all>
               [--steps N] [--model ...] [--out report/] [--fresh] [--quiet]
  repro info   [--model ...]

Common options:
  --backend auto|host|pjrt   execution backend (default auto: PJRT when
                             artifacts exist, else the pure-Rust host mirror)
  --threads N                worker threads for the parallel engine (0 = auto;
                             MOR_THREADS env var also respected)
  --par-min-block N          tensors below N elements stay serial
  --policy SPEC              MoR decision policy: threshold (paper default),
                             metric[=BUDGET] or static[=INPUT,WEIGHT,GRAD];
                             MOR_POLICY env var also respected. Non-threshold
                             policies need the host backend. `repro report
                             policies` compares all three on two tasks.

Robustness options (train):
  --faults SPEC              deterministic fault injection for chaos runs
                             (host backend only; MOR_FAULTS env var also
                             respected): `;`-separated entries from
                             nan:grad@step=N, nan:weight@step=N,
                             inf:grad@step=N, inf:weight@step=N,
                             bitflip:block@p=P, panic:worker@step=N,
                             repeat-panic:worker@step=N,count=K,
                             stall:step@step=N, torn-save@ckpt=K. Seeded
                             from the training seed — bitwise
                             reproducible at any --threads.
  --guard SPEC               numeric guard (MOR_GUARD): `on`, `off` or
                             skip=K,quarantine=N,rewinds=R,spike=F.
                             Escalates skip-step → BF16
                             quarantine → rewind to the last good
                             checkpoint; interventions land in
                             <artifact>.<config>.guard.csv. Fault-free
                             guarded runs are bitwise-identical to
                             unguarded ones.
  --ckpt-keep K              keep only the newest K ring checkpoints
                             (MOR_CKPT_KEEP; default: keep all)
  --auto-resume              resume from the newest loadable checkpoint in
                             --out, walking past corrupt/torn files
                             (mutually exclusive with --resume)

Fleet options (fleet):
  --tenants N                concurrent training runs to multiplex (each in
                             runs/fleet/tenant<i>; host backend)
  --weights W0,W1,...        fair-share weights, one per tenant (default all
                             1); slice share converges to weight/sum(weights)
  --quantum Q                steps per scheduling slice; 0 (default) runs each
                             tenant to completion uninterrupted. Q > 0
                             suspends tenants at Q-step boundaries through the
                             checkpoint ring — bitwise identical to solo runs.
  --max-runs M               tenants resident per round (MOR_MAX_RUNS;
                             default: the pool's thread count)
  --adaptive                 shrink slice quanta while more tenants are
                             runnable than --max-runs slots (scheduling only;
                             trajectories stay bitwise-identical)
  --retries N                supervisor retry budget per tenant per demotion
                             rung (MOR_RETRIES; default 3)
  --backoff R                base backoff in scheduler rounds, doubling per
                             retry (default 1)
  --stall-after N            consecutive no-progress slices before the stall
                             watchdog trips (MOR_STALL_AFTER; default 3)
  --auto-resume              restart a crashed fleet from <out>/fleet.manifest
                             (tenant rings resume regardless; the manifest
                             restores the scheduler/supervisor ledger so the
                             resumed interleaving is bitwise-continuous)
  --faults SPEC              injected into tenant 0 only — a containment demo:
                             the other tenants must finish unperturbed.
                             A failing tenant walks the supervisor ladder:
                             retry w/ backoff → BF16 quarantine + widened
                             guard → scalar kernels → dead

Checkpoint/resume: `--ckpt-every N` writes a full MORCKPT2 training
checkpoint (params, Adam moments, data cursors, RNG streams, scaling
histories, stats, a metrics row-count+hash digest — `--embed-metrics`
stores the full row history instead) every N completed steps;
`--resume <ckpt>` continues such a run, replaying the metrics prefix
from the original run's metrics.csv after verifying it against the
digest. Pass the run's TOTAL --steps (not the remaining count): a
resumed run is bitwise identical to the uninterrupted one — params,
metrics rows (minus wall-clock step_ms) and MoR decision fractions —
at any --threads setting.

PJRT artifacts are built with `make artifacts [MODEL=small]`; without
them every command still runs on the host backend.";

fn cmd_train(args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let runtime = runtime_of(args, model)?;
    let steps = args.u64("steps", 100);
    let config = TrainConfig::by_name(args.get_or("config", "config1"), steps)
        .context("--config must be config1 or config2")?;
    let artifact = args.get_or("artifact", "train_mor_tensor_block").to_string();
    let mut opts =
        TrainerOptions::new(&artifact, steps, PathBuf::from(args.get_or("out", "runs")));
    opts.threshold = args.f32("threshold", 0.045);
    opts.val_every = args.u64("val-every", 20);
    opts.suite_every = args.u64("suite-every", 0);
    opts.ckpt_every = args.u64("ckpt-every", 0);
    opts.resume = args.get("resume").map(PathBuf::from);
    opts.auto_resume = args.flag("auto-resume");
    opts.ckpt_keep = ckpt_keep_of(args);
    opts.faults = faults_of(args);
    opts.guard = guard_of(args);
    opts.embed_metrics = args.flag("embed-metrics");
    opts.stats_window = args.u64("stats-window", (steps / 4).max(1));
    opts.per_channel = artifact.contains("channel");
    opts.quiet = args.flag("quiet");
    // Explicit per-run policy override; when --policy is absent this
    // stays None and the run inherits the runtime default (the
    // process-global one, which main() set from the same flag).
    opts.policy = policy_of(args);
    // opts.parallelism stays None: the run inherits the runtime's
    // handle, which is the process-global one main() set from the CLI
    // flags — one pool end to end.
    let trainer = Trainer::new(&runtime, config);
    let outcome = trainer.run(&opts)?;
    println!(
        "done: final train loss {:.4}, val loss {:.4}, mean step {:.0} ms, metrics at {}",
        outcome.final_train_loss,
        outcome.final_val_loss,
        outcome.mean_step_ms,
        outcome.metrics_path.display()
    );
    println!(
        "BF16 fallback (aggregate): {:.2}% of tensor decisions",
        outcome.stats.overall_fallback_pct()
    );
    Ok(())
}

/// Multiplex N training runs over one shared pool via the fleet
/// scheduler (host backend; see `coordinator::scheduler`).
fn cmd_fleet(args: &Args) -> Result<()> {
    use mor::coordinator::scheduler::{run_fleet, FleetOptions, Tenant};
    use mor::coordinator::supervisor::SupervisorOptions;
    let model = model_of(args)?;
    let steps = args.u64("steps", 100);
    let n = args.usize("tenants", 2);
    if n == 0 {
        bail!("--tenants must be >= 1");
    }
    let config = TrainConfig::by_name(args.get_or("config", "config1"), steps)
        .context("--config must be config1 or config2")?;
    let artifact = args.get_or("artifact", "train_mor_tensor_block").to_string();
    let out = PathBuf::from(args.get_or("out", "runs/fleet"));
    let weights: Vec<usize> = match args.get("weights") {
        None => vec![1; n],
        Some(raw) => {
            let ws: Vec<usize> = raw
                .split(',')
                .map(|w| match w.trim().parse::<usize>() {
                    Ok(v) if v >= 1 => Ok(v),
                    _ => bail!("--weights entries must be integers >= 1, got {w:?}"),
                })
                .collect::<Result<_>>()?;
            if ws.len() != n {
                bail!("--weights has {} entries for {n} tenants", ws.len());
            }
            ws
        }
    };
    let faults = faults_of(args);
    let guard = guard_of(args);
    let policy = policy_of(args);
    let mut fleet_opts = FleetOptions::new(parallelism_of(args));
    fleet_opts.max_runs = max_runs_of(args, fleet_opts.max_runs);
    fleet_opts.quantum = args.u64("quantum", 0);
    fleet_opts.quiet = args.flag("quiet");
    fleet_opts.adaptive = args.flag("adaptive");
    // The fleet always runs supervised from the CLI: retry/backoff,
    // the degradation ladder, the stall watchdog, and a crash-safe
    // manifest in the fleet out dir (`--auto-resume` restarts a
    // crashed fleet from it, bitwise).
    let mut so = SupervisorOptions::new();
    so.retries = retries_of(args);
    so.backoff = args.u64("backoff", 1);
    so.stall_after = stall_after_of(args);
    so.manifest = Some(out.join("fleet.manifest"));
    so.auto_resume = args.flag("auto-resume");
    fleet_opts.supervisor = Some(so);
    let tenants: Vec<Tenant> = (0..n)
        .map(|i| {
            let id = format!("tenant{i}");
            let mut o = TrainerOptions::new(&artifact, steps, out.join(&id));
            o.threshold = args.f32("threshold", 0.045);
            o.val_every = args.u64("val-every", 20);
            o.ckpt_every = args.u64("ckpt-every", 0);
            o.ckpt_keep = ckpt_keep_of(args);
            o.stats_window = args.u64("stats-window", (steps / 4).max(1));
            o.per_channel = artifact.contains("channel");
            o.guard = guard;
            o.policy = policy.clone();
            o.quiet = true;
            // The fault schedule targets tenant 0 only: the point of
            // a chaos fleet is watching the neighbors stay clean.
            if i == 0 {
                o.faults = faults.clone();
            }
            Tenant::new(&id, model, config, o).with_weight(weights[i])
        })
        .collect();
    let fleet = run_fleet(&tenants, &fleet_opts)?;
    print!("{}", fleet.summary_table());
    let csv_path = out.join("fleet_summary.csv");
    std::fs::create_dir_all(&out)?;
    std::fs::write(&csv_path, fleet.summary_csv())
        .with_context(|| format!("writing {}", csv_path.display()))?;
    println!("summary csv at {}", csv_path.display());
    println!(
        "{} tenants over {} rounds ({} slices, max {} resident, quantum {})",
        n,
        fleet.rounds,
        fleet.schedule.len(),
        fleet_opts.max_runs,
        fleet_opts.quantum
    );
    if fleet.tenants.iter().all(|t| !t.completed()) {
        bail!("every tenant failed");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let exp = args
        .positional
        .first()
        .map(|s| s.as_str())
        .context("report needs an experiment id (table1..4, fig5..fig21, all)")?;
    let mut ctx = ReportCtx::with_runtime(
        runtime_of(args, model)?,
        args.u64("steps", 120),
        PathBuf::from(args.get_or("out", "report")),
    );
    ctx.fresh = args.flag("fresh");
    ctx.quiet = !args.flag("verbose");
    ctx.run_experiment(exp)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = model_of(args)?;
    let runtime = runtime_of(args, model)?;
    // Evaluate either a checkpoint or a fresh initialization (sanity
    // baseline: suite accuracy at chance level).
    let mut session = runtime.train_session(
        args.get_or("artifact", "train_baseline"),
        args.u64("seed", 1234),
    )?;
    if let Some(ckpt) = args.get("ckpt") {
        let ck = mor::coordinator::checkpoint::Checkpoint::load(&PathBuf::from(ckpt))?;
        let specs = param_specs(&model);
        let params: Vec<_> = specs
            .iter()
            .map(|s| {
                ck.get(&s.name)
                    .cloned()
                    .with_context(|| format!("checkpoint missing {}", s.name))
            })
            .collect::<Result<_>>()?;
        session.set_params(&params)?;
        println!("loaded checkpoint at step {}", ck.step);
    }
    let ev = runtime.eval_session("eval")?;
    let suite = EvalSuite::new(model.seq_len, model.vocab_size, 8, 0xE7A1);
    let scores = eval_suite(&ev, session.params_ref(), &suite)?;
    println!("{:<10} {:>10} {:>10}", "task", "loss", "acc %");
    for (name, loss, acc) in &scores.per_task {
        println!("{name:<10} {loss:>10.4} {acc:>10.2}");
    }
    println!("mean accuracy: {:.2}%", scores.mean_accuracy());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = model_of(args)?;
    println!("model preset {}: {model:?}", model.name);
    println!("parameters: {}", model.num_params());
    println!("flops/token (6N): {}", model.flops_per_token());
    let dir = artifacts_dir(args, &model);
    match Runtime::load(&dir, model) {
        Ok(rt) => {
            println!("artifacts at {} (manifest ok):", dir.display());
            for a in &rt.manifest.artifacts {
                println!("  {:<36} {:?}", a.name, a.kind);
            }
        }
        Err(e) => {
            println!("artifacts not loadable from {}: {e:#}", dir.display());
            let host = Runtime::host(model);
            println!("host backend provides:");
            for a in &host.manifest.artifacts {
                println!("  {:<36} {:?}", a.name, a.kind);
            }
        }
    }
    let p = parallelism_of(args);
    println!(
        "parallel engine: {} threads ({:?}), serial below {} elements",
        p.threads,
        p.engine(),
        p.min_items
    );
    Ok(())
}
