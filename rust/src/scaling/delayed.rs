//! Delayed vs current scaling — the prior-art scaling strategies the
//! paper builds on (§1: "[10] and [9] suggested current and delayed
//! per-tensor scaling"). Implemented as a baseline comparator for the
//! MoR recipes: *current* scaling uses this step's amax (what the rest
//! of this repo does); *delayed* scaling derives the scale from a
//! sliding history of recent amaxes, trading one fewer reduction on the
//! critical path for staleness — and, unlike GAM, it can saturate when
//! the live amax exceeds the history.

use crate::formats::e8m0::E8M0;
use crate::scaling::BlockScale;

/// Sliding amax history for one tensor (delayed scaling state).
#[derive(Debug, Clone)]
pub struct AmaxHistory {
    window: usize,
    history: std::collections::VecDeque<f32>,
}

impl AmaxHistory {
    /// `window` = number of recent steps to remember (Transformer-Engine
    /// style default is 1024; tests use small windows).
    pub fn new(window: usize) -> Self {
        AmaxHistory { window: window.max(1), history: Default::default() }
    }

    /// Record the amax observed this step.
    pub fn push(&mut self, amax: f32) {
        if self.history.len() == self.window {
            self.history.pop_front();
        }
        self.history.push_back(amax);
    }

    /// The delayed amax: max over the recorded history (None until the
    /// first push — callers fall back to current scaling for step 0).
    pub fn delayed_amax(&self) -> Option<f32> {
        self.history.iter().cloned().reduce(f32::max)
    }

    /// Delayed per-tensor scale for a target format max `q_amax`.
    pub fn delayed_scale(&self, q_amax: f32) -> Option<BlockScale> {
        let amax = self.delayed_amax()?;
        if amax <= 0.0 || !amax.is_finite() {
            return Some(BlockScale::IDENTITY);
        }
        let s = q_amax / amax;
        Some(BlockScale { scale: s, stored_exp: E8M0::from_scale_floor(s) })
    }

    /// Whether applying the delayed scale to a tensor with live amax
    /// `current_amax` would saturate (scaled beyond q_amax) — the
    /// failure mode GAM's round-down rule eliminates by construction.
    pub fn would_saturate(&self, current_amax: f32, q_amax: f32) -> bool {
        match self.delayed_scale(q_amax) {
            Some(b) => current_amax * b.scale > q_amax,
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }

    /// The configured window size (checkpoint metadata).
    pub fn window(&self) -> usize {
        self.window
    }

    /// The recorded amaxes, oldest first — the checkpointable state of
    /// the stream.
    pub fn values(&self) -> impl Iterator<Item = f32> + '_ {
        self.history.iter().copied()
    }

    /// Rebuild a history from checkpointed (window, values). Values
    /// beyond the window are dropped oldest-first, exactly as if they
    /// had been `push`ed in order.
    pub fn from_values(window: usize, values: &[f32]) -> Self {
        let mut h = AmaxHistory::new(window);
        for &v in values {
            h.push(v);
        }
        h
    }
}

impl PartialEq for AmaxHistory {
    fn eq(&self, other: &Self) -> bool {
        self.window == other.window
            && self.history.len() == other.history.len()
            && self
                .history
                .iter()
                .zip(other.history.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    #[test]
    fn empty_history_has_no_scale() {
        let h = AmaxHistory::new(4);
        assert!(h.delayed_amax().is_none());
        assert!(h.delayed_scale(448.0).is_none());
        assert!(!h.would_saturate(10.0, 448.0));
    }

    #[test]
    fn window_slides() {
        let mut h = AmaxHistory::new(3);
        for a in [10.0, 20.0, 5.0] {
            h.push(a);
        }
        assert_eq!(h.delayed_amax(), Some(20.0));
        h.push(1.0); // evicts 10.0
        h.push(2.0); // evicts 20.0
        assert_eq!(h.delayed_amax(), Some(5.0));
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn delayed_scale_maps_history_amax_to_qmax() {
        let mut h = AmaxHistory::new(8);
        h.push(7.0);
        h.push(14.0);
        let s = h.delayed_scale(448.0).unwrap();
        assert_eq!(s.scale * 14.0, 448.0);
    }

    #[test]
    fn saturation_when_live_amax_exceeds_history() {
        let mut h = AmaxHistory::new(4);
        h.push(10.0);
        // Live tensor grows beyond everything the history saw.
        assert!(h.would_saturate(25.0, 448.0));
        assert!(!h.would_saturate(9.0, 448.0));
        assert!(!h.would_saturate(10.0, 448.0)); // exactly at amax: ok
    }

    #[test]
    fn values_roundtrip_rebuilds_history() {
        let mut h = AmaxHistory::new(3);
        for a in [4.0, 8.0, 2.0, 1.0] {
            h.push(a); // 4.0 evicted
        }
        let vals: Vec<f32> = h.values().collect();
        assert_eq!(vals, vec![8.0, 2.0, 1.0]);
        let back = AmaxHistory::from_values(h.window(), &vals);
        assert_eq!(back, h);
        assert_eq!(back.delayed_amax(), h.delayed_amax());
        // Oversized value lists fold down exactly like live pushes.
        let folded = AmaxHistory::from_values(2, &[9.0, 5.0, 3.0]);
        assert_eq!(folded.values().collect::<Vec<_>>(), vec![5.0, 3.0]);
    }

    #[test]
    fn zero_history_gives_identity() {
        let mut h = AmaxHistory::new(2);
        h.push(0.0);
        assert_eq!(h.delayed_scale(448.0), Some(BlockScale::IDENTITY));
    }

    /// Property: delayed scaling never saturates on *monotonically
    /// non-increasing* amax sequences, and the delayed scale is always
    /// <= the current-scaling scale (staleness only under-scales when
    /// ranges shrink, over-scales when they grow).
    #[test]
    fn prop_delayed_vs_current() {
        prop(300, |g: &mut Gen| {
            let mut h = AmaxHistory::new(g.usize_in(1, 8));
            let mut amax = g.f32_log_uniform(1e-3, 1e3);
            for _ in 0..g.usize_in(1, 20) {
                h.push(amax);
                // Non-increasing sequence.
                amax *= g.f32_in(0.5, 1.0);
            }
            // Current tensor has amax <= history max → no saturation.
            assert!(!h.would_saturate(amax, 448.0));
            let delayed = h.delayed_scale(448.0).unwrap().scale;
            let current = 448.0 / amax;
            assert!(delayed <= current * (1.0 + 1e-6));
            true
        });
    }

    /// Property: on growing ranges delayed scaling saturates while GAM
    /// (recomputed each step) never does — the quantitative version of
    /// why the paper recomputes scales per mini-batch.
    #[test]
    fn prop_growth_saturates_delayed_not_gam() {
        prop(200, |g: &mut Gen| {
            let base = g.f32_log_uniform(1e-2, 1e2);
            let mut h = AmaxHistory::new(4);
            h.push(base);
            let grown = base * g.f32_in(1.5, 100.0);
            assert!(h.would_saturate(grown, 448.0));
            // GAM on the live tensor: scale * amax <= q_amax always.
            let s = crate::scaling::gam::compute(448.0, grown, &[grown]);
            assert!(grown * s.blocks[0].scale <= 448.0 * (1.0 + 1e-6));
            true
        });
    }
}
