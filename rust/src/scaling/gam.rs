//! Group Amax Mantissa scaling — Algorithm 1 of the paper, verbatim.
//!
//! For a group g with blocks {b}:
//! ```text
//! g_amax = max(abs(g));          s_g = q_amax / g_amax;   m_g = mantissa(s_g)
//! for each block b:
//!     b_amax = max(abs(b));      s_b = q_amax / b_amax;   m_b = mantissa(s_b)
//!     e_b = exponent(s_b)            if m_g <= m_b
//!         = exponent(s_b) - 1        otherwise   // round down: no saturation
//! reconstructed scale for b = m_g * 2^e_b
//! ```
//!
//! The stored artifacts are exactly what §2 describes: **one 23-bit
//! mantissa per group** (we keep it as the f32 `m_g` in [1,2)) and **one
//! 8-bit E8M0 exponent per block**.
//!
//! Invariant (proved by `prop_gam_*` below): for every non-empty block,
//! `s_ideal/2 < m_g * 2^e_b <= s_ideal` where `s_ideal = q_amax/b_amax`.
//! The upper bound is what prevents saturation; the lower bound says GAM
//! wastes less than one binade of range versus ideal scaling.

use super::{BlockScale, GroupScales, ScalingAlgo};
use crate::formats::e8m0::{exp2i, frexp1, E8M0};
use crate::util::par::{self, Parallelism};

/// Run Algorithm 1 for one group (serial).
pub fn compute(q_amax: f32, group_amax: f32, block_amaxes: &[f32]) -> GroupScales {
    compute_with(q_amax, group_amax, block_amaxes, &Parallelism::serial())
}

/// Run Algorithm 1 for one group, chunking the per-block map across
/// workers. Block scales are mutually independent given `m_g`, so the
/// result is bit-identical to the serial path.
pub fn compute_with(
    q_amax: f32,
    group_amax: f32,
    block_amaxes: &[f32],
    cfg: &Parallelism,
) -> GroupScales {
    if group_amax == 0.0 || !group_amax.is_finite() {
        // Degenerate group (all zeros): identity scales throughout.
        return GroupScales {
            group_mantissa: 1.0,
            blocks: vec![BlockScale::IDENTITY; block_amaxes.len()],
            algo: ScalingAlgo::Gam,
        };
    }
    let s_g = q_amax / group_amax;
    let (m_g, _e_g) = frexp1(s_g);
    let blocks = par::par_map(cfg, block_amaxes.len(), |i| {
        let ba = block_amaxes[i];
        if ba == 0.0 || !ba.is_finite() {
            return BlockScale::IDENTITY;
        }
        let s_b = q_amax / ba;
        let (m_b, e_b) = frexp1(s_b);
        let e = if m_g <= m_b { e_b } else { e_b - 1 };
        let stored = E8M0::from_exponent(e);
        BlockScale { scale: m_g * stored.to_f32(), stored_exp: stored }
    });
    GroupScales { group_mantissa: m_g, blocks, algo: ScalingAlgo::Gam }
}

/// Reconstruct a block scale from stored metadata — the "on-the-fly"
/// combination step of §2 (shared mantissa × per-block exponent).
pub fn reconstruct(group_mantissa: f32, stored_exp: E8M0) -> f32 {
    group_mantissa * exp2i(stored_exp.exponent())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    const Q: f32 = 448.0;

    #[test]
    fn group_block_identical_amax_gives_ideal_scale() {
        // When a block's amax equals the group amax, m_b == m_g and the
        // reconstruction is exactly the ideal scale.
        let g = compute(Q, 7.3, &[7.3]);
        let ideal = Q / 7.3;
        assert!((g.blocks[0].scale - ideal).abs() <= ideal * 1e-6);
    }

    #[test]
    fn mantissa_is_shared_and_in_unit_binade() {
        let g = compute(Q, 12.0, &[12.0, 5.0, 0.25, 3.7]);
        assert!((1.0..2.0).contains(&g.group_mantissa));
        for b in &g.blocks {
            // scale / 2^e == m_g exactly for every block.
            let m = b.scale / exp2i(b.stored_exp.exponent());
            assert_eq!(m, g.group_mantissa);
        }
    }

    #[test]
    fn round_down_case_triggers() {
        // Pick amaxes so m_g > m_b for some block: group amax 3.0 →
        // s_g=149.33 → m_g≈1.1667 ; block amax 4.0 → s_b=112 → m_b=1.75
        // (m_g < m_b, no round-down); block amax 3.5 → s_b=128 → m_b=1.0
        // (m_g > m_b → exponent drops by 1).
        let g = compute(Q, 3.0, &[3.5]);
        let s_ideal = Q / 3.5; // 128 = 1.0 * 2^7
        assert!(g.blocks[0].scale <= s_ideal);
        assert!(g.blocks[0].scale > s_ideal / 2.0);
        // exponent must be 6 (=7-1)
        assert_eq!(g.blocks[0].stored_exp.exponent(), 6);
    }

    #[test]
    fn reconstruct_matches_compute() {
        let g = compute(Q, 9.0, &[9.0, 1.0, 0.001]);
        for b in &g.blocks {
            assert_eq!(reconstruct(g.group_mantissa, b.stored_exp), b.scale);
        }
    }

    /// Property: never saturates, never wastes a full binade.
    #[test]
    fn prop_gam_bounded_by_ideal() {
        prop(1000, |g: &mut Gen| {
            let group_amax = g.f32_log_uniform(1e-20, 1e20);
            let nblocks = g.usize_in(1, 16);
            // Block amaxes are <= group amax by construction.
            let amaxes: Vec<f32> =
                (0..nblocks).map(|_| group_amax * g.f32_in(1e-6, 1.0)).collect();
            let s = compute(Q, group_amax, &amaxes);
            for (ba, b) in amaxes.iter().zip(&s.blocks) {
                let ideal = Q / ba;
                // E8M0 exponent clamping can only round further down, so
                // the no-saturation direction always holds:
                assert!(
                    b.scale <= ideal * (1.0 + 1e-6),
                    "saturation: amax={ba} scale={} ideal={ideal}",
                    b.scale
                );
                // Range-waste bound holds whenever the exponent wasn't
                // clamped at the E8M0 range ends.
                if b.stored_exp.exponent().abs() < 127 {
                    assert!(
                        b.scale > ideal / 2.0,
                        "waste: amax={ba} scale={} ideal={ideal}",
                        b.scale
                    );
                }
            }
            true
        });
    }

    /// Property: scaled block amax always lands in (q_amax/2, q_amax].
    #[test]
    fn prop_scaled_amax_in_top_binade() {
        prop(1000, |g: &mut Gen| {
            let group_amax = g.f32_log_uniform(1e-10, 1e10);
            let amaxes: Vec<f32> = (0..g.usize_in(1, 8))
                .map(|_| group_amax * g.f32_in(0.01, 1.0))
                .collect();
            let s = compute(Q, group_amax, &amaxes);
            for (ba, b) in amaxes.iter().zip(&s.blocks) {
                let v = ba * b.scale;
                assert!(v <= Q * (1.0 + 1e-6), "v={v}");
                assert!(v > Q / 2.0 * (1.0 - 1e-6), "v={v}");
            }
            true
        });
    }

    /// Property: group mantissa consistency — every reconstructed scale
    /// divided by its power-of-two is the same mantissa (the §2
    /// "Consistent Mantissa Operations" benefit).
    #[test]
    fn prop_consistent_mantissa() {
        prop(500, |g: &mut Gen| {
            let group_amax = g.f32_log_uniform(1e-5, 1e5);
            let amaxes: Vec<f32> = (0..g.usize_in(2, 12))
                .map(|_| group_amax * g.f32_in(0.001, 1.0))
                .collect();
            let s = compute(Q, group_amax, &amaxes);
            for b in &s.blocks {
                let m = b.scale / exp2i(b.stored_exp.exponent());
                assert!((m - s.group_mantissa).abs() < 1e-12);
            }
            true
        });
    }
}
