//! Scale-factor computation: the paper's **Group Amax Mantissa (GAM)**
//! algorithm (Alg. 1) plus the two baselines it is ablated against in
//! §4.1.2 — plain per-block FP32 amax scaling and pure E8M0 scaling.
//!
//! All three map a block's absolute maximum toward the target format's
//! maximum representable value (`q_amax`); they differ in how the scale
//! factor itself is represented:
//!
//! | algo      | per-block metadata | scale value                         |
//! |-----------|--------------------|-------------------------------------|
//! | FP32 amax | 32-bit f32         | exactly `q_amax / b_amax`           |
//! | E8M0      | 8-bit exponent     | `2^floor(log2(q_amax / b_amax))`    |
//! | GAM       | 8-bit exponent (+ one 23-bit group mantissa) | `m_g * 2^(e_b [-1])` |
//!
//! GAM's key invariant, enforced by the round-down step and verified by
//! property tests: the reconstructed scale never exceeds the ideal scale,
//! so scaling can never push a block's amax past `q_amax` (no
//! saturation), and it stays within one binade of ideal:
//! `s_ideal / 2 < s_gam <= s_ideal`.

pub mod delayed;
pub mod gam;

use crate::formats::e8m0::{floor_log2, E8M0};
use crate::util::par::{self, Parallelism};

/// Which scale-factor algorithm to use (CLI/manifest name in comments).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScalingAlgo {
    /// `gam` — Group Amax Mantissa (Alg. 1), the paper's proposal.
    Gam,
    /// `amax` — standard per-block FP32 amax scaling.
    AmaxFp32,
    /// `e8m0` — per-block power-of-two scaling (micro-scaling style).
    E8M0,
}

impl ScalingAlgo {
    pub fn name(self) -> &'static str {
        match self {
            ScalingAlgo::Gam => "gam",
            ScalingAlgo::AmaxFp32 => "amax",
            ScalingAlgo::E8M0 => "e8m0",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gam" => Some(ScalingAlgo::Gam),
            "amax" => Some(ScalingAlgo::AmaxFp32),
            "e8m0" => Some(ScalingAlgo::E8M0),
            _ => None,
        }
    }

    /// Per-block metadata cost in bits (excluding group-level metadata).
    pub fn block_metadata_bits(self) -> u32 {
        match self {
            ScalingAlgo::Gam => 8,
            ScalingAlgo::AmaxFp32 => 32,
            ScalingAlgo::E8M0 => 8,
        }
    }
}

/// A computed per-block scale: the f32 value applied to the data, plus
/// the stored representation (for metadata-accounting and exact
/// reconstruction tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockScale {
    /// The scale multiplied into the block before the fp8 cast.
    pub scale: f32,
    /// Stored exponent (E8M0) for GAM / E8M0 algos; unused for FP32 amax.
    pub stored_exp: E8M0,
}

impl BlockScale {
    /// Identity scale for all-zero blocks (nothing to preserve).
    pub const IDENTITY: BlockScale = BlockScale { scale: 1.0, stored_exp: E8M0(127) };
}

/// Scales for a whole group of blocks, plus group metadata.
#[derive(Debug, Clone)]
pub struct GroupScales {
    /// The shared group mantissa `m_g` in [1, 2) (GAM) or 1.0 (E8M0) or
    /// NaN marker (FP32 amax, where no group component exists).
    pub group_mantissa: f32,
    pub blocks: Vec<BlockScale>,
    pub algo: ScalingAlgo,
}

impl GroupScales {
    /// Total metadata bits for this group (Sec. 2 "Negligible Overhead").
    pub fn metadata_bits(&self) -> u64 {
        let group_bits = match self.algo {
            ScalingAlgo::Gam => 23, // one FP32 mantissa for the group
            _ => 0,
        };
        group_bits + self.blocks.len() as u64 * self.algo.block_metadata_bits() as u64
    }
}

/// Compute per-block scales with the selected algorithm, using the
/// process-global [`Parallelism`].
///
/// `q_amax` is the target format's max finite value, `group_amax` the
/// amax over the whole group, `block_amaxes` the per-block amaxes
/// (zero entries mark all-zero blocks and get [`BlockScale::IDENTITY`]).
pub fn compute_scales(
    algo: ScalingAlgo,
    q_amax: f32,
    group_amax: f32,
    block_amaxes: &[f32],
) -> GroupScales {
    compute_scales_with(algo, q_amax, group_amax, block_amaxes, &par::global())
}

/// [`compute_scales`] with an explicit [`Parallelism`]. Per-block scale
/// derivation is independent, so the block list is chunked across
/// workers; results come back in block order and are bit-identical to
/// the serial path.
pub fn compute_scales_with(
    algo: ScalingAlgo,
    q_amax: f32,
    group_amax: f32,
    block_amaxes: &[f32],
    cfg: &Parallelism,
) -> GroupScales {
    // The per-block work is a handful of flops; only fan out for very
    // large block lists.
    let cfg = cfg.gate(block_amaxes.len());
    match algo {
        ScalingAlgo::Gam => gam::compute_with(q_amax, group_amax, block_amaxes, &cfg),
        ScalingAlgo::AmaxFp32 => {
            let blocks = par::par_map(&cfg, block_amaxes.len(), |i| {
                let ba = block_amaxes[i];
                if ba == 0.0 || !ba.is_finite() {
                    BlockScale::IDENTITY
                } else {
                    let s = q_amax / ba;
                    BlockScale { scale: s, stored_exp: E8M0::from_scale_floor(s) }
                }
            });
            GroupScales { group_mantissa: f32::NAN, blocks, algo }
        }
        ScalingAlgo::E8M0 => {
            let blocks = par::par_map(&cfg, block_amaxes.len(), |i| {
                let ba = block_amaxes[i];
                if ba == 0.0 || !ba.is_finite() {
                    BlockScale::IDENTITY
                } else {
                    let e = floor_log2(q_amax / ba);
                    let stored = E8M0::from_exponent(e);
                    BlockScale { scale: stored.to_f32(), stored_exp: stored }
                }
            });
            GroupScales { group_mantissa: 1.0, blocks, algo }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q: f32 = 448.0; // E4M3

    #[test]
    fn amax_scaling_is_exact() {
        let s = compute_scales(ScalingAlgo::AmaxFp32, Q, 10.0, &[10.0, 5.0, 2.5]);
        assert_eq!(s.blocks[0].scale, 44.8);
        assert_eq!(s.blocks[1].scale, 89.6);
        assert_eq!(s.blocks[2].scale, 179.2);
        // amax scaling maps each block amax exactly onto q_amax.
        for (ba, b) in [10.0f32, 5.0, 2.5].iter().zip(&s.blocks) {
            assert_eq!(ba * b.scale, Q);
        }
    }

    #[test]
    fn e8m0_scaling_is_pow2_and_never_saturates() {
        let amaxes = [10.0f32, 5.0, 2.5, 0.1, 447.9, 448.0, 1000.0];
        let s = compute_scales(ScalingAlgo::E8M0, Q, 1000.0, &amaxes);
        for (ba, b) in amaxes.iter().zip(&s.blocks) {
            let sc = b.scale;
            assert_eq!(sc, b.stored_exp.to_f32());
            assert!(ba * sc <= Q, "amax {ba} scaled to {}", ba * sc);
            assert!(ba * sc > Q / 2.0, "amax {ba} scaled only to {}", ba * sc);
        }
    }

    #[test]
    fn zero_blocks_get_identity() {
        for algo in [ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0] {
            let s = compute_scales(algo, Q, 3.0, &[3.0, 0.0]);
            assert_eq!(s.blocks[1], BlockScale::IDENTITY);
        }
    }

    #[test]
    fn metadata_accounting() {
        let s = compute_scales(ScalingAlgo::Gam, Q, 1.0, &[1.0; 10]);
        assert_eq!(s.metadata_bits(), 23 + 10 * 8);
        let s = compute_scales(ScalingAlgo::AmaxFp32, Q, 1.0, &[1.0; 10]);
        assert_eq!(s.metadata_bits(), 320);
        let s = compute_scales(ScalingAlgo::E8M0, Q, 1.0, &[1.0; 10]);
        assert_eq!(s.metadata_bits(), 80);
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in [ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0] {
            assert_eq!(ScalingAlgo::parse(a.name()), Some(a));
        }
    }
}
