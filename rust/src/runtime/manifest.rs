//! The artifact manifest — the ABI contract between `python/compile/
//! aot.py` (writer) and the Rust runtime (reader). A deliberately simple
//! line-oriented format (no JSON dependency offline):
//!
//! ```text
//! manifest_version 1
//! model small
//! vocab_size 256
//! d_model 256
//! n_layers 4
//! n_heads 4
//! d_ff 1024
//! seq_len 128
//! artifact train_mor_tensor_block
//!   file train_mor_tensor_block.hlo.txt
//!   kind train
//!   recipe tensor_level
//!   partition block128x128
//!   scaling gam
//!   batch 8
//!   num_params 20
//!   stats_len 192
//! end
//! ```
//!
//! Parameter ordering is *not* listed per artifact: both sides derive it
//! from [`crate::model::naming::param_specs`], and `check_model`
//! cross-validates the embedded model dims against the Rust preset.

use crate::model::config::ModelConfig;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What a compiled executable does, which fixes its input/output ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Inputs: params..., m..., v..., tokens, step, lr, threshold.
    /// Outputs: new_params..., new_m..., new_v..., loss, relerr, fallback.
    Train,
    /// Inputs: params..., tokens, mask. Outputs: loss, acc.
    Eval,
    /// Inputs: one tensor (+ threshold). Outputs: qdq tensor, relerr.
    Quant,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "train" => Ok(ArtifactKind::Train),
            "eval" => Ok(ArtifactKind::Eval),
            "quant" => Ok(ArtifactKind::Quant),
            _ => bail!("unknown artifact kind {s:?}"),
        }
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    /// Free-form recipe fields (recipe/partition/scaling/threshold/...).
    pub fields: BTreeMap<String, String>,
}

impl ArtifactEntry {
    pub fn field(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.field(key)
            .ok_or_else(|| anyhow!("artifact {} missing field {key}", self.name))?
            .parse()
            .with_context(|| format!("artifact {} field {key}", self.name))
    }
}

/// A parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub model_name: String,
    pub model_fields: BTreeMap<String, usize>,
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (artifact files are
    /// resolved relative to it).
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut version = 0u32;
        let mut model_name = String::new();
        let mut model_fields = BTreeMap::new();
        let mut artifacts = Vec::new();
        let mut current: Option<ArtifactEntry> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line.split_once(' ').unwrap_or((line, ""));
            let err = |m: &str| anyhow!("manifest line {}: {m}: {raw:?}", lineno + 1);
            match key {
                "manifest_version" => version = value.parse().map_err(|_| err("bad version"))?,
                "model" => model_name = value.to_string(),
                "artifact" => {
                    if current.is_some() {
                        bail!(err("artifact without closing 'end'"));
                    }
                    current = Some(ArtifactEntry {
                        name: value.to_string(),
                        file: PathBuf::new(),
                        kind: ArtifactKind::Quant,
                        fields: BTreeMap::new(),
                    });
                }
                "end" => {
                    let a = current.take().ok_or_else(|| err("stray 'end'"))?;
                    if a.file.as_os_str().is_empty() {
                        bail!("artifact {} missing 'file'", a.name);
                    }
                    artifacts.push(a);
                }
                _ => {
                    if let Some(a) = current.as_mut() {
                        match key {
                            "file" => a.file = dir.join(value),
                            "kind" => a.kind = ArtifactKind::parse(value)?,
                            _ => {
                                a.fields.insert(key.to_string(), value.to_string());
                            }
                        }
                    } else if let Ok(v) = value.parse::<usize>() {
                        model_fields.insert(key.to_string(), v);
                    } else {
                        bail!(err("unrecognized top-level line"));
                    }
                }
            }
        }
        if current.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        Ok(Manifest {
            version,
            model_name,
            model_fields,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                let known: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                anyhow!("artifact {name:?} not in manifest (have: {known:?})")
            })
    }

    /// Verify the manifest's embedded model dims match the Rust preset —
    /// the guard against ABI drift between the two languages.
    pub fn check_model(&self, m: &ModelConfig) -> Result<()> {
        if self.model_name != m.name {
            bail!("manifest model {:?} != expected {:?}", self.model_name, m.name);
        }
        let expect = [
            ("vocab_size", m.vocab_size),
            ("d_model", m.d_model),
            ("n_layers", m.n_layers),
            ("n_heads", m.n_heads),
            ("d_ff", m.d_ff),
            ("seq_len", m.seq_len),
        ];
        for (k, v) in expect {
            match self.model_fields.get(k) {
                Some(got) if *got == v => {}
                Some(got) => bail!("manifest {k}={got} but preset {} has {v}", m.name),
                None => bail!("manifest missing model field {k}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
manifest_version 1
model tiny
vocab_size 256
d_model 64
n_layers 2
n_heads 2
d_ff 256
seq_len 64
artifact train_baseline
  file train_baseline.hlo.txt
  kind train
  recipe baseline
  batch 8
  num_params 20
  stats_len 96
end
artifact quant_e4m3_gam
  file quant_e4m3_gam.hlo.txt
  kind quant
  rows 64
  cols 64
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.artifacts.len(), 2);
        let t = m.get("train_baseline").unwrap();
        assert_eq!(t.kind, ArtifactKind::Train);
        assert_eq!(t.usize_field("batch").unwrap(), 8);
        assert_eq!(t.file, Path::new("/tmp/a/train_baseline.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn model_check_passes_and_fails() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.check_model(&ModelConfig::TINY).is_ok());
        assert!(m.check_model(&ModelConfig::SMALL).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("manifest_version 2\nmodel x\n", Path::new(".")).is_err());
        assert!(Manifest::parse(
            "manifest_version 1\nartifact a\n  kind train\n",
            Path::new(".")
        )
        .is_err()); // no file + unterminated
        assert!(Manifest::parse("manifest_version 1\nend\n", Path::new(".")).is_err());
        assert!(Manifest::parse("manifest_version 1\nwhat is this\n", Path::new(".")).is_err());
    }
}
