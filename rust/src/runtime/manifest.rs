//! The artifact manifest — the ABI contract between `python/compile/
//! aot.py` (writer) and the Rust runtime (reader). A deliberately simple
//! line-oriented format (no JSON dependency offline):
//!
//! ```text
//! manifest_version 1
//! model small
//! vocab_size 256
//! d_model 256
//! n_layers 4
//! n_heads 4
//! d_ff 1024
//! seq_len 128
//! artifact train_mor_tensor_block
//!   file train_mor_tensor_block.hlo.txt
//!   kind train
//!   recipe tensor_level
//!   partition block128x128
//!   scaling gam
//!   batch 8
//!   num_params 20
//!   stats_len 192
//! end
//! ```
//!
//! Parameter ordering is *not* listed per artifact: both sides derive it
//! from [`crate::model::naming::param_specs`], and `check_model`
//! cross-validates the embedded model dims against the Rust preset.

use crate::model::config::ModelConfig;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What a compiled executable does, which fixes its input/output ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Inputs: params..., m..., v..., tokens, step, lr, threshold.
    /// Outputs: new_params..., new_m..., new_v..., loss, relerr, fallback.
    Train,
    /// Inputs: params..., tokens, mask. Outputs: loss, acc.
    Eval,
    /// Inputs: one tensor (+ threshold). Outputs: qdq tensor, relerr.
    Quant,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "train" => Ok(ArtifactKind::Train),
            "eval" => Ok(ArtifactKind::Eval),
            "quant" => Ok(ArtifactKind::Quant),
            _ => bail!("unknown artifact kind {s:?}"),
        }
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    /// Free-form recipe fields (recipe/partition/scaling/threshold/...).
    pub fields: BTreeMap<String, String>,
}

impl ArtifactEntry {
    pub fn field(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.field(key)
            .ok_or_else(|| anyhow!("artifact {} missing field {key}", self.name))?
            .parse()
            .with_context(|| format!("artifact {} field {key}", self.name))
    }
}

/// A parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub model_name: String,
    pub model_fields: BTreeMap<String, usize>,
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (artifact files are
    /// resolved relative to it).
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut version = 0u32;
        let mut model_name = String::new();
        let mut model_fields = BTreeMap::new();
        let mut artifacts = Vec::new();
        let mut current: Option<ArtifactEntry> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line.split_once(' ').unwrap_or((line, ""));
            let err = |m: &str| anyhow!("manifest line {}: {m}: {raw:?}", lineno + 1);
            match key {
                "manifest_version" => version = value.parse().map_err(|_| err("bad version"))?,
                "model" => model_name = value.to_string(),
                "artifact" => {
                    if current.is_some() {
                        bail!(err("artifact without closing 'end'"));
                    }
                    current = Some(ArtifactEntry {
                        name: value.to_string(),
                        file: PathBuf::new(),
                        kind: ArtifactKind::Quant,
                        fields: BTreeMap::new(),
                    });
                }
                "end" => {
                    let a = current.take().ok_or_else(|| err("stray 'end'"))?;
                    if a.file.as_os_str().is_empty() {
                        bail!("artifact {} missing 'file'", a.name);
                    }
                    artifacts.push(a);
                }
                _ => {
                    if let Some(a) = current.as_mut() {
                        match key {
                            "file" => a.file = dir.join(value),
                            "kind" => a.kind = ArtifactKind::parse(value)?,
                            _ => {
                                a.fields.insert(key.to_string(), value.to_string());
                            }
                        }
                    } else if let Ok(v) = value.parse::<usize>() {
                        model_fields.insert(key.to_string(), v);
                    } else {
                        bail!(err("unrecognized top-level line"));
                    }
                }
            }
        }
        if current.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        Ok(Manifest {
            version,
            model_name,
            model_fields,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                let known: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                anyhow!("artifact {name:?} not in manifest (have: {known:?})")
            })
    }

    /// Synthetic manifest for the host execution backend: the standard
    /// train/eval/quant artifact set with the recipe fields the AOT
    /// writer would embed, but no HLO files — `Runtime::host` executes
    /// these via `runtime::host` instead of PJRT.
    pub fn host_synthetic(m: &ModelConfig) -> Manifest {
        // Batch size of the synthetic host artifacts matches the tiny
        // AOT artifacts so tests/benches behave alike on both paths.
        const HOST_BATCH: usize = 8;
        const QUANT_ROWS: usize = 256;
        const QUANT_COLS: usize = 256;

        let mut model_fields = BTreeMap::new();
        for (k, v) in [
            ("vocab_size", m.vocab_size),
            ("d_model", m.d_model),
            ("n_layers", m.n_layers),
            ("n_heads", m.n_heads),
            ("d_ff", m.d_ff),
            ("seq_len", m.seq_len),
        ] {
            model_fields.insert(k.to_string(), v);
        }
        let num_params = crate::model::naming::param_specs(m).len();
        let stats_len = crate::model::naming::QuantTensorId::count(m);

        let field =
            |entries: &[(&str, String)]| -> BTreeMap<String, String> {
                entries.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
            };
        let train = |name: &str, recipe: &str, partition: &str, scaling: &str| ArtifactEntry {
            name: name.to_string(),
            file: PathBuf::from("<host>"),
            kind: ArtifactKind::Train,
            fields: field(&[
                ("backend", "host".to_string()),
                ("recipe", recipe.to_string()),
                ("partition", partition.to_string()),
                ("scaling", scaling.to_string()),
                ("batch", HOST_BATCH.to_string()),
                ("num_params", num_params.to_string()),
                ("stats_len", stats_len.to_string()),
            ]),
        };
        let mut artifacts = vec![
            train("train_baseline", "baseline", "tensor", "gam"),
            train("train_mor_tensor_block", "tensor_level", "block128x128", "gam"),
            train("train_mor_tensor_block64", "tensor_level", "block64x64", "gam"),
            train("train_mor_tensor_tensor", "tensor_level", "tensor", "gam"),
            train("train_mor_tensor_channel", "tensor_level", "channel", "gam"),
            train("train_mor_tensor_block_amax", "tensor_level", "block128x128", "amax"),
            train("train_mor_tensor_block_e8m0", "tensor_level", "block128x128", "e8m0"),
            train("train_mor_subtensor_two_way", "subtensor2", "block128x128", "gam"),
            train("train_mor_subtensor_three_way", "subtensor3", "block128x128", "gam"),
        ];
        artifacts.push(ArtifactEntry {
            name: "eval".to_string(),
            file: PathBuf::from("<host>"),
            kind: ArtifactKind::Eval,
            fields: field(&[
                ("backend", "host".to_string()),
                ("batch", HOST_BATCH.to_string()),
            ]),
        });
        for (name, format, partition, scaling) in [
            ("quant_e4m3_gam_block128", "e4m3", "block128x128", "gam"),
            ("quant_e4m3_gam_block64", "e4m3", "block64x64", "gam"),
            ("quant_e4m3_gam_tensor", "e4m3", "tensor", "gam"),
            ("quant_e4m3_gam_channel_rows", "e4m3", "channel_rows", "gam"),
            ("quant_e4m3_gam_channel_cols", "e4m3", "channel_cols", "gam"),
            ("quant_e4m3_amax_block128", "e4m3", "block128x128", "amax"),
            ("quant_e4m3_e8m0_block128", "e4m3", "block128x128", "e8m0"),
            ("quant_e5m2_gam_block128", "e5m2", "block128x128", "gam"),
        ] {
            artifacts.push(ArtifactEntry {
                name: name.to_string(),
                file: PathBuf::from("<host>"),
                kind: ArtifactKind::Quant,
                fields: field(&[
                    ("backend", "host".to_string()),
                    ("format", format.to_string()),
                    ("partition", partition.to_string()),
                    ("scaling", scaling.to_string()),
                    ("rows", QUANT_ROWS.to_string()),
                    ("cols", QUANT_COLS.to_string()),
                ]),
            });
        }
        Manifest {
            version: 1,
            model_name: m.name.to_string(),
            model_fields,
            artifacts,
            dir: PathBuf::from("."),
        }
    }

    /// Verify the manifest's embedded model dims match the Rust preset —
    /// the guard against ABI drift between the two languages.
    pub fn check_model(&self, m: &ModelConfig) -> Result<()> {
        if self.model_name != m.name {
            bail!("manifest model {:?} != expected {:?}", self.model_name, m.name);
        }
        let expect = [
            ("vocab_size", m.vocab_size),
            ("d_model", m.d_model),
            ("n_layers", m.n_layers),
            ("n_heads", m.n_heads),
            ("d_ff", m.d_ff),
            ("seq_len", m.seq_len),
        ];
        for (k, v) in expect {
            match self.model_fields.get(k) {
                Some(got) if *got == v => {}
                Some(got) => bail!("manifest {k}={got} but preset {} has {v}", m.name),
                None => bail!("manifest missing model field {k}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
manifest_version 1
model tiny
vocab_size 256
d_model 64
n_layers 2
n_heads 2
d_ff 256
seq_len 64
artifact train_baseline
  file train_baseline.hlo.txt
  kind train
  recipe baseline
  batch 8
  num_params 20
  stats_len 96
end
artifact quant_e4m3_gam
  file quant_e4m3_gam.hlo.txt
  kind quant
  rows 64
  cols 64
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.artifacts.len(), 2);
        let t = m.get("train_baseline").unwrap();
        assert_eq!(t.kind, ArtifactKind::Train);
        assert_eq!(t.usize_field("batch").unwrap(), 8);
        assert_eq!(t.file, Path::new("/tmp/a/train_baseline.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn model_check_passes_and_fails() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.check_model(&ModelConfig::TINY).is_ok());
        assert!(m.check_model(&ModelConfig::SMALL).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("manifest_version 2\nmodel x\n", Path::new(".")).is_err());
        assert!(Manifest::parse(
            "manifest_version 1\nartifact a\n  kind train\n",
            Path::new(".")
        )
        .is_err()); // no file + unterminated
        assert!(Manifest::parse("manifest_version 1\nend\n", Path::new(".")).is_err());
        assert!(Manifest::parse("manifest_version 1\nwhat is this\n", Path::new(".")).is_err());
    }
}
