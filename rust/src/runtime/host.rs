//! Host execution backend: a pure-Rust mirror of the Layer-2 compiled
//! step (`python/compile/model.py`) — decoder-only transformer forward,
//! explicit manual backward, MoR fake quantization on every linear-layer
//! GEMM operand, and the fused Adam update.
//!
//! This is what makes the coordinator, trainer, report harness and
//! benches runnable **without Python artifacts**: `Runtime::host`
//! dispatches train/eval/quant sessions here instead of PJRT. The
//! numerics layer is the same bit-exact host mirror (`formats`,
//! `scaling`, `quant`, `mor`) the Pallas kernels are validated against,
//! and every GEMM/fake-quant call below runs on the parallel chunked
//! engine (`util::par`), so the host step scales with `--threads`.
//!
//! Mirrored structure (python names in parentheses): [`layernorm_fwd`]
//! (`layernorm_fwd`), [`gelu_fwd`], causal multi-head attention
//! ([`attention_fwd`]/[`attention_bwd`]), quantized [`linear_fwd`]/
//! [`linear_bwd`] with the paper's six stats slots per linear, the
//! next-token cross-entropy, and `train_step`'s Adam with bias
//! correction. Stats slot order matches `QuantTensorId::flat`.

use crate::faults::FaultPlan;
use crate::formats::ReprType;
use crate::kernels::gemm::{pack_b, PackedB};
use crate::model::config::ModelConfig;
use crate::model::naming::QuantTensorId;
use crate::mor::policy::{
    BlockChoice, BlockProps, DecisionPolicy, MorThresholdPolicy, PolicyRef, TensorClass,
    TensorScope,
};
use crate::quant::fake_quant::fake_quantize_with;
use crate::quant::partition::{BlockRegion, Partition};
use crate::scaling::delayed::AmaxHistory;
use crate::scaling::ScalingAlgo;
use crate::tensor::ops::{matmul_nt_with, matmul_packed_with, matmul_tn_with, matmul_with};
use crate::tensor::Tensor;
use crate::util::par::{self, KernelMode, Parallelism};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

pub const LN_EPS: f32 = 1e-5;
const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi), f32 of 0.7978845608028654
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.95;
const ADAM_EPS: f32 = 1e-8;

// ---------------------------------------------------------------------------
// Recipe configuration (mirrors python QuantConfig)
// ---------------------------------------------------------------------------

/// Which MoR recipe the compiled step would have baked in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostRecipeKind {
    Baseline,
    TensorLevel,
    SubTensorTwoWay,
    SubTensorThreeWay,
}

/// Partition spec: fixed, or per-channel resolved by contraction
/// direction (python `_partition_for`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostPartition {
    Fixed(Partition),
    Channel,
}

impl HostPartition {
    pub fn resolve(self, direction: usize) -> Partition {
        match self {
            HostPartition::Fixed(p) => p,
            HostPartition::Channel => {
                if direction == 0 {
                    Partition::ChannelRows
                } else {
                    Partition::ChannelCols
                }
            }
        }
    }

    /// Whether both contraction directions resolve to the same concrete
    /// partition (every non-channel spec). Lets callers reuse one
    /// quantization result for both directions of the same tensor.
    pub fn direction_invariant(self) -> bool {
        matches!(self, HostPartition::Fixed(_))
    }
}

/// A fully-specified host recipe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostQuant {
    pub kind: HostRecipeKind,
    pub partition: HostPartition,
    pub scaling: ScalingAlgo,
}

impl HostQuant {
    pub fn baseline() -> HostQuant {
        HostQuant {
            kind: HostRecipeKind::Baseline,
            partition: HostPartition::Fixed(Partition::Tensor),
            scaling: ScalingAlgo::Gam,
        }
    }

    /// Parse the manifest artifact fields (`recipe`, `partition`,
    /// `scaling`) the AOT writer and the synthetic host manifest share.
    pub fn from_fields(recipe: &str, partition: &str, scaling: &str) -> Result<HostQuant> {
        let kind = match recipe {
            "baseline" => HostRecipeKind::Baseline,
            "tensor_level" => HostRecipeKind::TensorLevel,
            "subtensor2" => HostRecipeKind::SubTensorTwoWay,
            "subtensor3" => HostRecipeKind::SubTensorThreeWay,
            _ => bail!("unknown recipe {recipe:?}"),
        };
        let partition = if partition == "channel" {
            HostPartition::Channel
        } else {
            HostPartition::Fixed(
                Partition::parse(partition)
                    .ok_or_else(|| anyhow!("unknown partition {partition:?}"))?,
            )
        };
        let scaling = ScalingAlgo::parse(scaling)
            .ok_or_else(|| anyhow!("unknown scaling {scaling:?}"))?;
        Ok(HostQuant { kind, partition, scaling })
    }
}

/// Per-block source selection of a planned MoR operand quantization —
/// the *decision* half of [`mor_quantize`], separated from output
/// materialization so the fused quantize-on-pack path can write GEMM
/// pack buffers directly instead of materializing a tensor that the
/// GEMM would immediately re-read.
enum QuantChoice {
    /// The operand stays in original precision (baseline recipe, or a
    /// whole-tensor fallback): every element reads the input.
    Original,
    /// Whole-tensor E4M3 accept: every element reads the candidate.
    WholeE4M3(Tensor),
    /// Sub-tensor mix: `sel[bi]` picks block `bi`'s source
    /// (0 = E4M3 candidate, 1 = E5M2 candidate, 2 = original input).
    PerBlock {
        blocks: Vec<BlockRegion>,
        sel: Vec<u8>,
        fq8: Tensor,
        fq5: Tensor,
    },
}

/// A planned MoR operand quantization: the block decisions plus the
/// recorded telemetry, with the output not yet materialized. Produced
/// by [`mor_quantize_plan`]; consumed by [`MorQuantPlan::into_tensor`]
/// (the historical path) or [`MorQuantPlan::into_packed_b`] (fused).
pub struct MorQuantPlan {
    choice: QuantChoice,
    relerr: f32,
    fallback: f32,
}

impl MorQuantPlan {
    /// Mean E4M3 relative error of the operand (0 for baseline).
    pub fn relerr(&self) -> f32 {
        self.relerr
    }

    /// BF16-fallback fraction of the operand (0/1 tensor-level,
    /// fractional sub-tensor).
    pub fn fallback(&self) -> f32 {
        self.fallback
    }

    /// Materialize the quantized operand as a tensor — exactly the
    /// historical [`mor_quantize`] output, bit for bit.
    pub fn into_tensor(self, x: &Tensor) -> Tensor {
        match self.choice {
            QuantChoice::Original => x.clone(),
            QuantChoice::WholeE4M3(t) => t,
            QuantChoice::PerBlock { blocks, sel, fq8, fq5 } => {
                let (_, cols) = x.as_2d();
                let mut out = x.clone();
                for (b, s) in blocks.iter().zip(sel.iter()) {
                    let src = match *s {
                        0 => fq8.data(),
                        1 => fq5.data(),
                        _ => continue, // fallback block: already x
                    };
                    let width = b.c1 - b.c0;
                    for r in b.r0..b.r1 {
                        let at = r * cols + b.c0;
                        out.data_mut()[at..at + width].copy_from_slice(&src[at..at + width]);
                    }
                }
                out
            }
        }
    }

    /// Fused quantize-on-pack: write the quantized operand directly
    /// into a GEMM pack buffer, skipping the materialize+re-read pass.
    /// The pack contents are bit-identical to
    /// `kernels::gemm::pack_b(&self.into_tensor(x))` — packing is a
    /// pure copy, so routing each block's row segments straight from
    /// its source (candidate or input) to panel storage changes no
    /// values.
    pub fn into_packed_b(self, x: &Tensor) -> PackedB {
        match self.choice {
            QuantChoice::Original => pack_b(x),
            QuantChoice::WholeE4M3(t) => pack_b(&t),
            QuantChoice::PerBlock { blocks, sel, fq8, fq5 } => {
                // as_2d(), like into_tensor: folded N-D operands pack
                // the same way they materialize.
                let (rows, cols) = x.as_2d();
                let mut bp = PackedB::zeroed(rows, cols);
                for (b, s) in blocks.iter().zip(sel.iter()) {
                    let src = match *s {
                        0 => fq8.data(),
                        1 => fq5.data(),
                        _ => x.data(), // fallback blocks pack the input
                    };
                    let width = b.c1 - b.c0;
                    for r in b.r0..b.r1 {
                        let at = r * cols + b.c0;
                        bp.write_row_segment(r, b.c0, &src[at..at + width]);
                    }
                }
                bp
            }
        }
    }
}

/// Plan one MoR operand quantization (python `mor_quantize`'s decision
/// machinery): run the candidate fake-quantizations, put the recipe's
/// accept/fallback questions to the [`DecisionPolicy`], and return the
/// block-source plan plus telemetry. On fallback the operand stays in
/// its original precision, exactly like the compiled step's
/// `jnp.where(use, fq8, x2d)`.
///
/// The measurement half (candidate fake-quantizations, telemetry) is
/// recipe-owned and policy-independent; only the *decisions* — the
/// tensor-level accept and the per-block representation choice — are
/// delegated. Under [`MorThresholdPolicy`] the plan is bitwise
/// identical to the historical inline logic.
///
/// The sub-tensor recipes need two candidate quantizations (E4M3 and
/// E5M2) of the same tensor; they are independent, so they overlap on
/// the worker pool via [`par::join2`] — each stays internally
/// chunk-parallel and bit-identical to its serial run.
#[allow(clippy::too_many_arguments)]
pub fn mor_quantize_plan_policy(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    policy: &dyn DecisionPolicy,
    scope: TensorScope,
    faults: Option<&FaultPlan>,
    cfg: &Parallelism,
) -> MorQuantPlan {
    if q.kind == HostRecipeKind::Baseline {
        return MorQuantPlan { choice: QuantChoice::Original, relerr: 0.0, fallback: 0.0 };
    }
    let part = q.partition.resolve(direction);
    let needs_e5m2 = matches!(
        q.kind,
        HostRecipeKind::SubTensorTwoWay | HostRecipeKind::SubTensorThreeWay
    );
    let (mut fq8, fq5) = if needs_e5m2 {
        let (fq8, fq5) = par::join2(
            cfg,
            || fake_quantize_with(x, ReprType::E4M3, part, q.scaling, cfg),
            || fake_quantize_with(x, ReprType::E5M2, part, q.scaling, cfg),
        );
        (fq8, Some(fq5))
    } else {
        (fake_quantize_with(x, ReprType::E4M3, part, q.scaling, cfg), None)
    };
    let relerr = fq8.global_err.mean() as f32;

    // Fault injection: corrupt the E4M3 candidate *here*, before the
    // plan materializes into either a tensor or a packed B panel, so
    // both representations inherit the same corrupted value and the
    // SIMD ≡ blocked ≡ scalar contract is untouched. Telemetry above
    // was computed pre-flip — the corruption is silent, exactly what
    // the guard must catch downstream.
    if let Some(fp) = faults {
        let (rows, cols) = x.as_2d();
        let regions: Vec<BlockRegion> = if matches!(q.kind, HostRecipeKind::TensorLevel) {
            vec![BlockRegion { r0: 0, r1: rows, c0: 0, c1: cols }]
        } else {
            part.blocks(rows, cols)
        };
        for (bi, reg) in regions.iter().enumerate() {
            if reg.is_empty() {
                continue;
            }
            if let Some(mut rng) =
                fp.bitflip_stream(scope.class.index(), scope.layer, scope.step, direction, bi)
            {
                let r = rng.usize_in(reg.r0, reg.r1 - 1);
                let c = rng.usize_in(reg.c0, reg.c1 - 1);
                // Flip a high exponent bit of the dequantized value: a
                // silent large-magnitude corruption, the classic SDC.
                let i = r * cols + c;
                let bits = fq8.out.data()[i].to_bits() ^ (1 << 30);
                fq8.out.data_mut()[i] = f32::from_bits(bits);
            }
        }
    }

    match q.kind {
        HostRecipeKind::TensorLevel => {
            let ctx = scope.ctx(direction, false);
            if policy.accept_tensor(&ctx, ReprType::E4M3, relerr as f64, th as f64) {
                MorQuantPlan { choice: QuantChoice::WholeE4M3(fq8.out), relerr, fallback: 0.0 }
            } else {
                MorQuantPlan { choice: QuantChoice::Original, relerr, fallback: 1.0 }
            }
        }
        HostRecipeKind::SubTensorTwoWay | HostRecipeKind::SubTensorThreeWay => {
            let fq5 = fq5.expect("sub-tensor recipes computed the E5M2 candidate");
            let three_way = q.kind == HostRecipeKind::SubTensorThreeWay;
            let ctx = scope.ctx(direction, three_way);
            let (rows, cols) = x.as_2d();
            let blocks = part.blocks(rows, cols);
            let nb = blocks.len().max(1) as f32;
            let mut sel = Vec::with_capacity(blocks.len());
            let mut fallback_blocks = 0usize;
            for bi in 0..blocks.len() {
                let props = BlockProps {
                    e4m3_err: &fq8.block_err[bi],
                    e5m2_err: &fq5.block_err[bi],
                    range: fq8.block_range[bi],
                };
                let choice = match policy.choose_block(&ctx, &props) {
                    // E5M2 is not on offer under the two-way recipe.
                    BlockChoice::E5m2 if !three_way => BlockChoice::Fallback,
                    c => c,
                };
                match choice {
                    BlockChoice::E4m3 => sel.push(0),
                    BlockChoice::E5m2 => sel.push(1),
                    BlockChoice::Fallback => {
                        sel.push(2); // block stays in original precision
                        fallback_blocks += 1;
                    }
                }
            }
            MorQuantPlan {
                choice: QuantChoice::PerBlock { blocks, sel, fq8: fq8.out, fq5: fq5.out },
                relerr,
                fallback: fallback_blocks as f32 / nb,
            }
        }
        HostRecipeKind::Baseline => unreachable!(),
    }
}

/// [`mor_quantize_plan_policy`] under the default [`MorThresholdPolicy`]
/// and an anonymous scope — the historical entry point, bit for bit.
pub fn mor_quantize_plan(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    cfg: &Parallelism,
) -> MorQuantPlan {
    mor_quantize_plan_policy(
        q,
        x,
        th,
        direction,
        &MorThresholdPolicy,
        TensorScope::default(),
        None,
        cfg,
    )
}

/// Apply the MoR recipe to one 2-D GEMM operand: returns (quantized
/// tensor, relerr, fallback fraction) — [`mor_quantize_plan`]
/// materialized.
pub fn mor_quantize(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    cfg: &Parallelism,
) -> (Tensor, f32, f32) {
    let plan = mor_quantize_plan(q, x, th, direction, cfg);
    let (relerr, fallback) = (plan.relerr, plan.fallback);
    (plan.into_tensor(x), relerr, fallback)
}

/// [`mor_quantize`] with an explicit policy and tensor scope — the
/// training paths' entry point.
#[allow(clippy::too_many_arguments)]
pub fn mor_quantize_policy(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    policy: &dyn DecisionPolicy,
    scope: TensorScope,
    faults: Option<&FaultPlan>,
    cfg: &Parallelism,
) -> (Tensor, f32, f32) {
    let plan = mor_quantize_plan_policy(q, x, th, direction, policy, scope, faults, cfg);
    let (relerr, fallback) = (plan.relerr, plan.fallback);
    (plan.into_tensor(x), relerr, fallback)
}

/// [`mor_quantize`] fused with GEMM operand packing: the quantized
/// values land directly in a [`PackedB`] (column panels), so the
/// B-side operand of a linear-layer GEMM never materializes as a
/// row-major tensor at all. Telemetry and pack contents are bitwise
/// equal to the unfused quantize-then-pack sequence (pinned by
/// `rust/tests/parallel_equivalence.rs`).
pub fn mor_quantize_packed(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    cfg: &Parallelism,
) -> (PackedB, f32, f32) {
    let plan = mor_quantize_plan(q, x, th, direction, cfg);
    let (relerr, fallback) = (plan.relerr, plan.fallback);
    (plan.into_packed_b(x), relerr, fallback)
}

/// [`mor_quantize_packed`] with an explicit policy and tensor scope.
#[allow(clippy::too_many_arguments)]
pub fn mor_quantize_packed_policy(
    q: &HostQuant,
    x: &Tensor,
    th: f32,
    direction: usize,
    policy: &dyn DecisionPolicy,
    scope: TensorScope,
    faults: Option<&FaultPlan>,
    cfg: &Parallelism,
) -> (PackedB, f32, f32) {
    let plan = mor_quantize_plan_policy(q, x, th, direction, policy, scope, faults, cfg);
    let (relerr, fallback) = (plan.relerr, plan.fallback);
    (plan.into_packed_b(x), relerr, fallback)
}

// ---------------------------------------------------------------------------
// Non-linear components (unquantized, per the paper's §4 scope)
// ---------------------------------------------------------------------------

/// Per-row layernorm residuals.
pub struct LnCache {
    /// Normalized activations, same shape as the input.
    xhat: Tensor,
    /// Per-row reciprocal standard deviation.
    rstd: Vec<f32>,
}

/// y = xhat * scale + bias per row; returns (y, residuals).
pub fn layernorm_fwd(x: &Tensor, scale: &Tensor, bias: &Tensor) -> (Tensor, LnCache) {
    let (rows, d) = x.as_2d();
    let mut y = Tensor::zeros(x.shape());
    let mut xhat = Tensor::zeros(x.shape());
    let mut rstd = vec![0f32; rows];
    let (sd, bd) = (scale.data(), bias.data());
    for r in 0..rows {
        let row = &x.data()[r * d..(r + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for c in 0..d {
            let xh = (row[c] - mu) * rs;
            xhat.data_mut()[r * d + c] = xh;
            y.data_mut()[r * d + c] = xh * sd[c] + bd[c];
        }
    }
    (y, LnCache { xhat, rstd })
}

/// Backward: returns (dx, dscale, dbias).
pub fn layernorm_bwd(cache: &LnCache, scale: &Tensor, dy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let (rows, d) = dy.as_2d();
    let mut dx = Tensor::zeros(dy.shape());
    let mut dscale = Tensor::zeros(&[d]);
    let mut dbias = Tensor::zeros(&[d]);
    let sd = scale.data();
    for r in 0..rows {
        let dyr = &dy.data()[r * d..(r + 1) * d];
        let xhr = &cache.xhat.data()[r * d..(r + 1) * d];
        let mut m1 = 0f32;
        let mut m2 = 0f32;
        for c in 0..d {
            let dxh = dyr[c] * sd[c];
            m1 += dxh;
            m2 += dxh * xhr[c];
            dscale.data_mut()[c] += dyr[c] * xhr[c];
            dbias.data_mut()[c] += dyr[c];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let rs = cache.rstd[r];
        for c in 0..d {
            let dxh = dyr[c] * sd[c];
            dx.data_mut()[r * d + c] = rs * (dxh - m1 - xhr[c] * m2);
        }
    }
    (dx, dscale, dbias)
}

/// tanh-approximation GELU; returns (y, tanh values for backward).
pub fn gelu_fwd(x: &Tensor) -> (Tensor, Tensor) {
    let mut y = Tensor::zeros(x.shape());
    let mut t = Tensor::zeros(x.shape());
    for (i, &v) in x.data().iter().enumerate() {
        let inner = GELU_C * (v + 0.044715 * v * v * v);
        let th = inner.tanh();
        t.data_mut()[i] = th;
        y.data_mut()[i] = 0.5 * v * (1.0 + th);
    }
    (y, t)
}

pub fn gelu_bwd(x: &Tensor, t: &Tensor, dy: &Tensor) -> Tensor {
    let mut dx = Tensor::zeros(x.shape());
    for i in 0..x.len() {
        let v = x.data()[i];
        let th = t.data()[i];
        let dinner = GELU_C * (1.0 + 3.0 * 0.044715 * v * v);
        let dt = (1.0 - th * th) * dinner;
        dx.data_mut()[i] = dy.data()[i] * (0.5 * (1.0 + th) + 0.5 * v * dt);
    }
    dx
}

/// Residuals of causal multi-head attention, stored head-major:
/// q/k/v are `[B,H,S,hd]`, p is the `[B,H,S,S]` softmax.
pub struct AttnCache {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    p: Vec<f32>,
}

struct Dims {
    b: usize,
    s: usize,
    d: usize,
    h: usize,
    hd: usize,
}

impl Dims {
    fn of(m: &ModelConfig, batch: usize) -> Dims {
        Dims { b: batch, s: m.seq_len, d: m.d_model, h: m.n_heads, hd: m.head_dim() }
    }
}

/// Causal MHA over already-projected q/k/v (each `[B*S, D]` with heads
/// along the feature axis). Returns (`[B*S, D]` context, residuals).
pub fn attention_fwd(
    m: &ModelConfig,
    batch: usize,
    q3: &Tensor,
    k3: &Tensor,
    v3: &Tensor,
) -> (Tensor, AttnCache) {
    let Dims { b, s, d, h, hd } = Dims::of(m, batch);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut q = vec![0f32; b * h * s * hd];
    let mut k = vec![0f32; b * h * s * hd];
    let mut v = vec![0f32; b * h * s * hd];
    // [B*S, D] with column h*hd+c  →  [B,H,S,hd].
    let pack = |src: &Tensor, dst: &mut Vec<f32>| {
        for bi in 0..b {
            for hi in 0..h {
                for si in 0..s {
                    let to = ((bi * h + hi) * s + si) * hd;
                    let from = (bi * s + si) * d + hi * hd;
                    dst[to..to + hd].copy_from_slice(&src.data()[from..from + hd]);
                }
            }
        }
    };
    pack(q3, &mut q);
    pack(k3, &mut k);
    pack(v3, &mut v);

    let mut p = vec![0f32; b * h * s * s];
    let mut out = Tensor::zeros(&[b * s, d]);
    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s;
            for s1 in 0..s {
                // Causal scores row: positions 0..=s1 participate.
                let qrow = &q[(base + s1) * hd..(base + s1 + 1) * hd];
                let mut scores = vec![0f32; s1 + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (s2, sc) in scores.iter_mut().enumerate() {
                    let krow = &k[(base + s2) * hd..(base + s2 + 1) * hd];
                    let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                    *sc = dot * scale;
                    maxv = maxv.max(*sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                let prow = &mut p[(base + s1) * s..(base + s1 + 1) * s];
                for (s2, sc) in scores.iter().enumerate() {
                    prow[s2] = sc / denom;
                }
                // Context: out[s1] = sum_{s2<=s1} p * v[s2].
                let o0 = (bi * s + s1) * d + hi * hd;
                let orow = &mut out.data_mut()[o0..o0 + hd];
                for s2 in 0..=s1 {
                    let pv = prow[s2];
                    if pv == 0.0 {
                        continue;
                    }
                    let vrow = &v[(base + s2) * hd..(base + s2 + 1) * hd];
                    for c in 0..hd {
                        orow[c] += pv * vrow[c];
                    }
                }
            }
        }
    }
    (out, AttnCache { q, k, v, p })
}

/// Backward of [`attention_fwd`]; returns (dq, dk, dv) each `[B*S, D]`.
pub fn attention_bwd(
    m: &ModelConfig,
    batch: usize,
    cache: &AttnCache,
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let Dims { b, s, d, h, hd } = Dims::of(m, batch);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq3 = Tensor::zeros(&[b * s, d]);
    let mut dk3 = Tensor::zeros(&[b * s, d]);
    let mut dv3 = Tensor::zeros(&[b * s, d]);
    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s;
            // do/dv/ds in head layout for this (b, h).
            let do_at = |s1: usize, c: usize| dout.data()[(bi * s + s1) * d + hi * hd + c];
            let mut dv = vec![0f32; s * hd];
            let mut ds = vec![0f32; s * s];
            for s1 in 0..s {
                let prow = &cache.p[(base + s1) * s..(base + s1 + 1) * s];
                // dp[s1, s2] = do[s1] . v[s2]; row-sum for softmax bwd.
                let mut dp = vec![0f32; s1 + 1];
                let mut dot_pp = 0f32;
                for (s2, dpv) in dp.iter_mut().enumerate() {
                    let vrow = &cache.v[(base + s2) * hd..(base + s2 + 1) * hd];
                    let mut acc = 0f32;
                    for c in 0..hd {
                        acc += do_at(s1, c) * vrow[c];
                    }
                    *dpv = acc;
                    dot_pp += acc * prow[s2];
                }
                for (s2, dpv) in dp.iter().enumerate() {
                    ds[s1 * s + s2] = prow[s2] * (dpv - dot_pp) * scale;
                }
                // dv[s2] += p[s1,s2] * do[s1].
                for s2 in 0..=s1 {
                    let pv = prow[s2];
                    if pv == 0.0 {
                        continue;
                    }
                    for c in 0..hd {
                        dv[s2 * hd + c] += pv * do_at(s1, c);
                    }
                }
            }
            // dq[s1] = sum_{s2<=s1} ds * k[s2]; dk[s2] += ds * q[s1].
            for s1 in 0..s {
                for s2 in 0..=s1 {
                    let dsv = ds[s1 * s + s2];
                    if dsv == 0.0 {
                        continue;
                    }
                    let krow = &cache.k[(base + s2) * hd..(base + s2 + 1) * hd];
                    let qrow = &cache.q[(base + s1) * hd..(base + s1 + 1) * hd];
                    for c in 0..hd {
                        dq3.data_mut()[(bi * s + s1) * d + hi * hd + c] += dsv * krow[c];
                        dk3.data_mut()[(bi * s + s2) * d + hi * hd + c] += dsv * qrow[c];
                    }
                }
            }
            for s2 in 0..s {
                let to = (bi * s + s2) * d + hi * hd;
                dv3.data_mut()[to..to + hd].copy_from_slice(&dv[s2 * hd..(s2 + 1) * hd]);
            }
        }
    }
    (dq3, dk3, dv3)
}

// ---------------------------------------------------------------------------
// Quantized linear layer + stats recording
// ---------------------------------------------------------------------------

/// Per-step MoR telemetry, slot order = `QuantTensorId::flat`.
pub struct StepStats {
    pub relerr: Vec<f32>,
    pub fallback: Vec<f32>,
    /// Per-slot operand amax — feeds the delayed-scaling history
    /// ([`HostTrainer`]'s per-slot [`AmaxHistory`] telemetry, part of
    /// the checkpointable session state).
    pub amax: Vec<f32>,
}

impl StepStats {
    fn new(n_slots: usize) -> StepStats {
        StepStats {
            relerr: vec![0.0; n_slots],
            fallback: vec![0.0; n_slots],
            amax: vec![0.0; n_slots],
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn record(
        &mut self,
        layer: usize,
        linear: usize,
        tensor: usize,
        dir: usize,
        re: f32,
        fb: f32,
        amax: f32,
    ) {
        let id = QuantTensorId { layer, linear, tensor, direction: dir };
        let idx = id.flat(0);
        self.relerr[idx] = re;
        self.fallback[idx] = fb;
        self.amax[idx] = amax;
    }
}

/// Everything a quantized GEMM needs to plan its operands: the recipe,
/// the run threshold, the active [`DecisionPolicy`] and the 1-based
/// optimizer step — bundled so the model walk threads one value
/// instead of four loose parameters.
#[derive(Clone, Copy)]
pub struct StepEnv<'a> {
    pub quant: &'a HostQuant,
    pub th: f32,
    pub policy: &'a dyn DecisionPolicy,
    /// Optimizer step feeding [`DecisionCtx::step`]
    /// ([`crate::mor::policy::DecisionCtx`]); 0 outside training.
    pub step: u64,
    /// Active fault-injection plan (chaos testing); `None` in normal
    /// runs, and `None` keeps every quantization bit-identical.
    pub faults: Option<&'a FaultPlan>,
}

/// y = fq(x) @ fq(w), recording input/weight forward-direction stats.
/// The two operand quantizations are independent and overlap on the
/// pool.
///
/// Under the kernel engine the weight operand quantizes **fused with
/// packing** ([`mor_quantize_packed`]): its quantized values land
/// directly in the GEMM's column panels, never materializing as a
/// row-major tensor. The scalar oracle keeps the historical
/// materialize-then-multiply sequence. Both produce bit-identical
/// outputs and telemetry.
fn linear_fwd(
    env: &StepEnv,
    stats: &mut StepStats,
    layer: usize,
    linear: usize,
    x2d: &Tensor,
    w: &Tensor,
    cfg: &Parallelism,
) -> Tensor {
    let (q, th, pol, fa) = (env.quant, env.th, env.policy, env.faults);
    let xs = TensorScope::new(TensorClass::Input, layer, env.step);
    let ws = TensorScope::new(TensorClass::Weight, layer, env.step);
    if cfg.kernel() == KernelMode::Scalar {
        let ((qx, rex, fbx), (qw, rew, fbw)) = par::join2(
            cfg,
            || mor_quantize_policy(q, x2d, th, 0, pol, xs, fa, cfg),
            || mor_quantize_policy(q, w, th, 1, pol, ws, fa, cfg),
        );
        stats.record(layer, linear, 0, 0, rex, fbx, x2d.amax());
        stats.record(layer, linear, 1, 0, rew, fbw, w.amax());
        return matmul_with(&qx, &qw, cfg);
    }
    let ((qx, rex, fbx), (pw, rew, fbw)) = par::join2(
        cfg,
        || mor_quantize_policy(q, x2d, th, 0, pol, xs, fa, cfg),
        || mor_quantize_packed_policy(q, w, th, 1, pol, ws, fa, cfg),
    );
    stats.record(layer, linear, 0, 0, rex, fbx, x2d.amax());
    stats.record(layer, linear, 1, 0, rew, fbw, w.amax());
    matmul_packed_with(&qx, &pw, cfg)
}

/// Backward GEMMs with their own quantized operands (the paper's "and
/// their transposes"): dx = fq(dy) @ fq(W^T), dW = fq(x^T) @ fq(dy).
///
/// Pipeline-level parallelism: the backward operand quantizations
/// (dy in both directions when they differ, W^T and x^T, transposes
/// included) share no data, so they run overlapped on the worker pool,
/// as do the two backward GEMMs that consume them. Every overlapped
/// piece is an independent computation whose internal chunk merge is
/// canonical, so the result is bit-identical to the sequential order.
#[allow(clippy::too_many_arguments)]
fn linear_bwd(
    env: &StepEnv,
    stats: &mut StepStats,
    layer: usize,
    linear: usize,
    x2d: &Tensor,
    w: &Tensor,
    dy2d: &Tensor,
    cfg: &Parallelism,
) -> (Tensor, Tensor) {
    if cfg.kernel() == KernelMode::Scalar {
        return linear_bwd_scalar(env, stats, layer, linear, x2d, w, dy2d, cfg);
    }
    let (q, th, pol, fa) = (env.quant, env.th, env.policy, env.faults);
    let xs = TensorScope::new(TensorClass::Input, layer, env.step);
    let ws = TensorScope::new(TensorClass::Weight, layer, env.step);
    let gs = TensorScope::new(TensorClass::Grad, layer, env.step);
    // Kernel engine, fused quantize-on-pack for both B-side operands:
    // W^T (B of the dx GEMM) and the direction-1 dy (B of the dW GEMM)
    // quantize straight into pack buffers. dy direction 0 and x^T are
    // the A-side operands, so they materialize as tensors exactly as
    // before. When the partition resolves both contraction directions
    // identically, the direction-1 dy quantization would be
    // bit-identical to direction 0 — it is skipped and the pack copies
    // the materialized tensor instead (packing is a pure copy, so the
    // reuse semantics are unchanged). Per-channel partitions make it a
    // fourth independent quantization joining the overlap tree.
    let (((qdy0, reg0, fbg0), alt_dy), ((pwt, rew1, fbw1), (qxt, rex1, fbx1))) = par::join2(
        cfg,
        || {
            par::join2(
                cfg,
                || mor_quantize_policy(q, dy2d, th, 0, pol, gs, fa, cfg),
                || {
                    if q.partition.direction_invariant() {
                        None
                    } else {
                        Some(mor_quantize_packed_policy(q, dy2d, th, 1, pol, gs, fa, cfg))
                    }
                },
            )
        },
        || {
            par::join2(
                cfg,
                || {
                    let wt = w.transpose();
                    mor_quantize_packed_policy(q, &wt, th, 1, pol, ws, fa, cfg)
                },
                || {
                    let xt = x2d.transpose();
                    mor_quantize_policy(q, &xt, th, 0, pol, xs, fa, cfg)
                },
            )
        },
    );
    let (pdy1, reg1, fbg1) = match alt_dy {
        Some((p, re, fb)) => (p, re, fb),
        None => (pack_b(&qdy0), reg0, fbg0),
    };
    let (dx, dw) = par::join2(
        cfg,
        || matmul_packed_with(&qdy0, &pwt, cfg),
        || matmul_packed_with(&qxt, &pdy1, cfg),
    );
    // Operand amaxes are transpose-invariant, so they come from the
    // untransposed tensors.
    let (axm, awm, agm) = (x2d.amax(), w.amax(), dy2d.amax());
    stats.record(layer, linear, 0, 1, rex1, fbx1, axm);
    stats.record(layer, linear, 1, 1, rew1, fbw1, awm);
    stats.record(layer, linear, 2, 0, reg0, fbg0, agm);
    stats.record(layer, linear, 2, 1, reg1, fbg1, agm);
    (dx, dw)
}

/// The historical (scalar-oracle) backward path: every operand
/// materializes, every GEMM packs internally or runs naive.
#[allow(clippy::too_many_arguments)]
fn linear_bwd_scalar(
    env: &StepEnv,
    stats: &mut StepStats,
    layer: usize,
    linear: usize,
    x2d: &Tensor,
    w: &Tensor,
    dy2d: &Tensor,
    cfg: &Parallelism,
) -> (Tensor, Tensor) {
    let (q, th, pol, fa) = (env.quant, env.th, env.policy, env.faults);
    let xs = TensorScope::new(TensorClass::Input, layer, env.step);
    let ws = TensorScope::new(TensorClass::Weight, layer, env.step);
    let gs = TensorScope::new(TensorClass::Grad, layer, env.step);
    let (((qdy0, reg0, fbg0), alt_dy), ((qwt, rew1, fbw1), (qxt, rex1, fbx1))) = par::join2(
        cfg,
        || {
            par::join2(
                cfg,
                || mor_quantize_policy(q, dy2d, th, 0, pol, gs, fa, cfg),
                || {
                    if q.partition.direction_invariant() {
                        None
                    } else {
                        Some(mor_quantize_policy(q, dy2d, th, 1, pol, gs, fa, cfg))
                    }
                },
            )
        },
        || {
            par::join2(
                cfg,
                || {
                    let wt = w.transpose();
                    mor_quantize_policy(q, &wt, th, 1, pol, ws, fa, cfg)
                },
                || {
                    let xt = x2d.transpose();
                    mor_quantize_policy(q, &xt, th, 0, pol, xs, fa, cfg)
                },
            )
        },
    );
    let (qdy1, reg1, fbg1) = match &alt_dy {
        Some((t, re, fb)) => (t, *re, *fb),
        None => (&qdy0, reg0, fbg0),
    };
    let (dx, dw) = par::join2(
        cfg,
        || matmul_with(&qdy0, &qwt, cfg),
        || matmul_with(&qxt, qdy1, cfg),
    );
    let (axm, awm, agm) = (x2d.amax(), w.amax(), dy2d.amax());
    stats.record(layer, linear, 0, 1, rex1, fbx1, axm);
    stats.record(layer, linear, 1, 1, rew1, fbw1, awm);
    stats.record(layer, linear, 2, 0, reg0, fbg0, agm);
    stats.record(layer, linear, 2, 1, reg1, fbg1, agm);
    (dx, dw)
}

// ---------------------------------------------------------------------------
// Full model
// ---------------------------------------------------------------------------

/// Per-layer parameter view into the canonical flat parameter list.
struct LayerParams<'a> {
    ln1_s: &'a Tensor,
    ln1_b: &'a Tensor,
    wqkv: &'a Tensor,
    wproj: &'a Tensor,
    ln2_s: &'a Tensor,
    ln2_b: &'a Tensor,
    w1: &'a Tensor,
    w2: &'a Tensor,
}

fn layer_params<'a>(params: &'a [Tensor], l: usize) -> LayerParams<'a> {
    let i = 1 + l * 8;
    LayerParams {
        ln1_s: &params[i],
        ln1_b: &params[i + 1],
        wqkv: &params[i + 2],
        wproj: &params[i + 3],
        ln2_s: &params[i + 4],
        ln2_b: &params[i + 5],
        w1: &params[i + 6],
        w2: &params[i + 7],
    }
}

struct LayerCache {
    ln1: LnCache,
    qkv_in: Tensor,
    attn: AttnCache,
    proj_in: Tensor,
    ln2: LnCache,
    fc1_in: Tensor,
    gelu_in: Tensor,
    gelu_t: Tensor,
    fc2_in: Tensor,
}

struct ForwardCache {
    layers: Vec<LayerCache>,
    lnf: LnCache,
    xf: Tensor,
}

/// Split a `[BS, 3D]` qkv projection into its three `[BS, D]` parts.
fn split3(qkv: &Tensor, d: usize) -> (Tensor, Tensor, Tensor) {
    let (rows, cols) = qkv.as_2d();
    debug_assert_eq!(cols, 3 * d);
    let mut q = Tensor::zeros(&[rows, d]);
    let mut k = Tensor::zeros(&[rows, d]);
    let mut v = Tensor::zeros(&[rows, d]);
    for r in 0..rows {
        let src = &qkv.data()[r * cols..(r + 1) * cols];
        q.data_mut()[r * d..(r + 1) * d].copy_from_slice(&src[..d]);
        k.data_mut()[r * d..(r + 1) * d].copy_from_slice(&src[d..2 * d]);
        v.data_mut()[r * d..(r + 1) * d].copy_from_slice(&src[2 * d..]);
    }
    (q, k, v)
}

/// Concatenate three `[BS, D]` gradients into `[BS, 3D]`.
fn concat3(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (rows, d) = q.as_2d();
    let mut out = Tensor::zeros(&[rows, 3 * d]);
    for r in 0..rows {
        out.data_mut()[r * 3 * d..r * 3 * d + d].copy_from_slice(&q.data()[r * d..(r + 1) * d]);
        out.data_mut()[r * 3 * d + d..r * 3 * d + 2 * d]
            .copy_from_slice(&k.data()[r * d..(r + 1) * d]);
        out.data_mut()[r * 3 * d + 2 * d..r * 3 * d + 3 * d]
            .copy_from_slice(&v.data()[r * d..(r + 1) * d]);
    }
    out
}

fn add_into(dst: &mut Tensor, src: &Tensor) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, b) in dst.data_mut().iter_mut().zip(src.data()) {
        *a += b;
    }
}

/// The host mirror indexes the embedding/loss tables directly, so the
/// accepted token domain is checked up front (the compiled path would
/// have clamped/gathered device-side instead of panicking).
fn check_tokens(tokens: &[i32], vocab: usize) -> Result<()> {
    for (i, &t) in tokens.iter().enumerate() {
        if t < 0 || t as usize >= vocab {
            bail!("token {t} at position {i} outside vocab 0..{vocab}");
        }
    }
    Ok(())
}

/// Forward pass over one token batch; returns `[B*S, V]` logits (and,
/// when `save`, the residuals for [`backward`]).
#[allow(clippy::too_many_arguments)]
fn forward(
    m: &ModelConfig,
    env: &StepEnv,
    params: &[Tensor],
    tokens: &[i32],
    batch: usize,
    stats: &mut StepStats,
    save: bool,
    cfg: &Parallelism,
) -> (Tensor, Option<ForwardCache>) {
    let (s, d) = (m.seq_len, m.d_model);
    let bs = batch * s;
    debug_assert_eq!(tokens.len(), bs);
    let emb = &params[0];
    let n_layer_params = 1 + 8 * m.n_layers;
    let lnf_s = &params[n_layer_params];
    let lnf_b = &params[n_layer_params + 1];
    let head = &params[n_layer_params + 2];

    // Embedding lookup.
    let mut x = Tensor::zeros(&[bs, d]);
    for (r, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        x.data_mut()[r * d..(r + 1) * d].copy_from_slice(&emb.data()[t * d..(t + 1) * d]);
    }

    let mut layers = Vec::with_capacity(if save { m.n_layers } else { 0 });
    for l in 0..m.n_layers {
        let lp = layer_params(params, l);
        // Attention block: x = x + proj(attn(qkv(ln1(x)))).
        let (h2d, ln1) = layernorm_fwd(&x, lp.ln1_s, lp.ln1_b);
        let qkv = linear_fwd(env, stats, l, 0, &h2d, lp.wqkv, cfg);
        let (q3, k3, v3) = split3(&qkv, d);
        let (a2d, attn) = attention_fwd(m, batch, &q3, &k3, &v3);
        let proj = linear_fwd(env, stats, l, 1, &a2d, lp.wproj, cfg);
        add_into(&mut x, &proj);

        // MLP block: x = x + fc2(gelu(fc1(ln2(x)))).
        let (h2, ln2) = layernorm_fwd(&x, lp.ln2_s, lp.ln2_b);
        let f2d = linear_fwd(env, stats, l, 2, &h2, lp.w1, cfg);
        let (g, gelu_t) = gelu_fwd(&f2d);
        let o2d = linear_fwd(env, stats, l, 3, &g, lp.w2, cfg);
        add_into(&mut x, &o2d);

        if save {
            layers.push(LayerCache {
                ln1,
                qkv_in: h2d,
                attn,
                proj_in: a2d,
                ln2,
                fc1_in: h2,
                gelu_in: f2d,
                gelu_t,
                fc2_in: g,
            });
        }
    }
    let (xf, lnf) = layernorm_fwd(&x, lnf_s, lnf_b);
    let logits = matmul_with(&xf, head, cfg); // lm_head unquantized (§4 scope)
    let cache = if save { Some(ForwardCache { layers, lnf, xf }) } else { None };
    (logits, cache)
}

/// Next-token cross-entropy over all positions but the last of each
/// row; also returns d loss / d logits.
fn loss_and_dlogits(
    m: &ModelConfig,
    logits: &Tensor,
    tokens: &[i32],
    batch: usize,
) -> (f32, Tensor) {
    let (s, v) = (m.seq_len, m.vocab_size);
    let n = (batch * (s - 1)) as f32;
    let mut loss = 0f64;
    let mut dlogits = Tensor::zeros(&[batch * s, v]);
    for b in 0..batch {
        for si in 0..s - 1 {
            let r = b * s + si;
            let target = tokens[b * s + si + 1] as usize;
            let row = &logits.data()[r * v..(r + 1) * v];
            let maxv = row.iter().fold(f32::NEG_INFINITY, |a, x| a.max(*x));
            let sumexp: f32 = row.iter().map(|x| (x - maxv).exp()).sum();
            let lse = maxv + sumexp.ln();
            loss += (lse - row[target]) as f64;
            let drow = &mut dlogits.data_mut()[r * v..(r + 1) * v];
            for c in 0..v {
                let p = (row[c] - maxv).exp() / sumexp;
                drow[c] = (p - if c == target { 1.0 } else { 0.0 }) / n;
            }
        }
    }
    ((loss / n as f64) as f32, dlogits)
}

/// Manual backward through the whole model; returns grads in canonical
/// parameter order.
#[allow(clippy::too_many_arguments)]
fn backward(
    m: &ModelConfig,
    env: &StepEnv,
    params: &[Tensor],
    cache: &ForwardCache,
    dlogits: &Tensor,
    tokens: &[i32],
    batch: usize,
    stats: &mut StepStats,
    cfg: &Parallelism,
) -> Vec<Tensor> {
    let d = m.d_model;
    let n_layer_params = 1 + 8 * m.n_layers;
    let lnf_s = &params[n_layer_params];
    let head = &params[n_layer_params + 2];

    // lm_head GEMM (unquantized).
    let dhead = matmul_tn_with(&cache.xf, dlogits, cfg);
    let dxf = matmul_nt_with(dlogits, head, cfg);
    let (mut dx, dlnf_s, dlnf_b) = layernorm_bwd(&cache.lnf, lnf_s, &dxf);

    let mut dlayers: Vec<[Tensor; 8]> = Vec::with_capacity(m.n_layers);
    for l in (0..m.n_layers).rev() {
        let lp = layer_params(params, l);
        let lc = &cache.layers[l];

        // MLP block.
        let (dg, dw2) = linear_bwd(env, stats, l, 3, &lc.fc2_in, lp.w2, &dx, cfg);
        let df = gelu_bwd(&lc.gelu_in, &lc.gelu_t, &dg);
        let (dh2, dw1) = linear_bwd(env, stats, l, 2, &lc.fc1_in, lp.w1, &df, cfg);
        let (dx_mlp, dln2s, dln2b) = layernorm_bwd(&lc.ln2, lp.ln2_s, &dh2);
        add_into(&mut dx, &dx_mlp);

        // Attention block.
        let (da2d, dwproj) = linear_bwd(env, stats, l, 1, &lc.proj_in, lp.wproj, &dx, cfg);
        let (dq3, dk3, dv3) = attention_bwd(m, batch, &lc.attn, &da2d);
        let dqkv = concat3(&dq3, &dk3, &dv3);
        let (dh2d, dwqkv) = linear_bwd(env, stats, l, 0, &lc.qkv_in, lp.wqkv, &dqkv, cfg);
        let (dx_attn, dln1s, dln1b) = layernorm_bwd(&lc.ln1, lp.ln1_s, &dh2d);
        add_into(&mut dx, &dx_attn);

        dlayers.push([dln1s, dln1b, dwqkv, dwproj, dln2s, dln2b, dw1, dw2]);
    }
    dlayers.reverse();

    // Embedding: scatter-add of dx at token positions.
    let mut demb = Tensor::zeros(params[0].shape());
    for (r, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        for c in 0..d {
            demb.data_mut()[t * d + c] += dx.data()[r * d + c];
        }
    }

    let mut grads = Vec::with_capacity(params.len());
    grads.push(demb);
    for dl in dlayers {
        grads.extend(dl);
    }
    grads.push(dlnf_s);
    grads.push(dlnf_b);
    grads.push(dhead);
    grads
}

// ---------------------------------------------------------------------------
// Train / eval entry points (the host ABI)
// ---------------------------------------------------------------------------

/// Window of the per-slot delayed-scaling amax telemetry
/// ([`HostTrainer::amax_history`]) — Transformer-Engine-style histories
/// scaled to the testbed.
pub const AMAX_HIST_WINDOW: usize = 16;

/// The host-side train session state: params + Adam moments, stepped in
/// place, plus the per-slot delayed-scaling amax history telemetry.
/// Mirrors the compiled train artifact's fused step.
pub struct HostTrainer {
    pub model: ModelConfig,
    pub quant: HostQuant,
    /// The per-run engine handle every hot-path call below runs on.
    pub par: Parallelism,
    /// The precision-assignment policy every quantization decision in
    /// [`HostTrainer::step`] consults. Defaults to the paper's
    /// [`MorThresholdPolicy`]; swap per run with
    /// [`HostTrainer::with_policy`].
    pub policy: PolicyRef,
    pub params: Vec<Tensor>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    /// Per-slot sliding amax history (slot order = `QuantTensorId::
    /// flat`): the delayed-scaling state a resumed run must restore to
    /// keep its scaling decisions auditable against the uninterrupted
    /// run (pure telemetry today — the recipes recompute scales per
    /// mini-batch — but checkpointed like the rest of the dynamic
    /// state so a delayed-scaling recipe slots in without a format
    /// change).
    amax_hist: Vec<AmaxHistory>,
    /// Active fault-injection plan (chaos testing); `None` in normal
    /// runs.
    faults: Option<Arc<FaultPlan>>,
    /// Numeric-guard mode: scan gradients for non-finite values each
    /// step and skip the Adam update when any are found (ladder rung 1).
    skip_nonfinite: bool,
    /// Per-slot amaxes observed by the last step (guard telemetry).
    last_amax: Vec<f32>,
    /// Non-finite gradient values counted by the last step's scan (0
    /// when `skip_nonfinite` is off).
    last_nonfinite: u64,
    /// Whether the last step skipped its update.
    last_skipped: bool,
}

impl HostTrainer {
    /// Initialize parameters host-side with the deterministic seed,
    /// exactly like [`super::client::init_param`] does for PJRT.
    pub fn new(model: ModelConfig, quant: HostQuant, seed: u64, par: Parallelism) -> HostTrainer {
        let specs = crate::model::naming::param_specs(&model);
        let params: Vec<Tensor> = specs
            .iter()
            .enumerate()
            .map(|(i, sp)| {
                super::client::init_param(&model, &sp.name, &sp.shape, seed.wrapping_add(i as u64))
            })
            .collect();
        let m = specs.iter().map(|sp| Tensor::zeros(&sp.shape)).collect();
        let v = specs.iter().map(|sp| Tensor::zeros(&sp.shape)).collect();
        let amax_hist =
            vec![AmaxHistory::new(AMAX_HIST_WINDOW); QuantTensorId::count(&model)];
        let policy: PolicyRef = Arc::new(MorThresholdPolicy);
        HostTrainer {
            model,
            quant,
            par,
            policy,
            params,
            m,
            v,
            amax_hist,
            faults: None,
            skip_nonfinite: false,
            last_amax: Vec::new(),
            last_nonfinite: 0,
            last_skipped: false,
        }
    }

    /// Install (or clear) the fault-injection plan for this session.
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    /// Toggle the guard's non-finite gradient scan + skip-step rung.
    pub fn set_skip_nonfinite(&mut self, on: bool) {
        self.skip_nonfinite = on;
    }

    /// Per-slot amaxes the last step observed.
    pub fn last_amax(&self) -> &[f32] {
        &self.last_amax
    }

    /// Non-finite gradient values the last step's scan counted.
    pub fn last_nonfinite_grads(&self) -> u64 {
        self.last_nonfinite
    }

    /// Whether the last step skipped its parameter update.
    pub fn last_update_skipped(&self) -> bool {
        self.last_skipped
    }

    /// Replace the decision policy (builder style, for session setup).
    pub fn with_policy(mut self, policy: PolicyRef) -> HostTrainer {
        self.policy = policy;
        self
    }

    /// The Adam moments, in canonical parameter order (checkpointing).
    pub fn moments(&self) -> (&[Tensor], &[Tensor]) {
        (&self.m, &self.v)
    }

    /// The per-slot delayed-scaling amax histories (checkpointing).
    pub fn amax_history(&self) -> &[AmaxHistory] {
        &self.amax_hist
    }

    /// Restore the full dynamic state (params + Adam moments + amax
    /// histories) from a checkpoint. Arities and shapes must match the
    /// model; an empty `amax_hist` resets the telemetry (the PJRT
    /// backend exports none).
    pub fn load_state(
        &mut self,
        params: &[Tensor],
        m: &[Tensor],
        v: &[Tensor],
        amax_hist: &[AmaxHistory],
    ) -> Result<()> {
        let n = self.params.len();
        if params.len() != n || m.len() != n || v.len() != n {
            bail!(
                "state arity mismatch: {} params / {} m / {} v, expected {n}",
                params.len(),
                m.len(),
                v.len()
            );
        }
        for (i, ((p, mm), vv)) in params.iter().zip(m).zip(v).enumerate() {
            let want = self.params[i].shape();
            if p.shape() != want || mm.shape() != want || vv.shape() != want {
                bail!("state shape mismatch at param {i}: expected {want:?}");
            }
        }
        let n_slots = QuantTensorId::count(&self.model);
        if !amax_hist.is_empty() && amax_hist.len() != n_slots {
            bail!("amax history has {} slots, expected {n_slots}", amax_hist.len());
        }
        self.params = params.to_vec();
        self.m = m.to_vec();
        self.v = v.to_vec();
        self.amax_hist = if amax_hist.is_empty() {
            vec![AmaxHistory::new(AMAX_HIST_WINDOW); n_slots]
        } else {
            amax_hist.to_vec()
        };
        Ok(())
    }

    /// One fused step: fwd + manual bwd + Adam. Returns
    /// (loss, relerr slots, fallback slots).
    pub fn step(
        &mut self,
        tokens: &[i32],
        batch: usize,
        lr: f32,
        th: f32,
        adam_t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        if tokens.len() != batch * self.model.seq_len {
            bail!(
                "token batch has {} elements, expected {}",
                tokens.len(),
                batch * self.model.seq_len
            );
        }
        check_tokens(tokens, self.model.vocab_size)?;
        let n_slots = QuantTensorId::count(&self.model);
        let mut stats = StepStats::new(n_slots);
        let step1 = adam_t as u64;
        if let Some(fp) = &self.faults {
            // Armed on this thread, consumed by the first join2 of the
            // forward pass (linear_fwd calls join2 unconditionally).
            // The trainer disarms before every step (see
            // `faults::clear_worker_panic`), so a flag orphaned by an
            // aborted run can never fire inside another tenant sharing
            // this pool thread.
            if fp.worker_panic_due(step1) {
                crate::faults::arm_worker_panic();
            }
        }
        let env = StepEnv {
            quant: &self.quant,
            th,
            policy: self.policy.as_ref(),
            step: step1,
            faults: self.faults.as_deref(),
        };
        let (logits, cache) = forward(
            &self.model,
            &env,
            &self.params,
            tokens,
            batch,
            &mut stats,
            true,
            &self.par,
        );
        let (loss, dlogits) = loss_and_dlogits(&self.model, &logits, tokens, batch);
        let cache = cache.expect("forward(save=true) returns a cache");
        let grads = backward(
            &self.model,
            &env,
            &self.params,
            &cache,
            &dlogits,
            tokens,
            batch,
            &mut stats,
            &self.par,
        );

        // Fault injection, gradient seeds: poison one element of one
        // gradient tensor, after backward and before the update — the
        // exact corruption the guard's scan must catch.
        let mut grads = grads;
        let seeded = self
            .faults
            .as_ref()
            .map_or(Vec::new(), |fp| fp.seeds_due(step1));
        for (si, (kind, site)) in seeded.iter().enumerate() {
            if *site != crate::faults::SeedSite::Grad {
                continue;
            }
            let fp = self.faults.as_ref().expect("seeds came from the plan");
            let mut rng = fp.seed_target_stream(step1, si as u64);
            let pi = rng.usize_in(0, grads.len() - 1);
            let ei = rng.usize_in(0, grads[pi].len().max(1) - 1);
            grads[pi].data_mut()[ei] = kind.value();
        }

        // Advance the per-slot delayed-scaling histories with the
        // amaxes this step observed (checkpointable telemetry).
        for (h, &a) in self.amax_hist.iter_mut().zip(stats.amax.iter()) {
            h.push(a);
        }
        self.last_amax = stats.amax.clone();
        self.last_nonfinite = 0;
        self.last_skipped = false;

        // Guard rung 1: scan gradients for non-finite values; a single
        // one poisons Adam state and the parameters it feeds, so the
        // whole update is skipped (optimizer state untouched).
        if self.skip_nonfinite {
            let mut bad = 0u64;
            for g in &grads {
                for v in g.data() {
                    if !v.is_finite() {
                        bad += 1;
                    }
                }
            }
            self.last_nonfinite = bad;
        }
        let do_update = !(self.skip_nonfinite && self.last_nonfinite > 0);
        if do_update {
            let bc1 = 1.0 - ADAM_B1.powf(adam_t);
            let bc2 = 1.0 - ADAM_B2.powf(adam_t);
            for ((p, g), (mi, vi)) in
                self.params.iter_mut().zip(&grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
            {
                for i in 0..p.len() {
                    let gv = g.data()[i];
                    let m_new = ADAM_B1 * mi.data()[i] + (1.0 - ADAM_B1) * gv;
                    let v_new = ADAM_B2 * vi.data()[i] + (1.0 - ADAM_B2) * gv * gv;
                    mi.data_mut()[i] = m_new;
                    vi.data_mut()[i] = v_new;
                    let mhat = m_new / bc1;
                    let vhat = v_new / bc2;
                    p.data_mut()[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
                }
            }
        } else {
            self.last_skipped = true;
        }

        // Fault injection, weight seeds: poison one parameter element
        // *after* the update — corruption no gradient scan can see,
        // forcing the guard's param-norm check and the rewind rung.
        for (si, (kind, site)) in seeded.iter().enumerate() {
            if *site != crate::faults::SeedSite::Weight {
                continue;
            }
            let fp = self.faults.as_ref().expect("seeds came from the plan");
            let mut rng = fp.seed_target_stream(step1, 0x10 + si as u64);
            let pi = rng.usize_in(0, self.params.len() - 1);
            let ei = rng.usize_in(0, self.params[pi].len().max(1) - 1);
            self.params[pi].data_mut()[ei] = kind.value();
        }
        Ok((loss, stats.relerr, stats.fallback))
    }
}

/// Masked eval (mirrors python `eval_step`): mean loss and next-token
/// accuracy over positions with mask = 1.
///
/// This is the **tensor-native** host eval entry: parameters are
/// borrowed host tensors, no `xla::Literal` interchange anywhere on
/// the path. `Runtime`-level callers reach it through
/// `EvalSession::eval_params` with `ParamsRef::Tensors`, which is how
/// validation and suite passes on the host backend skip the
/// Tensor→Literal→Tensor round-trip entirely (the PJRT path keeps the
/// Literal interface).
pub fn host_eval_tensors(
    model: &ModelConfig,
    params: &[Tensor],
    tokens: &[i32],
    mask: &[f32],
    batch: usize,
    cfg: &Parallelism,
) -> Result<(f32, f32)> {
    let (s, v) = (model.seq_len, model.vocab_size);
    if tokens.len() != batch * s || mask.len() != batch * s {
        bail!("eval batch shape mismatch: {} tokens, {} mask", tokens.len(), mask.len());
    }
    check_tokens(tokens, v)?;
    let mut stats = StepStats::new(QuantTensorId::count(model));
    let quant = HostQuant::baseline();
    // Baseline recipe: no quantization decisions run, so the policy is
    // inert here — eval scores are policy-independent by construction.
    let env =
        StepEnv { quant: &quant, th: 1.0, policy: &MorThresholdPolicy, step: 0, faults: None };
    let (logits, _) = forward(model, &env, params, tokens, batch, &mut stats, false, cfg);
    let mut n = 0f64;
    let mut loss = 0f64;
    let mut correct = 0f64;
    for b in 0..batch {
        for si in 0..s - 1 {
            let w = mask[b * s + si];
            if w == 0.0 {
                continue;
            }
            let r = b * s + si;
            let target = tokens[b * s + si + 1] as usize;
            let row = &logits.data()[r * v..(r + 1) * v];
            let maxv = row.iter().fold(f32::NEG_INFINITY, |a, x| a.max(*x));
            let sumexp: f32 = row.iter().map(|x| (x - maxv).exp()).sum();
            let lse = maxv + sumexp.ln();
            loss += ((lse - row[target]) * w) as f64;
            // total_cmp: NaN logits (diverged params) must not panic
            // mid-eval — the NaN loss above already surfaces them.
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            correct += ((pred == target) as u32 as f32 * w) as f64;
            n += w as f64;
        }
    }
    let n = n.max(1.0);
    Ok(((loss / n) as f32, (correct / n) as f32))
}

/// Standalone fake-quant "kernel": (x) → (qdq(x), mean relative error),
/// the host twin of the compiled quant artifacts.
pub fn host_quant(
    x: &Tensor,
    fmt: ReprType,
    partition: Partition,
    scaling: ScalingAlgo,
    cfg: &Parallelism,
) -> (Tensor, f32) {
    let fq = fake_quantize_with(x, fmt, partition, scaling, cfg);
    let relerr = fq.global_err.mean() as f32;
    (fq.out, relerr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::BatchLoader;
    use crate::data::synthetic::CorpusProfile;

    #[test]
    fn quant_fields_roundtrip() {
        let q = HostQuant::from_fields("tensor_level", "block128x128", "gam").unwrap();
        assert_eq!(q.kind, HostRecipeKind::TensorLevel);
        assert_eq!(q.partition, HostPartition::Fixed(Partition::BLOCK128));
        let q = HostQuant::from_fields("subtensor3", "channel", "amax").unwrap();
        assert_eq!(q.partition.resolve(0), Partition::ChannelRows);
        assert_eq!(q.partition.resolve(1), Partition::ChannelCols);
        assert!(HostQuant::from_fields("??", "tensor", "gam").is_err());
        assert!(HostQuant::from_fields("baseline", "??", "gam").is_err());
        assert!(HostQuant::from_fields("baseline", "tensor", "??").is_err());
    }

    #[test]
    fn mor_quantize_baseline_is_identity() {
        let x = Tensor::normal(&[8, 8], 1.0, 1);
        let (out, re, fb) =
            mor_quantize(&HostQuant::baseline(), &x, 0.045, 0, &Parallelism::serial());
        assert_eq!(out, x);
        assert_eq!((re, fb), (0.0, 0.0));
    }

    #[test]
    fn mor_quantize_tensor_level_decides() {
        let q = HostQuant::from_fields("tensor_level", "tensor", "gam").unwrap();
        let smooth = Tensor::normal(&[16, 16], 1.0, 2);
        let (_, re, fb) = mor_quantize(&q, &smooth, 0.045, 0, &Parallelism::serial());
        assert!(re > 0.0 && re < 0.045);
        assert_eq!(fb, 0.0);
        // Wide-range tensor falls back and stays bit-identical.
        let mut wild = Tensor::normal(&[16, 16], 1.0, 3);
        for (i, v) in wild.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 13) as i32 - 6);
        }
        let (out, re, fb) = mor_quantize(&q, &wild, 0.045, 0, &Parallelism::serial());
        assert!(re >= 0.045);
        assert_eq!(fb, 1.0);
        assert_eq!(out, wild);
    }

    #[test]
    fn mor_quantize_policy_overrides_decisions() {
        use crate::mor::policy::StaticAssignmentPolicy;
        let cfg = Parallelism::serial();
        let mut wild = Tensor::normal(&[16, 16], 1.0, 3);
        for (i, v) in wild.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 13) as i32 - 6);
        }
        // The no-policy entry point is the threshold policy, bit for bit.
        for (recipe, partition) in
            [("tensor_level", "tensor"), ("subtensor2", "block4x4"), ("subtensor3", "block4x4")]
        {
            let q = HostQuant::from_fields(recipe, partition, "gam").unwrap();
            let (a, rea, fba) = mor_quantize(&q, &wild, 0.045, 0, &cfg);
            let (b, reb, fbb) = mor_quantize_policy(
                &q,
                &wild,
                0.045,
                0,
                &MorThresholdPolicy,
                TensorScope::default(),
                None,
                &cfg,
            );
            assert_eq!(a, b, "{recipe} output");
            assert_eq!((rea.to_bits(), fba.to_bits()), (reb.to_bits(), fbb.to_bits()));
        }
        // A static all-E4M3 assignment forces the accept the threshold
        // policy refuses on this wide-range tensor.
        let q = HostQuant::from_fields("tensor_level", "tensor", "gam").unwrap();
        let (_, re, fb) = mor_quantize(&q, &wild, 0.045, 0, &cfg);
        assert!(re >= 0.045 && fb == 1.0);
        let all_e4m3 = StaticAssignmentPolicy { table: [ReprType::E4M3; 3] };
        let (out, re, fb) = mor_quantize_policy(
            &q,
            &wild,
            0.045,
            0,
            &all_e4m3,
            TensorScope::default(),
            None,
            &cfg,
        );
        assert!(re >= 0.045, "telemetry is policy-independent");
        assert_eq!(fb, 0.0, "static policy accepts regardless of relerr");
        assert_ne!(out, wild, "accepted tensor is actually quantized");
    }

    #[test]
    fn fused_pack_matches_materialized_quantize() {
        // Every recipe class: the fused quantize-on-pack buffer must
        // equal pack_b() of the materialized quantization, and the
        // telemetry must match bit for bit.
        let mut x = Tensor::normal(&[24, 20], 1.0, 77);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 9) as i32 - 4); // wide range: mixed decisions
        }
        let cfg = Parallelism::serial();
        for (recipe, partition, scaling) in [
            ("baseline", "tensor", "gam"),
            ("tensor_level", "block128x128", "gam"),
            ("tensor_level", "tensor", "amax"), // wild input: falls back
            ("subtensor2", "block4x4", "gam"),
            ("subtensor3", "block4x4", "gam"),
            ("subtensor3", "channel", "amax"),
        ] {
            let q = HostQuant::from_fields(recipe, partition, scaling).unwrap();
            for direction in [0usize, 1] {
                let (qt, re, fb) = mor_quantize(&q, &x, 0.045, direction, &cfg);
                let (pk, re2, fb2) = mor_quantize_packed(&q, &x, 0.045, direction, &cfg);
                assert_eq!(re.to_bits(), re2.to_bits(), "{recipe} relerr");
                assert_eq!(fb.to_bits(), fb2.to_bits(), "{recipe} fallback");
                let want = pack_b(&qt);
                assert_eq!(want.data().len(), pk.data().len());
                for (i, (a, b)) in want.data().iter().zip(pk.data()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{recipe}/{partition} dir {direction} pack element {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn layernorm_roundtrip_gradients() {
        // Finite-difference check of layernorm_bwd on a small input.
        let x = Tensor::normal(&[3, 5], 1.0, 4);
        let scale = Tensor::from_vec(&[5], vec![1.0, 0.9, 1.1, 1.2, 0.8]);
        let bias = Tensor::from_vec(&[5], vec![0.1, -0.1, 0.0, 0.2, -0.2]);
        let (y0, cache) = layernorm_fwd(&x, &scale, &bias);
        let dy = Tensor::normal(&[3, 5], 1.0, 5);
        let (dx, _, _) = layernorm_bwd(&cache, &scale, &dy);
        // loss = sum(y * dy); numeric dx via central differences.
        let eps = 1e-3f32;
        for i in [0usize, 7, 14] {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let (yp, _) = layernorm_fwd(&xp, &scale, &bias);
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let (ym, _) = layernorm_fwd(&xm, &scale, &bias);
            let num: f32 = yp
                .data()
                .iter()
                .zip(ym.data())
                .zip(dy.data())
                .map(|((a, b), d)| (a - b) / (2.0 * eps) * d)
                .sum();
            assert!(
                (num - dx.data()[i]).abs() < 2e-2 * (1.0 + num.abs()),
                "i={i}: numeric {num} vs analytic {}",
                dx.data()[i]
            );
        }
        let _ = y0;
    }

    #[test]
    fn attention_shapes_and_causality() {
        let m = ModelConfig::TINY;
        let bs = 2 * m.seq_len;
        let q3 = Tensor::normal(&[bs, m.d_model], 0.5, 6);
        let k3 = Tensor::normal(&[bs, m.d_model], 0.5, 7);
        let mut v3 = Tensor::normal(&[bs, m.d_model], 0.5, 8);
        let (out1, _) = attention_fwd(&m, 2, &q3, &k3, &v3);
        // Perturbing v at the LAST position must not change position 0.
        let last = (m.seq_len - 1) * m.d_model;
        v3.data_mut()[last] += 100.0;
        let (out2, _) = attention_fwd(&m, 2, &q3, &k3, &v3);
        for c in 0..m.d_model {
            assert_eq!(out1.data()[c], out2.data()[c], "causality violated at col {c}");
        }
        assert_eq!(out1.shape(), &[bs, m.d_model]);
    }

    #[test]
    fn host_training_reduces_loss() {
        let model = ModelConfig::TINY;
        let mut t = HostTrainer::new(model, HostQuant::baseline(), 42, Parallelism::auto());
        let profile = CorpusProfile::Nemotron4Like;
        let loader = BatchLoader::new(profile, model.vocab_size, 4, model.seq_len, 42, 0);
        let mut first = 0f32;
        let mut last = 0f32;
        for i in 0..8 {
            let b = loader.next_batch();
            let (loss, _, _) = t.step(&b.tokens, 4, 3e-3, 0.045, (i + 1) as f32).unwrap();
            assert!(loss.is_finite(), "step {i} loss {loss}");
            if i == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first, "loss should drop: first {first}, last {last}");
    }

    #[test]
    fn host_step_emits_quant_stats() {
        let model = ModelConfig::TINY;
        let quant = HostQuant::from_fields("tensor_level", "block128x128", "gam").unwrap();
        let mut t = HostTrainer::new(model, quant, 7, Parallelism::auto());
        let profile = CorpusProfile::Nemotron4Like;
        let loader = BatchLoader::new(profile, model.vocab_size, 2, model.seq_len, 7, 0);
        let b = loader.next_batch();
        let (loss, relerr, fallback) = t.step(&b.tokens, 2, 1e-3, 0.045, 1.0).unwrap();
        assert!(loss.is_finite());
        assert_eq!(relerr.len(), QuantTensorId::count(&model));
        assert_eq!(fallback.len(), relerr.len());
        assert!(relerr.iter().any(|r| *r > 0.0), "no relerr recorded");
        assert!(fallback.iter().all(|f| (0.0..=1.0).contains(f)));
    }

    #[test]
    fn host_eval_scores_in_range() {
        let model = ModelConfig::TINY;
        let t = HostTrainer::new(model, HostQuant::baseline(), 3, Parallelism::auto());
        let profile = CorpusProfile::Nemotron4Like;
        let loader = BatchLoader::new(profile, model.vocab_size, 2, model.seq_len, 3, 1);
        let b = loader.next_batch();
        let mask = crate::coordinator::trainer::full_mask(2, model.seq_len);
        let (loss, acc) =
            host_eval_tensors(&model, &t.params, &b.tokens, &mask, 2, &t.par).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        // Untrained ≈ chance over 256 symbols.
        assert!(acc < 0.1, "untrained acc {acc}");
    }
}
