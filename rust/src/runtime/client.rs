//! The PJRT client wrapper and the typed execution sessions.

use super::manifest::{ArtifactKind, Manifest};
use crate::model::config::ModelConfig;
use crate::model::naming::{param_specs, QuantTensorId};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// A loaded artifact set: PJRT client + manifest + compiled-executable
/// cache. One `Runtime` per artifact directory / model preset.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub model: ModelConfig,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest in `dir` and verify it matches the preset.
    pub fn load(dir: &Path, model: ModelConfig) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest.check_model(&model)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, model, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("artifact path not utf8")?,
        )
        .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Start a training session for a train artifact, initializing
    /// parameters and Adam state host-side (deterministic seed).
    pub fn train_session(&self, name: &str, seed: u64) -> Result<TrainSession> {
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Train {
            bail!("artifact {name} is not a train step");
        }
        let exe = self.executable(name)?;
        let batch = entry.usize_field("batch")?;
        let specs = param_specs(&self.model);
        if let Ok(n) = entry.usize_field("num_params") {
            if n != specs.len() {
                bail!("artifact {name} has {n} params, Rust expects {}", specs.len());
            }
        }
        let stats_len = entry.usize_field("stats_len").unwrap_or(0);
        if stats_len != QuantTensorId::count(&self.model) {
            bail!(
                "artifact {name} stats_len {} != expected {}",
                stats_len,
                QuantTensorId::count(&self.model)
            );
        }
        // Initialization mirrors python/compile/model.py `init_params`:
        // scaled-normal weights, ones/zeros for LN.
        let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * specs.len());
        for (i, s) in specs.iter().enumerate() {
            let t = init_param(&self.model, &s.name, &s.shape, seed.wrapping_add(i as u64));
            state.push(tensor_to_literal(&t)?);
        }
        for s in &specs {
            state.push(tensor_to_literal(&Tensor::zeros(&s.shape))?); // m
        }
        for s in &specs {
            state.push(tensor_to_literal(&Tensor::zeros(&s.shape))?); // v
        }
        Ok(TrainSession {
            exe,
            num_params: specs.len(),
            stats_len,
            batch,
            seq: self.model.seq_len,
            state,
            step: 0,
        })
    }

    /// Create an eval session for the eval artifact.
    pub fn eval_session(&self, name: &str) -> Result<EvalSession> {
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Eval {
            bail!("artifact {name} is not an eval step");
        }
        Ok(EvalSession {
            exe: self.executable(name)?,
            batch: entry.usize_field("batch")?,
            seq: self.model.seq_len,
            num_params: param_specs(&self.model).len(),
        })
    }

    /// Create a quant session (standalone kernel executable).
    pub fn quant_session(&self, name: &str) -> Result<QuantSession> {
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Quant {
            bail!("artifact {name} is not a quant kernel");
        }
        Ok(QuantSession {
            exe: self.executable(name)?,
            rows: entry.usize_field("rows")?,
            cols: entry.usize_field("cols")?,
        })
    }
}

/// Parameter initialization — must match `model.init_params` in python
/// (both draw from the same xorshift/Box–Muller stream via
/// [`Tensor::normal`]; the checkpoint tests pin equality).
pub fn init_param(m: &ModelConfig, name: &str, shape: &[usize], seed: u64) -> Tensor {
    if name.contains("ln") && name.ends_with("scale") {
        Tensor::from_vec(shape, vec![1.0; shape.iter().product()])
    } else if name.ends_with("bias") {
        Tensor::zeros(shape)
    } else {
        // 0.02 init for embeddings, 1/sqrt(d) style for projections.
        let std = if name.starts_with("embedding") || name.starts_with("lm_head") {
            0.02
        } else {
            (2.0 / (m.d_model as f32 + shape[0] as f32)).sqrt()
        };
        Tensor::normal(shape, std, seed)
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    Ok(xla::Literal::vec1(tokens).reshape(&[batch as i64, seq as i64])?)
}

/// Host-visible outputs of one training step.
#[derive(Debug, Clone)]
pub struct StepOutputs {
    pub loss: f32,
    /// Per-slot E4M3 relative error, indexed by [`QuantTensorId::flat`].
    pub relerr: Vec<f32>,
    /// Per-slot BF16-fallback fraction in [0,1] (0/1 for tensor-level
    /// decisions, block fraction for sub-tensor recipes).
    pub fallback: Vec<f32>,
}

/// A live training run: owns the param/optimizer state literals and the
/// compiled step.
pub struct TrainSession {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub num_params: usize,
    pub stats_len: usize,
    pub batch: usize,
    pub seq: usize,
    /// params ++ m ++ v, in canonical order.
    state: Vec<xla::Literal>,
    step: u64,
}

impl TrainSession {
    /// Run one optimizer step on a token batch.
    pub fn step(&mut self, tokens: &[i32], lr: f32, threshold: f32) -> Result<StepOutputs> {
        let adam_t = (self.step + 1) as f32;
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        let toks = tokens_literal(tokens, self.batch, self.seq)?;
        let t_lit = xla::Literal::scalar(adam_t);
        let lr_lit = xla::Literal::scalar(lr);
        let th_lit = xla::Literal::scalar(threshold);
        inputs.push(&toks);
        inputs.push(&t_lit);
        inputs.push(&lr_lit);
        inputs.push(&th_lit);

        let result = self.exe.execute::<&xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut parts = tuple.to_tuple()?;
        let expect = 3 * self.num_params + 3;
        if parts.len() != expect {
            bail!("train step returned {} outputs, expected {expect}", parts.len());
        }
        // Outputs: params ++ m ++ v ++ [loss, relerr, fallback].
        let fallback = parts.pop().unwrap().to_vec::<f32>()?;
        let relerr = parts.pop().unwrap().to_vec::<f32>()?;
        let loss = parts.pop().unwrap().get_first_element::<f32>()?;
        self.state = parts;
        self.step += 1;
        Ok(StepOutputs { loss, relerr, fallback })
    }

    pub fn steps_taken(&self) -> u64 {
        self.step
    }

    /// Copy the current parameters to host tensors (for checkpoints,
    /// eval, and the param-norm metric).
    pub fn params(&self) -> Result<Vec<Tensor>> {
        self.state[..self.num_params].iter().map(literal_to_tensor).collect()
    }

    /// Borrow the parameter literals (zero-copy path for eval).
    pub fn param_literals(&self) -> &[xla::Literal] {
        &self.state[..self.num_params]
    }

    /// Global parameter L2 norm (Figures 5/6/8/20 bottom panel).
    pub fn param_norm(&self) -> Result<f32> {
        let mut sq = 0f64;
        for t in self.params()? {
            let n = t.l2() as f64;
            sq += n * n;
        }
        Ok(sq.sqrt() as f32)
    }

    /// Replace parameters (e.g. restoring a checkpoint).
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        if params.len() != self.num_params {
            bail!("expected {} params, got {}", self.num_params, params.len());
        }
        for (i, t) in params.iter().enumerate() {
            self.state[i] = tensor_to_literal(t)?;
        }
        Ok(())
    }

    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }
}

/// Masked-eval session: loss + next-token accuracy over masked positions.
pub struct EvalSession {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq: usize,
    pub num_params: usize,
}

impl EvalSession {
    /// Evaluate one batch: `mask[b,s] = 1` marks scored positions.
    pub fn eval(
        &self,
        params: &[xla::Literal],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)> {
        if params.len() != self.num_params {
            bail!("expected {} params, got {}", self.num_params, params.len());
        }
        let toks = tokens_literal(tokens, self.batch, self.seq)?;
        let mask_lit =
            xla::Literal::vec1(mask).reshape(&[self.batch as i64, self.seq as i64])?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&toks);
        inputs.push(&mask_lit);
        let result = self.exe.execute::<&xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 2 {
            bail!("eval step returned {} outputs, expected 2", parts.len());
        }
        let loss = parts[0].get_first_element::<f32>()?;
        let acc = parts[1].get_first_element::<f32>()?;
        Ok((loss, acc))
    }
}

/// Standalone quant-kernel session (cross-validation + benches): input
/// one `[rows, cols]` tensor, output (qdq tensor, global relerr).
pub struct QuantSession {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub rows: usize,
    pub cols: usize,
}

impl QuantSession {
    pub fn run(&self, x: &Tensor) -> Result<(Tensor, f32)> {
        assert_eq!(x.shape(), &[self.rows, self.cols], "quant kernel shape mismatch");
        let lit = tensor_to_literal(x)?;
        let result = self.exe.execute::<&xla::Literal>(&[&lit])?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 2 {
            bail!("quant kernel returned {} outputs, expected 2", parts.len());
        }
        let out = literal_to_tensor(&parts[0])?;
        let relerr = parts[1].get_first_element::<f32>()?;
        Ok((out, relerr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_param_conventions() {
        let m = ModelConfig::TINY;
        let ln = init_param(&m, "decoder.layer.0.ln1.scale", &[64], 1);
        assert!(ln.data().iter().all(|v| *v == 1.0));
        let bias = init_param(&m, "decoder.layer.0.ln1.bias", &[64], 1);
        assert!(bias.data().iter().all(|v| *v == 0.0));
        let w = init_param(&m, "decoder.layer.0.mlp.fc1.weight", &[64, 256], 1);
        assert!(w.amax() > 0.0 && w.amax() < 1.0);
        let e = init_param(&m, "embedding.weight", &[256, 64], 2);
        let std =
            (e.data().iter().map(|v| v * v).sum::<f32>() / e.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.003, "std={std}");
    }

    // PJRT-dependent paths are covered by rust/tests/integration_*.rs
    // (they need built artifacts).
}
