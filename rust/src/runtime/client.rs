//! The typed execution sessions, dispatching over two backends:
//!
//! * **PJRT** — compiled HLO artifacts via the `xla` crate (the
//!   original path; requires `make artifacts`).
//! * **Host** — the pure-Rust mirror in [`super::host`], requiring no
//!   artifacts at all: [`Runtime::host`] builds a synthetic manifest
//!   and every session runs the bit-exact host numerics on the parallel
//!   chunked engine.
//!
//! The session API (`TrainSession::step`, `EvalSession::eval`,
//! `QuantSession::run`) is identical for both, so the coordinator,
//! report harness and benches never know which backend they drive.

use super::host::{host_eval_tensors, host_quant, HostQuant, HostTrainer};
use super::manifest::{ArtifactKind, Manifest};
use crate::formats::ReprType;
use crate::model::config::ModelConfig;
use crate::mor::policy::{self, PolicyRef};
use crate::model::naming::{param_specs, QuantTensorId};
use crate::quant::partition::Partition;
use crate::scaling::delayed::AmaxHistory;
use crate::scaling::ScalingAlgo;
use crate::tensor::Tensor;
use crate::util::par::{self, Parallelism};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

enum Backend {
    Pjrt {
        client: xla::PjRtClient,
        cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    },
    Host,
}

/// Per-session execution context: the run-scoped knobs every session
/// constructor threads together — the engine handle and the precision
/// decision policy. [`Runtime::session_ctx`] seeds one from the
/// runtime's defaults; callers override fields before passing it to
/// the `*_session_ctx` constructors (that is what `Trainer::run` does).
#[derive(Clone)]
pub struct SessionCtx {
    pub parallelism: Parallelism,
    pub policy: PolicyRef,
}

impl SessionCtx {
    /// This context with a different engine handle.
    pub fn with_parallelism(mut self, p: Parallelism) -> SessionCtx {
        self.parallelism = p;
        self
    }

    /// This context with a different decision policy.
    pub fn with_policy(mut self, p: PolicyRef) -> SessionCtx {
        self.policy = p;
        self
    }
}

/// A loaded artifact set: backend + manifest + model preset. One
/// `Runtime` per artifact directory (PJRT) or per preset (host). The
/// runtime also owns the default [`Parallelism`] handle and
/// [`PolicyRef`] its sessions inherit; per-run overrides go through
/// the `*_session_with` / `*_session_ctx` constructors (that is what
/// `Trainer::run` does), replacing the old process-global scoped
/// override.
pub struct Runtime {
    backend: Backend,
    pub manifest: Manifest,
    pub model: ModelConfig,
    parallelism: Parallelism,
    policy: PolicyRef,
}

impl Runtime {
    /// Load the manifest in `dir` and verify it matches the preset
    /// (PJRT backend).
    pub fn load(dir: &Path, model: ModelConfig) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest.check_model(&model)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            backend: Backend::Pjrt { client, cache: RefCell::new(HashMap::new()) },
            manifest,
            model,
            parallelism: par::global(),
            policy: policy::global(),
        })
    }

    /// Artifact-free host runtime: a synthetic manifest covering the
    /// standard train/eval/quant artifact set, executed by the host
    /// mirror. The end-to-end path for tests, benches and `repro`
    /// commands when no compiled artifacts exist.
    pub fn host(model: ModelConfig) -> Runtime {
        Self::host_with(model, par::global(), policy::global())
    }

    /// [`Runtime::host`] with explicit engine/policy defaults instead
    /// of the process globals. The fleet scheduler builds one runtime
    /// per tenant slice on pool worker threads; taking the handles as
    /// arguments keeps a tenant's numerics independent of whatever
    /// ambient global another run may have installed.
    pub fn host_with(model: ModelConfig, parallelism: Parallelism, policy: PolicyRef) -> Runtime {
        Runtime {
            backend: Backend::Host,
            manifest: Manifest::host_synthetic(&model),
            model,
            parallelism,
            policy,
        }
    }

    /// This runtime with a different default [`Parallelism`]; sessions
    /// created afterwards inherit the new handle (and its pool).
    pub fn with_parallelism(mut self, p: Parallelism) -> Runtime {
        self.parallelism = p;
        self
    }

    /// Replace the default [`Parallelism`] in place. Existing sessions
    /// keep the handle they were created with.
    pub fn set_parallelism(&mut self, p: Parallelism) {
        self.parallelism = p;
    }

    /// The default engine handle sessions inherit.
    pub fn parallelism(&self) -> &Parallelism {
        &self.parallelism
    }

    /// This runtime with a different default [`DecisionPolicy`]
    /// ([`crate::mor::policy::DecisionPolicy`]); sessions created
    /// afterwards inherit it.
    pub fn with_policy(mut self, p: PolicyRef) -> Runtime {
        self.policy = p;
        self
    }

    /// Replace the default policy in place. Existing sessions keep the
    /// policy they were created with.
    pub fn set_policy(&mut self, p: PolicyRef) {
        self.policy = p;
    }

    /// The default decision policy sessions inherit.
    pub fn policy(&self) -> &PolicyRef {
        &self.policy
    }

    /// A [`SessionCtx`] seeded from this runtime's defaults — the
    /// starting point for per-run overrides.
    pub fn session_ctx(&self) -> SessionCtx {
        SessionCtx { parallelism: self.parallelism.clone(), policy: self.policy.clone() }
    }

    /// The shared auto-backend policy: PJRT when a manifest exists at
    /// `dir`, the host backend otherwise. The CLI and the report
    /// harness both resolve through this.
    pub fn auto(dir: &Path, model: ModelConfig) -> Result<Runtime> {
        if dir.join("manifest.txt").exists() {
            Self::load(dir, model)
        } else {
            Ok(Self::host(model))
        }
    }

    /// Whether this runtime executes host-side (no PJRT).
    pub fn is_host(&self) -> bool {
        matches!(self.backend, Backend::Host)
    }

    /// Compile (or fetch from cache) an artifact by manifest name
    /// (PJRT backend only).
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let Backend::Pjrt { client, cache } = &self.backend else {
            bail!("host runtime has no compiled executables (artifact {name})");
        };
        if let Some(e) = cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("artifact path not utf8")?,
        )
        .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = Rc::new(exe);
        cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Start a training session for a train artifact, initializing
    /// parameters and Adam state host-side (deterministic seed). Uses
    /// the runtime's default [`SessionCtx`].
    pub fn train_session(&self, name: &str, seed: u64) -> Result<TrainSession> {
        self.train_session_ctx(name, seed, self.session_ctx())
    }

    /// [`Runtime::train_session`] with an explicit per-run
    /// [`Parallelism`] handle (owned by the session for its lifetime);
    /// the policy stays the runtime default.
    pub fn train_session_with(
        &self,
        name: &str,
        seed: u64,
        par: Parallelism,
    ) -> Result<TrainSession> {
        self.train_session_ctx(name, seed, self.session_ctx().with_parallelism(par))
    }

    /// [`Runtime::train_session`] with a full per-run [`SessionCtx`]
    /// (engine handle + decision policy) — the entry every other train
    /// constructor routes through.
    pub fn train_session_ctx(
        &self,
        name: &str,
        seed: u64,
        ctx: SessionCtx,
    ) -> Result<TrainSession> {
        let SessionCtx { parallelism: par, policy } = ctx;
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Train {
            bail!("artifact {name} is not a train step");
        }
        let batch = entry.usize_field("batch")?;
        let specs = param_specs(&self.model);
        if let Ok(n) = entry.usize_field("num_params") {
            if n != specs.len() {
                bail!("artifact {name} has {n} params, Rust expects {}", specs.len());
            }
        }
        let stats_len = entry.usize_field("stats_len").unwrap_or(0);
        if stats_len != QuantTensorId::count(&self.model) {
            bail!(
                "artifact {name} stats_len {} != expected {}",
                stats_len,
                QuantTensorId::count(&self.model)
            );
        }

        let imp = match &self.backend {
            Backend::Host => {
                let quant = HostQuant::from_fields(
                    entry.field("recipe").unwrap_or("baseline"),
                    entry.field("partition").unwrap_or("tensor"),
                    entry.field("scaling").unwrap_or("gam"),
                )
                .with_context(|| format!("artifact {name} recipe fields"))?;
                let trainer = HostTrainer::new(self.model, quant, seed, par).with_policy(policy);
                TrainImpl::Host {
                    trainer,
                    param_lits: Vec::new(),
                    lits_stale: true,
                    lits_rebuilds: 0,
                }
            }
            Backend::Pjrt { .. } => {
                // The compiled artifacts bake the paper's threshold
                // decisions into the HLO; a swapped-in policy cannot
                // reach them, so anything else must fail loudly.
                if policy.pin() != crate::mor::policy::MorThresholdPolicy.pin() {
                    bail!(
                        "the PJRT backend compiles the threshold policy into its \
                         artifacts; policy {:?} requires the host backend",
                        policy.describe()
                    );
                }
                let exe = self.executable(name)?;
                // Initialization mirrors python/compile/model.py
                // `init_params`: scaled-normal weights, ones/zeros for LN.
                let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * specs.len());
                for (i, s) in specs.iter().enumerate() {
                    let t =
                        init_param(&self.model, &s.name, &s.shape, seed.wrapping_add(i as u64));
                    state.push(tensor_to_literal(&t)?);
                }
                for s in &specs {
                    state.push(tensor_to_literal(&Tensor::zeros(&s.shape))?); // m
                }
                for s in &specs {
                    state.push(tensor_to_literal(&Tensor::zeros(&s.shape))?); // v
                }
                TrainImpl::Pjrt { exe, state }
            }
        };
        Ok(TrainSession {
            imp,
            num_params: specs.len(),
            stats_len,
            batch,
            seq: self.model.seq_len,
            step: 0,
        })
    }

    /// Create an eval session for the eval artifact, on the runtime's
    /// default [`Parallelism`].
    pub fn eval_session(&self, name: &str) -> Result<EvalSession> {
        self.eval_session_with(name, self.parallelism.clone())
    }

    /// [`Runtime::eval_session`] with a per-run [`SessionCtx`]. Eval
    /// runs the unquantized baseline forward, so only the engine handle
    /// is consulted; the policy rides along for constructor uniformity.
    pub fn eval_session_ctx(&self, name: &str, ctx: SessionCtx) -> Result<EvalSession> {
        self.eval_session_with(name, ctx.parallelism)
    }

    /// [`Runtime::eval_session`] with an explicit per-run handle.
    pub fn eval_session_with(&self, name: &str, par: Parallelism) -> Result<EvalSession> {
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Eval {
            bail!("artifact {name} is not an eval step");
        }
        let imp = match &self.backend {
            Backend::Host => EvalImpl::Host { model: self.model, par },
            Backend::Pjrt { .. } => EvalImpl::Pjrt(self.executable(name)?),
        };
        Ok(EvalSession {
            imp,
            batch: entry.usize_field("batch")?,
            seq: self.model.seq_len,
            num_params: param_specs(&self.model).len(),
        })
    }

    /// Create a quant session (standalone kernel executable), on the
    /// runtime's default [`Parallelism`].
    pub fn quant_session(&self, name: &str) -> Result<QuantSession> {
        self.quant_session_with(name, self.parallelism.clone())
    }

    /// [`Runtime::quant_session`] with a per-run [`SessionCtx`]. The
    /// standalone kernels quantize to a fixed artifact format — no
    /// decisions run, so only the engine handle is consulted.
    pub fn quant_session_ctx(&self, name: &str, ctx: SessionCtx) -> Result<QuantSession> {
        self.quant_session_with(name, ctx.parallelism)
    }

    /// [`Runtime::quant_session`] with an explicit per-run handle.
    pub fn quant_session_with(&self, name: &str, par: Parallelism) -> Result<QuantSession> {
        let entry = self.manifest.get(name)?;
        if entry.kind != ArtifactKind::Quant {
            bail!("artifact {name} is not a quant kernel");
        }
        let imp = match &self.backend {
            Backend::Host => QuantImpl::Host {
                fmt: entry
                    .field("format")
                    .and_then(ReprType::parse)
                    .ok_or_else(|| anyhow!("artifact {name} missing/unknown format"))?,
                partition: entry
                    .field("partition")
                    .and_then(Partition::parse)
                    .ok_or_else(|| anyhow!("artifact {name} missing/unknown partition"))?,
                scaling: entry
                    .field("scaling")
                    .and_then(ScalingAlgo::parse)
                    .ok_or_else(|| anyhow!("artifact {name} missing/unknown scaling"))?,
                par,
            },
            Backend::Pjrt { .. } => QuantImpl::Pjrt(self.executable(name)?),
        };
        Ok(QuantSession {
            imp,
            rows: entry.usize_field("rows")?,
            cols: entry.usize_field("cols")?,
        })
    }
}

/// Parameter initialization — must match `model.init_params` in python
/// (both draw from the same xorshift/Box–Muller stream via
/// [`Tensor::normal`]; the checkpoint tests pin equality).
pub fn init_param(m: &ModelConfig, name: &str, shape: &[usize], seed: u64) -> Tensor {
    if name.contains("ln") && name.ends_with("scale") {
        Tensor::from_vec(shape, vec![1.0; shape.iter().product()])
    } else if name.ends_with("bias") {
        Tensor::zeros(shape)
    } else {
        // 0.02 init for embeddings, 1/sqrt(d) style for projections.
        let std = if name.starts_with("embedding") || name.starts_with("lm_head") {
            0.02
        } else {
            (2.0 / (m.d_model as f32 + shape[0] as f32)).sqrt()
        };
        Tensor::normal(shape, std, seed)
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    Ok(xla::Literal::vec1(tokens).reshape(&[batch as i64, seq as i64])?)
}

/// Host-visible outputs of one training step.
#[derive(Debug, Clone)]
pub struct StepOutputs {
    pub loss: f32,
    /// Per-slot E4M3 relative error, indexed by [`QuantTensorId::flat`].
    pub relerr: Vec<f32>,
    /// Per-slot BF16-fallback fraction in [0,1] (0/1 for tensor-level
    /// decisions, block fraction for sub-tensor recipes).
    pub fallback: Vec<f32>,
    /// Per-slot amax observed this step (host backend; empty for PJRT).
    /// The numeric guard's overflow monitor reads these.
    pub amax: Vec<f32>,
    /// Non-finite gradient values found by the pre-update scan (always
    /// 0 unless [`TrainSession::set_guard_skip`] armed the scan).
    pub nonfinite_grads: u64,
    /// Whether the optimizer update was skipped because the scan found
    /// non-finite gradients.
    pub skipped: bool,
}

/// A borrowed view of a session's parameters in whichever form the
/// owning backend holds them — the zero-copy eval interchange.
///
/// The host backend hands out its tensors directly
/// ([`ParamsRef::Tensors`]); PJRT hands out its state literals
/// ([`ParamsRef::Literals`]). `EvalSession::eval_params` accepts either
/// and only converts when the *backends* genuinely differ, so the
/// host-train → host-eval path allocates no `Literal` copies at all.
#[derive(Clone, Copy)]
pub enum ParamsRef<'a> {
    Tensors(&'a [Tensor]),
    Literals(&'a [xla::Literal]),
}

impl ParamsRef<'_> {
    pub fn len(&self) -> usize {
        match self {
            ParamsRef::Tensors(t) => t.len(),
            ParamsRef::Literals(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The complete dynamic state of a [`TrainSession`], in host form —
/// what [`TrainSession::export_state`] hands the checkpoint writer and
/// [`TrainSession::import_state`] restores on resume. Restoring this
/// (plus the coordinator-owned state: data cursors, stats, metrics) is
/// what makes a resumed run bitwise identical to an uninterrupted one.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Completed optimizer steps (drives Adam bias correction).
    pub step: u64,
    /// Parameters, canonical `param_specs` order.
    pub params: Vec<Tensor>,
    /// Adam first moments, same order.
    pub opt_m: Vec<Tensor>,
    /// Adam second moments, same order.
    pub opt_v: Vec<Tensor>,
    /// Per-slot delayed-scaling amax histories (host backend; empty
    /// for PJRT, whose device state carries no host-side telemetry).
    pub amax_hist: Vec<AmaxHistory>,
}

enum TrainImpl {
    /// Compiled step: owns the param/optimizer state literals.
    Pjrt { exe: Rc<xla::PjRtLoadedExecutable>, state: Vec<xla::Literal> },
    /// Host mirror: owns tensors; `param_lits` shadows the parameters
    /// so `param_literals` serves the cross-backend interchange,
    /// rebuilt lazily and **exactly once per staleness window** (the
    /// stale flag keeps the per-step cost at zero when nothing reads
    /// the literals between steps; `lits_rebuilds` counts rebuilds so
    /// tests can pin both properties). The tensor-native eval path
    /// (`params_ref`) never touches this shadow at all.
    Host {
        trainer: HostTrainer,
        param_lits: Vec<xla::Literal>,
        lits_stale: bool,
        lits_rebuilds: u64,
    },
}

/// A live training run: owns the model state and the step function.
pub struct TrainSession {
    imp: TrainImpl,
    pub num_params: usize,
    pub stats_len: usize,
    pub batch: usize,
    pub seq: usize,
    step: u64,
}

impl TrainSession {
    /// Run one optimizer step on a token batch.
    pub fn step(&mut self, tokens: &[i32], lr: f32, threshold: f32) -> Result<StepOutputs> {
        let adam_t = (self.step + 1) as f32;
        let out = match &mut self.imp {
            TrainImpl::Host { trainer, lits_stale, .. } => {
                let (loss, relerr, fallback) =
                    trainer.step(tokens, self.batch, lr, threshold, adam_t)?;
                *lits_stale = true;
                StepOutputs {
                    loss,
                    relerr,
                    fallback,
                    amax: trainer.last_amax().to_vec(),
                    nonfinite_grads: trainer.last_nonfinite_grads(),
                    skipped: trainer.last_update_skipped(),
                }
            }
            TrainImpl::Pjrt { exe, state } => {
                let mut inputs: Vec<&xla::Literal> = state.iter().collect();
                let toks = tokens_literal(tokens, self.batch, self.seq)?;
                let t_lit = xla::Literal::scalar(adam_t);
                let lr_lit = xla::Literal::scalar(lr);
                let th_lit = xla::Literal::scalar(threshold);
                inputs.push(&toks);
                inputs.push(&t_lit);
                inputs.push(&lr_lit);
                inputs.push(&th_lit);

                let result = exe.execute::<&xla::Literal>(&inputs)?;
                let tuple = result[0][0].to_literal_sync()?;
                let mut parts = tuple.to_tuple()?;
                let expect = 3 * self.num_params + 3;
                if parts.len() != expect {
                    bail!("train step returned {} outputs, expected {expect}", parts.len());
                }
                // Outputs: params ++ m ++ v ++ [loss, relerr, fallback].
                let fallback = parts.pop().unwrap().to_vec::<f32>()?;
                let relerr = parts.pop().unwrap().to_vec::<f32>()?;
                let loss = parts.pop().unwrap().get_first_element::<f32>()?;
                *state = parts;
                StepOutputs {
                    loss,
                    relerr,
                    fallback,
                    amax: Vec::new(),
                    nonfinite_grads: 0,
                    skipped: false,
                }
            }
        };
        self.step += 1;
        Ok(out)
    }

    pub fn steps_taken(&self) -> u64 {
        self.step
    }

    /// Arm (or disarm) the pre-update non-finite gradient scan — the
    /// numeric guard's first rung. A no-op on PJRT, where the update is
    /// baked into the compiled step.
    pub fn set_guard_skip(&mut self, on: bool) {
        if let TrainImpl::Host { trainer, .. } = &mut self.imp {
            trainer.set_skip_nonfinite(on);
        }
    }

    /// Install a deterministic fault-injection plan (`--faults`); pass
    /// `None` to clear. Injection hooks exist only in the host mirror,
    /// so a plan on the PJRT backend fails loudly.
    pub fn set_faults(
        &mut self,
        faults: Option<std::sync::Arc<crate::faults::FaultPlan>>,
    ) -> Result<()> {
        match &mut self.imp {
            TrainImpl::Host { trainer, .. } => {
                trainer.set_faults(faults);
                Ok(())
            }
            TrainImpl::Pjrt { .. } => {
                if faults.is_some() {
                    bail!("fault injection (--faults) requires the host backend");
                }
                Ok(())
            }
        }
    }

    /// Copy the current parameters to host tensors (for checkpoints,
    /// eval, and the param-norm metric).
    pub fn params(&self) -> Result<Vec<Tensor>> {
        match &self.imp {
            TrainImpl::Host { trainer, .. } => Ok(trainer.params.clone()),
            TrainImpl::Pjrt { state, .. } => {
                state[..self.num_params].iter().map(literal_to_tensor).collect()
            }
        }
    }

    /// Borrow the current parameters in the backend's native form —
    /// the zero-copy eval interchange. Prefer this over
    /// [`TrainSession::param_literals`]: on the host backend it borrows
    /// the trainer's tensors directly (no Literal shadow is built or
    /// refreshed, and staleness cannot arise by construction).
    pub fn params_ref(&self) -> ParamsRef<'_> {
        match &self.imp {
            TrainImpl::Host { trainer, .. } => ParamsRef::Tensors(&trainer.params),
            TrainImpl::Pjrt { state, .. } => ParamsRef::Literals(&state[..self.num_params]),
        }
    }

    /// Borrow the parameter literals (the cross-backend interchange).
    /// For the host backend the shadow copy is rebuilt here, lazily and
    /// exactly once after any step/param mutation, however many times
    /// it is read in between.
    pub fn param_literals(&mut self) -> &[xla::Literal] {
        match &mut self.imp {
            TrainImpl::Host { trainer, param_lits, lits_stale, lits_rebuilds } => {
                if *lits_stale {
                    *param_lits = trainer
                        .params
                        .iter()
                        .map(|t| {
                            tensor_to_literal(t).expect("param tensors are well-shaped")
                        })
                        .collect();
                    *lits_stale = false;
                    *lits_rebuilds += 1;
                }
                &param_lits[..]
            }
            TrainImpl::Pjrt { state, .. } => &state[..self.num_params],
        }
    }

    /// How many times the host backend rebuilt its Literal shadow (0
    /// for PJRT, where the state *is* literals). The regression hook
    /// for both "the stale path refreshes exactly once" and "the
    /// tensor-native eval path allocates no Literal copies".
    pub fn param_literal_rebuilds(&self) -> u64 {
        match &self.imp {
            TrainImpl::Host { lits_rebuilds, .. } => *lits_rebuilds,
            TrainImpl::Pjrt { .. } => 0,
        }
    }

    /// Global parameter L2 norm (Figures 5/6/8/20 bottom panel).
    pub fn param_norm(&self) -> Result<f32> {
        let mut sq = 0f64;
        for t in self.params()? {
            let n = t.l2() as f64;
            sq += n * n;
        }
        Ok(sq.sqrt() as f32)
    }

    /// Replace parameters (e.g. restoring a checkpoint).
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        if params.len() != self.num_params {
            bail!("expected {} params, got {}", self.num_params, params.len());
        }
        match &mut self.imp {
            TrainImpl::Host { trainer, lits_stale, .. } => {
                trainer.params = params.to_vec();
                *lits_stale = true;
            }
            TrainImpl::Pjrt { state, .. } => {
                for (i, t) in params.iter().enumerate() {
                    state[i] = tensor_to_literal(t)?;
                }
            }
        }
        Ok(())
    }

    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Export the complete dynamic session state (params + optimizer
    /// moments + step counter + scaling telemetry) as host tensors —
    /// the session half of a [`crate::coordinator::checkpoint`]
    /// `MORCKPT2` checkpoint. Works on both backends; PJRT pulls its
    /// state literals to host.
    pub fn export_state(&self) -> Result<TrainState> {
        match &self.imp {
            TrainImpl::Host { trainer, .. } => {
                let (m, v) = trainer.moments();
                Ok(TrainState {
                    step: self.step,
                    params: trainer.params.clone(),
                    opt_m: m.to_vec(),
                    opt_v: v.to_vec(),
                    amax_hist: trainer.amax_history().to_vec(),
                })
            }
            TrainImpl::Pjrt { state, .. } => {
                let n = self.num_params;
                let pull = |lits: &[xla::Literal]| -> Result<Vec<Tensor>> {
                    lits.iter().map(literal_to_tensor).collect()
                };
                Ok(TrainState {
                    step: self.step,
                    params: pull(&state[..n])?,
                    opt_m: pull(&state[n..2 * n])?,
                    opt_v: pull(&state[2 * n..3 * n])?,
                    amax_hist: Vec::new(),
                })
            }
        }
    }

    /// Restore a state exported by [`TrainSession::export_state`]. The
    /// arity/shape contract is checked; on success the session is
    /// bitwise indistinguishable from the one that exported — stepping
    /// it produces the exact sequence the original would have produced.
    pub fn import_state(&mut self, st: &TrainState) -> Result<()> {
        let n = self.num_params;
        if st.params.len() != n || st.opt_m.len() != n || st.opt_v.len() != n {
            bail!(
                "state arity mismatch: {} params / {} m / {} v, expected {n}",
                st.params.len(),
                st.opt_m.len(),
                st.opt_v.len()
            );
        }
        match &mut self.imp {
            TrainImpl::Host { trainer, lits_stale, .. } => {
                trainer.load_state(&st.params, &st.opt_m, &st.opt_v, &st.amax_hist)?;
                *lits_stale = true;
            }
            TrainImpl::Pjrt { state, .. } => {
                // Validate every shape against the live state literals
                // BEFORE overwriting anything, so a mismatched
                // checkpoint errors cleanly here (like the host
                // backend) instead of surfacing as an opaque XLA
                // execute failure — and never leaves the state
                // half-replaced.
                let full: Vec<&Tensor> =
                    st.params.iter().chain(&st.opt_m).chain(&st.opt_v).collect();
                for (i, t) in full.iter().enumerate() {
                    let shape = state[i].array_shape()?;
                    let dims: Vec<usize> =
                        shape.dims().iter().map(|d| *d as usize).collect();
                    if dims.as_slice() != t.shape() {
                        bail!(
                            "state shape mismatch at slot {i}: checkpoint {:?}, session {dims:?}",
                            t.shape()
                        );
                    }
                }
                for (i, t) in full.iter().enumerate() {
                    state[i] = tensor_to_literal(t)?;
                }
            }
        }
        self.step = st.step;
        Ok(())
    }
}

enum EvalImpl {
    Pjrt(Rc<xla::PjRtLoadedExecutable>),
    Host { model: ModelConfig, par: Parallelism },
}

/// Masked-eval session: loss + next-token accuracy over masked positions.
pub struct EvalSession {
    imp: EvalImpl,
    pub batch: usize,
    pub seq: usize,
    pub num_params: usize,
}

impl EvalSession {
    /// Evaluate one batch with parameters in either backend form — the
    /// preferred entry. Conversions happen only on the two cross-
    /// backend diagonals; the host-tensors and PJRT-literals cases run
    /// copy-free:
    ///
    /// | session \ params | `Tensors`             | `Literals`          |
    /// |------------------|-----------------------|---------------------|
    /// | Host             | zero-copy `host_eval_tensors` | Literal→Tensor once |
    /// | PJRT             | Tensor→Literal once   | zero-copy           |
    pub fn eval_params(
        &self,
        params: ParamsRef<'_>,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)> {
        if params.len() != self.num_params {
            bail!("expected {} params, got {}", self.num_params, params.len());
        }
        match (&self.imp, params) {
            (EvalImpl::Host { model, par }, ParamsRef::Tensors(tensors)) => {
                host_eval_tensors(model, tensors, tokens, mask, self.batch, par)
            }
            (_, ParamsRef::Literals(lits)) => self.eval(lits, tokens, mask),
            (EvalImpl::Pjrt(_), ParamsRef::Tensors(tensors)) => {
                let lits: Vec<xla::Literal> =
                    tensors.iter().map(tensor_to_literal).collect::<Result<Vec<_>>>()?;
                self.eval(&lits, tokens, mask)
            }
        }
    }

    /// Evaluate one batch: `mask[b,s] = 1` marks scored positions
    /// (the Literal-interchange entry; [`EvalSession::eval_params`]
    /// avoids the conversions when backends match).
    pub fn eval(
        &self,
        params: &[xla::Literal],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)> {
        if params.len() != self.num_params {
            bail!("expected {} params, got {}", self.num_params, params.len());
        }
        match &self.imp {
            EvalImpl::Host { model, par } => {
                let tensors: Vec<Tensor> =
                    params.iter().map(literal_to_tensor).collect::<Result<Vec<_>>>()?;
                host_eval_tensors(model, &tensors, tokens, mask, self.batch, par)
            }
            EvalImpl::Pjrt(exe) => {
                let toks = tokens_literal(tokens, self.batch, self.seq)?;
                let mask_lit =
                    xla::Literal::vec1(mask).reshape(&[self.batch as i64, self.seq as i64])?;
                let mut inputs: Vec<&xla::Literal> = params.iter().collect();
                inputs.push(&toks);
                inputs.push(&mask_lit);
                let result = exe.execute::<&xla::Literal>(&inputs)?;
                let tuple = result[0][0].to_literal_sync()?;
                let parts = tuple.to_tuple()?;
                if parts.len() != 2 {
                    bail!("eval step returned {} outputs, expected 2", parts.len());
                }
                let loss = parts[0].get_first_element::<f32>()?;
                let acc = parts[1].get_first_element::<f32>()?;
                Ok((loss, acc))
            }
        }
    }
}

enum QuantImpl {
    Pjrt(Rc<xla::PjRtLoadedExecutable>),
    Host { fmt: ReprType, partition: Partition, scaling: ScalingAlgo, par: Parallelism },
}

/// Standalone quant-kernel session (cross-validation + benches): input
/// one `[rows, cols]` tensor, output (qdq tensor, global relerr).
pub struct QuantSession {
    imp: QuantImpl,
    pub rows: usize,
    pub cols: usize,
}

impl QuantSession {
    pub fn run(&self, x: &Tensor) -> Result<(Tensor, f32)> {
        assert_eq!(x.shape(), &[self.rows, self.cols], "quant kernel shape mismatch");
        match &self.imp {
            QuantImpl::Host { fmt, partition, scaling, par } => {
                Ok(host_quant(x, *fmt, *partition, *scaling, par))
            }
            QuantImpl::Pjrt(exe) => {
                let lit = tensor_to_literal(x)?;
                let result = exe.execute::<&xla::Literal>(&[&lit])?;
                let tuple = result[0][0].to_literal_sync()?;
                let parts = tuple.to_tuple()?;
                if parts.len() != 2 {
                    bail!("quant kernel returned {} outputs, expected 2", parts.len());
                }
                let out = literal_to_tensor(&parts[0])?;
                let relerr = parts[1].get_first_element::<f32>()?;
                Ok((out, relerr))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_param_conventions() {
        let m = ModelConfig::TINY;
        let ln = init_param(&m, "decoder.layer.0.ln1.scale", &[64], 1);
        assert!(ln.data().iter().all(|v| *v == 1.0));
        let bias = init_param(&m, "decoder.layer.0.ln1.bias", &[64], 1);
        assert!(bias.data().iter().all(|v| *v == 0.0));
        let w = init_param(&m, "decoder.layer.0.mlp.fc1.weight", &[64, 256], 1);
        assert!(w.amax() > 0.0 && w.amax() < 1.0);
        let e = init_param(&m, "embedding.weight", &[256, 64], 2);
        let std =
            (e.data().iter().map(|v| v * v).sum::<f32>() / e.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.003, "std={std}");
    }

    #[test]
    fn host_runtime_serves_all_session_kinds() {
        let rt = Runtime::host(ModelConfig::TINY);
        assert!(rt.is_host());
        assert!(rt.manifest.check_model(&ModelConfig::TINY).is_ok());
        let mut s = rt.train_session("train_baseline", 5).unwrap();
        assert_eq!(s.stats_len, QuantTensorId::count(&ModelConfig::TINY));
        let tokens = vec![1i32; s.batch * s.seq];
        let out = s.step(&tokens, 1e-3, 0.045).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(s.steps_taken(), 1);
        assert_eq!(out.relerr.len(), s.stats_len);

        let ev = rt.eval_session("eval").unwrap();
        let mask = crate::coordinator::trainer::full_mask(ev.batch, ev.seq);
        let toks = vec![2i32; ev.batch * ev.seq];
        let (loss, acc) = ev.eval(s.param_literals(), &toks, &mask).unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));

        let qs = rt.quant_session("quant_e4m3_gam_block128").unwrap();
        let x = Tensor::normal(&[qs.rows, qs.cols], 1.0, 9);
        let (qx, relerr) = qs.run(&x).unwrap();
        assert_eq!(qx.shape(), x.shape());
        assert!(relerr > 0.0 && relerr < 0.1);
    }

    #[test]
    fn host_session_param_roundtrip() {
        let rt = Runtime::host(ModelConfig::TINY);
        let mut s = rt.train_session("train_baseline", 1).unwrap();
        let params = s.params().unwrap();
        assert_eq!(params.len(), s.num_params);
        assert_eq!(s.param_literals().len(), s.num_params);
        let n0 = s.param_norm().unwrap();
        s.set_params(&params).unwrap();
        let n1 = s.param_norm().unwrap();
        assert_eq!(n0, n1);
        // Wrong arity is rejected.
        assert!(s.set_params(&params[..1]).is_err());
    }

    #[test]
    fn host_eval_after_step_is_fresh_and_literal_free() {
        let rt = Runtime::host(ModelConfig::TINY);
        let mut s = rt.train_session("train_baseline", 5).unwrap();
        let ev = rt.eval_session("eval").unwrap();
        let toks: Vec<i32> = (0..ev.batch * ev.seq).map(|i| (i % 251) as i32).collect();
        let mask = crate::coordinator::trainer::full_mask(ev.batch, ev.seq);

        // Tensor-native eval before and after a train step: the second
        // eval must see the stepped parameters (no stale shadow), and
        // the whole sequence must build zero Literal copies.
        let (l0, _) = ev.eval_params(s.params_ref(), &toks, &mask).unwrap();
        let train_toks = vec![1i32; s.batch * s.seq];
        s.step(&train_toks, 1e-3, 0.045).unwrap();
        let (l1, _) = ev.eval_params(s.params_ref(), &toks, &mask).unwrap();
        assert_ne!(l0.to_bits(), l1.to_bits(), "eval did not see the stepped params");
        assert_eq!(
            s.param_literal_rebuilds(),
            0,
            "tensor-native host eval must not build Literal copies"
        );

        // The Literal interchange still works, refreshing lazily
        // exactly once per staleness window however often it is read.
        assert_eq!(s.param_literals().len(), s.num_params);
        let _ = s.param_literals();
        let _ = s.param_literals();
        assert_eq!(s.param_literal_rebuilds(), 1, "stale path must refresh exactly once");
        s.step(&train_toks, 1e-3, 0.045).unwrap();
        let _ = s.param_literals();
        assert_eq!(s.param_literal_rebuilds(), 2, "one refresh per mutation window");

        // Both interchanges agree bitwise on the same parameters.
        let (via_lits, _) = ev.eval(s.param_literals(), &toks, &mask).unwrap();
        let (via_tensors, _) = ev.eval_params(s.params_ref(), &toks, &mask).unwrap();
        assert_eq!(via_lits.to_bits(), via_tensors.to_bits());
    }

    #[test]
    fn host_runtime_rejects_unknown_and_kind_mismatch() {
        let rt = Runtime::host(ModelConfig::TINY);
        assert!(rt.train_session("nope", 1).is_err());
        assert!(rt.train_session("eval", 1).is_err());
        assert!(rt.eval_session("train_baseline").is_err());
        assert!(rt.executable("train_baseline").is_err());
    }

    #[test]
    fn sessions_inherit_runtime_parallelism_bitwise() {
        use crate::util::par::Parallelism;
        // A pooled runtime and a serial runtime must produce the exact
        // same step outputs (the parallel == serial contract, exercised
        // through the session API rather than the primitives).
        let pooled = Runtime::host(ModelConfig::TINY).with_parallelism(Parallelism::pooled(3, 1));
        assert_eq!(pooled.parallelism().threads, 3);
        let serial = Runtime::host(ModelConfig::TINY).with_parallelism(Parallelism::serial());
        let mut a = pooled.train_session("train_mor_tensor_block", 9).unwrap();
        let mut b = serial.train_session("train_mor_tensor_block", 9).unwrap();
        let tokens = vec![3i32; a.batch * a.seq];
        let oa = a.step(&tokens, 1e-3, 0.045).unwrap();
        let ob = b.step(&tokens, 1e-3, 0.045).unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits());
        assert_eq!(oa.relerr, ob.relerr);
        assert_eq!(oa.fallback, ob.fallback);
    }

    #[test]
    fn sessions_inherit_runtime_policy() {
        use crate::mor::policy::StaticAssignmentPolicy;
        use std::sync::Arc;
        let static_ref: PolicyRef =
            Arc::new(StaticAssignmentPolicy { table: [ReprType::E4M3; 3] });
        let rt = Runtime::host(ModelConfig::TINY);
        assert_eq!(rt.policy().describe(), "threshold");
        let forced = Runtime::host(ModelConfig::TINY).with_policy(static_ref.clone());
        assert_eq!(forced.policy().describe(), "static=e4m3,e4m3,e4m3");

        // An impossible threshold: the threshold policy rejects every
        // tensor (full fallback); the static assignment accepts
        // everything regardless of the measured error.
        let mut a = rt.train_session("train_mor_tensor_block", 9).unwrap();
        let mut b = forced.train_session("train_mor_tensor_block", 9).unwrap();
        let tokens = vec![3i32; a.batch * a.seq];
        let oa = a.step(&tokens, 1e-3, 1e-9).unwrap();
        let ob = b.step(&tokens, 1e-3, 1e-9).unwrap();
        assert!(oa.fallback.iter().all(|f| *f == 1.0), "threshold must reject all");
        assert!(ob.fallback.iter().all(|f| *f == 0.0), "static must accept all");

        // A per-session ctx override behaves exactly like the runtime
        // default it shadows.
        let ctx = rt.session_ctx().with_policy(static_ref);
        let mut c = rt.train_session_ctx("train_mor_tensor_block", 9, ctx).unwrap();
        let oc = c.step(&tokens, 1e-3, 1e-9).unwrap();
        assert_eq!(ob.loss.to_bits(), oc.loss.to_bits());
        assert_eq!(ob.relerr, oc.relerr);
        assert_eq!(ob.fallback, oc.fallback);
    }

    #[test]
    fn export_import_state_resumes_bitwise() {
        let rt = Runtime::host(ModelConfig::TINY);
        let mut a = rt.train_session("train_mor_tensor_block", 21).unwrap();
        let tokens: Vec<i32> = (0..a.batch * a.seq).map(|i| (i % 253) as i32).collect();
        for _ in 0..3 {
            a.step(&tokens, 1e-3, 0.045).unwrap();
        }
        let st = a.export_state().unwrap();
        assert_eq!(st.step, 3);
        assert_eq!(st.params.len(), a.num_params);
        assert_eq!(st.opt_m.len(), a.num_params);
        assert_eq!(st.amax_hist.len(), a.stats_len);
        assert!(st.amax_hist.iter().all(|h| h.len() == 3));
        // Moments are live after 3 steps.
        assert!(st.opt_m.iter().any(|t| t.data().iter().any(|v| *v != 0.0)));

        // A *different* fresh session (different seed) imports the
        // state and must continue exactly like the original.
        let mut b = rt.train_session("train_mor_tensor_block", 999).unwrap();
        b.import_state(&st).unwrap();
        assert_eq!(b.steps_taken(), 3);
        let oa = a.step(&tokens, 5e-4, 0.045).unwrap();
        let ob = b.step(&tokens, 5e-4, 0.045).unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits());
        assert_eq!(oa.relerr, ob.relerr);
        assert_eq!(oa.fallback, ob.fallback);
        let pa = a.params().unwrap();
        let pb = b.params().unwrap();
        for (x, y) in pa.iter().zip(pb.iter()) {
            assert_eq!(x, y, "params diverged after resume");
        }

        // Arity mismatches are rejected.
        let mut bad = st.clone();
        bad.opt_m.pop();
        assert!(b.import_state(&bad).is_err());
    }

    // PJRT-dependent paths are covered by rust/tests/integration_*.rs
    // (they need built artifacts).
}
