//! Runtime layer: typed train/eval/quant sessions over two backends.
//!
//! * **PJRT** ([`client`]): loads the HLO-text artifacts produced by
//!   `python/compile/aot.py`, compiles them on the PJRT CPU client (the
//!   `xla` crate), and executes them. Python is never on this path:
//!   artifacts are plain HLO text files and the manifest is a plain
//!   text file.
//! * **Host** ([`host`]): a pure-Rust mirror of the compiled step —
//!   transformer forward + manual backward + Adam + MoR telemetry on
//!   the bit-exact host numerics, parallelized by the chunked engine.
//!   [`Runtime::host`] needs no artifacts at all, which is what keeps
//!   `cargo test` and the trainer smoke tests self-contained.
//!
//! Every `Runtime` owns a default `util::par::Parallelism` handle (a
//! persistent worker pool) and a default `mor::policy` [`PolicyRef`];
//! sessions inherit both at creation. The `*_session_with`
//! constructors take an explicit per-run engine handle, and the
//! `*_session_ctx` constructors take a full [`SessionCtx`] (handle +
//! decision policy) — the path `Trainer::run` uses, so concurrent runs
//! never share or mutate a process-global setting.
//!
//! Parameters flow from train to eval sessions as a borrowed
//! [`ParamsRef`] (`TrainSession::params_ref` →
//! `EvalSession::eval_params`): tensors for the host backend, literals
//! for PJRT, converted only when the backends genuinely differ.
//!
//! Sessions are checkpointable: `TrainSession::export_state` /
//! `import_state` move the complete dynamic state ([`TrainState`]:
//! params, Adam moments, step counter, delayed-scaling amax histories)
//! in and out on both backends, which is what the coordinator's
//! `MORCKPT2` checkpoints and the bitwise resume ≡ continuous contract
//! are built on.
//!
//! ### Interchange notes (PJRT path)
//! * HLO **text** is the interchange format, not serialized protos
//!   (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids).
//! * Multi-output computations come back as **one tuple buffer**; the
//!   runtime pulls it to host and decomposes it. Train-state literals
//!   are reused directly as next-step inputs, so the only per-step cost
//!   is the unavoidable host↔device copy of the CPU PJRT client.

pub mod client;
pub mod host;
pub mod manifest;

pub use client::{
    EvalSession, ParamsRef, QuantSession, Runtime, SessionCtx, StepOutputs, TrainSession,
    TrainState,
};
pub use crate::mor::policy::PolicyRef;
pub use host::{HostQuant, HostTrainer, StepEnv};
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest};
