//! The fleet supervisor: per-tenant health, deterministic retry /
//! backoff, a stall watchdog, a degradation ladder, and a crash-safe
//! fleet manifest.
//!
//! PR 8 made a *single run* survive numeric faults (guard rewinds, the
//! checkpoint ring); the scheduler multiplexed runs into a fleet but
//! kept a binary view of tenant failure — one panic and the tenant is
//! dead. The supervisor closes that gap with three mechanisms, all
//! deterministic by construction:
//!
//! 1. **Retry with exponential backoff measured in scheduler rounds,
//!    not wall-clock.** A failed tenant re-enters the runnable set
//!    after `1, 2, 4, …` rounds (scaled by the configured base), so the
//!    supervised interleaving is a pure function of weights, failures
//!    and history — bitwise-reproducible at every `MOR_THREADS`.
//! 2. **A degradation ladder instead of binary death.** When the retry
//!    budget at the current rung is spent — or the tenant's own numeric
//!    guard exhausted its rewind budget, where retrying the same
//!    precision would just burn the budget again — the tenant is
//!    *demoted*: rung 1 forces a BF16 `StaticAssignmentPolicy` with a
//!    widened guard (precision quarantine), rung 2 additionally drops
//!    to scalar kernels. Each rung refreshes the retry budget; only a
//!    tenant that fails through every rung is declared Dead.
//! 3. **A stall watchdog counted in slices.** A tenant that keeps
//!    getting scheduled but stops completing steps (the `stall` fault
//!    class, or a real wedge self-preempted via the cooperative stop
//!    flag) accrues no-progress slices; after `stall_after` consecutive
//!    ones the watchdog trips and the failure ladder takes over.
//!
//! The whole ledger — health, budgets, backoff deadlines, pass
//! counters, the schedule log — is persisted after every round in a
//! **fleet manifest** (the same sectioned LE container + CRC32 trailer
//! + atomic fsync'd save as `MORCKPT2`), so `repro fleet --auto-resume`
//! restarts the *whole fleet* after a supervisor crash and the resumed
//! fleet is bitwise-identical to the uninterrupted one: tenants resume
//! from their own checkpoint rings, and the manifest restores exactly
//! the scheduler/supervisor state those rings cannot carry.

use super::checkpoint::{put_str, put_u32, put_u64, put_u8, Checkpoint, Rd};
use super::scheduler::Slice;
use super::trainer::TrainerOptions;
use crate::formats::ReprType;
use crate::mor::policy::{PolicyRef, StaticAssignmentPolicy};
use crate::util::par::{KernelMode, Parallelism};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// How many demotion rungs exist below a tenant's native configuration:
/// rung 1 = BF16 precision quarantine (+ widened guard), rung 2 =
/// scalar kernels on top. A failure at rung 2 is Dead.
pub const DEMOTION_RUNGS: u8 = 2;

/// Per-tenant health, the supervisor's five-state machine:
///
/// ```text
/// Healthy ──failure──▶ Degraded ──release──▶ (runs again)
///    ▲                    │ next failure
///    │ progress           ▼
///    │                 Backoff ──budget spent──▶ Quarantined (demoted)
///    └──────────────────────────────────────────────│ rungs spent
///                                                   ▼
///                                                  Dead
/// ```
///
/// (`Degraded` is "has failed at this rung, waiting to retry";
/// `Backoff` is the same tenant while its release round is still in the
/// future. `Quarantined` is sticky: a demoted tenant that completes
/// reports Quarantined, not Healthy — the precision demotion is a
/// visible outcome, never silently reabsorbed.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
    Backoff,
    Quarantined,
    Dead,
}

impl Health {
    pub fn name(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Backoff => "backoff",
            Health::Quarantined => "quarantined",
            Health::Dead => "dead",
        }
    }

    fn code(&self) -> u8 {
        match self {
            Health::Healthy => 0,
            Health::Degraded => 1,
            Health::Backoff => 2,
            Health::Quarantined => 3,
            Health::Dead => 4,
        }
    }

    fn from_code(c: u8) -> Result<Health> {
        Ok(match c {
            0 => Health::Healthy,
            1 => Health::Degraded,
            2 => Health::Backoff,
            3 => Health::Quarantined,
            4 => Health::Dead,
            other => bail!("fleet manifest corrupt: unknown health code {other}"),
        })
    }
}

/// Supervisor configuration (`--retries` / `--backoff` /
/// `--stall-after`, env twins `MOR_RETRIES` / `MOR_STALL_AFTER`).
#[derive(Debug, Clone)]
pub struct SupervisorOptions {
    /// Retry budget per tenant *per demotion rung*: after this many
    /// failed retries at one precision rung the tenant is demoted to
    /// the next (and the budget refreshes).
    pub retries: u32,
    /// Base backoff in scheduler rounds: the k-th retry at a rung waits
    /// `backoff * 2^(k-1)` rounds before re-entering the runnable set.
    pub backoff: u64,
    /// Stall watchdog: consecutive no-progress slices tolerated before
    /// the watchdog trips and the failure ladder takes over.
    pub stall_after: u32,
    /// Where to persist the fleet manifest (`None` = in-memory only).
    pub manifest: Option<PathBuf>,
    /// Resume a crashed fleet from the manifest when one exists.
    pub auto_resume: bool,
    /// Stop the scheduler loop before starting this round (testing
    /// hook: a deterministic stand-in for a supervisor crash — the
    /// manifest of every earlier round is already on disk).
    pub halt_after: Option<u64>,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        SupervisorOptions {
            retries: 3,
            backoff: 1,
            stall_after: 3,
            manifest: None,
            auto_resume: false,
            halt_after: None,
        }
    }
}

impl SupervisorOptions {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The supervisor's ledger entry for one tenant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSup {
    pub health: Health,
    /// Failed retries at the current demotion rung.
    pub retries_used: u32,
    /// Failed retries across all rungs (reporting).
    pub retries_total: u32,
    /// First round this tenant may run again (Backoff only).
    pub backoff_until: u64,
    /// Backoff length (in rounds) the *next* failure will impose;
    /// doubles per failure, resets on progress or demotion.
    pub backoff_len: u64,
    /// Consecutive slices without a completed step.
    pub stall_slices: u32,
    /// Demotion rung: 0 native, 1 BF16 quarantine, 2 + scalar kernels.
    pub demotions: u8,
    /// One-shot: the next slice must discard checkpointed guard state
    /// (a demotion just swapped in a widened guard).
    pub refresh_guard: bool,
}

impl TenantSup {
    fn new() -> TenantSup {
        TenantSup {
            health: Health::Healthy,
            retries_used: 0,
            retries_total: 0,
            backoff_until: 0,
            backoff_len: 0,
            stall_slices: 0,
            demotions: 0,
            refresh_guard: false,
        }
    }
}

/// What the failure ladder decided for one failed tenant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureVerdict {
    /// Retry at the same rung after backoff; runnable again at
    /// `release_round`.
    Retry { release_round: u64 },
    /// Budget spent (or guard exhausted): demote to `rung` and retry
    /// with a refreshed budget.
    Demote { rung: u8 },
    /// Every rung is spent; the tenant is dead.
    Dead,
}

/// The fleet supervisor: pure bookkeeping, no I/O except the manifest.
#[derive(Debug)]
pub struct Supervisor {
    pub opts: SupervisorOptions,
    tenants: Vec<TenantSup>,
}

impl Supervisor {
    pub fn new(opts: SupervisorOptions, n_tenants: usize) -> Supervisor {
        Supervisor { opts, tenants: (0..n_tenants).map(|_| TenantSup::new()).collect() }
    }

    pub fn tenant(&self, i: usize) -> &TenantSup {
        &self.tenants[i]
    }

    /// May tenant `i` be scheduled in `round`? Dead tenants never run;
    /// backoff holds a tenant out until its release round.
    pub fn eligible(&self, i: usize, round: u64) -> bool {
        match self.tenants[i].health {
            Health::Dead => false,
            Health::Backoff => round >= self.tenants[i].backoff_until,
            _ => true,
        }
    }

    /// Tenant `i` is being dispatched: a backoff release becomes a
    /// visible Degraded state (running again, not yet trusted).
    pub fn on_release(&mut self, i: usize) {
        if self.tenants[i].health == Health::Backoff {
            self.tenants[i].health = Health::Degraded;
        }
    }

    /// Tenant `i`'s slice completed steps: clear the stall counter,
    /// reset the backoff escalation, and restore trust — Quarantined
    /// stays sticky for a demoted tenant, everything else is Healthy.
    pub fn on_progress(&mut self, i: usize) {
        let t = &mut self.tenants[i];
        t.stall_slices = 0;
        t.backoff_len = 0;
        t.health = if t.demotions > 0 { Health::Quarantined } else { Health::Healthy };
    }

    /// Tenant `i`'s slice completed WITHOUT finishing a step. Returns
    /// the watchdog's failure message once `stall_after` consecutive
    /// no-progress slices accrue; `None` while still under the limit.
    pub fn on_no_progress(&mut self, i: usize, at_step: u64) -> Option<String> {
        let t = &mut self.tenants[i];
        t.stall_slices += 1;
        if t.stall_slices >= self.opts.stall_after {
            Some(format!(
                "stalled: no progress in {} consecutive slices (stuck at step {at_step})",
                t.stall_slices
            ))
        } else {
            None
        }
    }

    /// Walk the failure ladder for tenant `i` failing in `round`.
    /// `guard_exhausted` skips the retry branch: the tenant's own
    /// numeric guard already spent a whole rewind budget at this
    /// precision, so re-running unchanged would only spend another.
    pub fn on_failure(&mut self, i: usize, round: u64, guard_exhausted: bool) -> FailureVerdict {
        let retries = self.opts.retries;
        let base = self.opts.backoff;
        let t = &mut self.tenants[i];
        if !guard_exhausted && t.retries_used < retries {
            t.retries_used += 1;
            t.retries_total += 1;
            t.health = Health::Backoff;
            if t.backoff_len == 0 {
                t.backoff_len = base.max(1);
            }
            // Release after the backoff window: the failing round
            // itself doesn't count as waiting.
            t.backoff_until = round + 1 + t.backoff_len;
            t.backoff_len *= 2;
            return FailureVerdict::Retry { release_round: t.backoff_until };
        }
        if t.demotions < DEMOTION_RUNGS {
            t.demotions += 1;
            t.retries_used = 0;
            t.backoff_len = 0;
            t.stall_slices = 0;
            t.refresh_guard = true;
            t.health = Health::Quarantined;
            return FailureVerdict::Demote { rung: t.demotions };
        }
        t.health = Health::Dead;
        FailureVerdict::Dead
    }

    /// Consume the one-shot "discard checkpointed guard state" marker
    /// set by a demotion (the next slice resumes under the widened
    /// guard, whose saved state belongs to the old configuration).
    pub fn take_refresh_guard(&mut self, i: usize) -> bool {
        std::mem::take(&mut self.tenants[i].refresh_guard)
    }

    pub(crate) fn export(&self) -> Vec<TenantSup> {
        self.tenants.clone()
    }

    pub(crate) fn import(&mut self, tenants: Vec<TenantSup>) {
        assert_eq!(tenants.len(), self.tenants.len(), "manifest tenant count");
        self.tenants = tenants;
    }
}

/// The demoted-precision policy: every tensor class pinned to BF16.
/// Same decision surface as any other `DecisionPolicy`, so the demoted
/// run stays on the standard code path — just with quantization off.
pub fn demotion_policy() -> PolicyRef {
    Arc::new(StaticAssignmentPolicy { table: [ReprType::Bf16; 3] })
}

/// Rewrite one tenant's `TrainerOptions` for a demotion rung. Rung 1
/// forces the BF16 static policy with a widened guard (and `repin`, so
/// the tenant's own ring — pinned to the original policy/guard — still
/// resumes); rung 2 additionally drops the run to scalar kernels,
/// derived from the fleet's parallelism so the pool configuration is
/// preserved. Rungs are cumulative and idempotent.
pub fn apply_demotion(o: &mut TrainerOptions, rung: u8, fleet_par: &Parallelism) {
    if rung >= 1 {
        o.policy = Some(demotion_policy());
        o.guard = o.guard.map(|g| g.widened());
        o.repin = true;
    }
    if rung >= 2 {
        let base = o.parallelism.clone().unwrap_or_else(|| fleet_par.clone());
        o.parallelism = Some(base.with_kernel(KernelMode::Scalar));
    }
}

/// Resolve `MOR_RETRIES` strictly (library-side twin of `--retries`);
/// `fallback` when unset, a loud panic when malformed — the same
/// contract as the other env autos.
pub fn auto_retries(fallback: u32) -> u32 {
    match crate::util::env::parse_pos_int(
        crate::util::env::var("MOR_RETRIES").as_deref(),
        "MOR_RETRIES ",
        "positive retry count",
        "unset it to default to 3",
    ) {
        Ok(v) => v.map(|n| n as u32).unwrap_or(fallback),
        Err(msg) => panic!("{msg}"),
    }
}

/// Resolve `MOR_STALL_AFTER` strictly (twin of `--stall-after`).
pub fn auto_stall_after(fallback: u32) -> u32 {
    match crate::util::env::parse_pos_int(
        crate::util::env::var("MOR_STALL_AFTER").as_deref(),
        "MOR_STALL_AFTER ",
        "positive slice count",
        "unset it to default to 3",
    ) {
        Ok(v) => v.map(|n| n as u32).unwrap_or(fallback),
        Err(msg) => panic!("{msg}"),
    }
}

/// One tenant's row in the fleet manifest: the supervisor ledger plus
/// the scheduler state (progress, stride pass, terminal status) the
/// tenant's own checkpoint ring cannot carry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestTenant {
    pub id: String,
    pub sup: TenantSup,
    /// Completed steps at the last round boundary.
    pub completed: u64,
    /// Slices dispatched so far.
    pub slices: u64,
    /// Stride-scheduler virtual pass (u128, split hi/lo on disk).
    pub pass: u128,
    /// Terminal error text, if the tenant already failed for good.
    pub failed: Option<String>,
    /// Whether the tenant already ran to completion.
    pub done: bool,
}

/// The crash-safe fleet manifest: everything `run_fleet` needs to
/// restart mid-fleet bitwise. Saved atomically (tmp + fsync + rename)
/// with per-section CRC32 trailers via the `MORCKPT2` container, so a
/// torn or corrupt manifest fails loudly at load.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetManifest {
    /// Next round to run (every round below this completed fully).
    pub round: u64,
    /// The fleet's quantum, pinned so a resume with different slicing
    /// fails instead of silently diverging.
    pub quantum: u64,
    pub tenants: Vec<ManifestTenant>,
    /// Schedule log of the completed rounds.
    pub schedule: Vec<Slice>,
}

const SEC_META: &str = "fleet/meta";
const SEC_TENANTS: &str = "fleet/tenants";
const SEC_SCHEDULE: &str = "fleet/schedule";
const MANIFEST_VERSION: u8 = 1;

impl FleetManifest {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut ck = Checkpoint::new(self.round, Vec::new());

        let mut meta = Vec::new();
        put_u8(&mut meta, MANIFEST_VERSION);
        put_u64(&mut meta, self.round);
        put_u64(&mut meta, self.quantum);
        ck.push_section(SEC_META, meta);

        let mut tb = Vec::new();
        put_u32(&mut tb, self.tenants.len() as u32);
        for t in &self.tenants {
            put_str(&mut tb, &t.id);
            put_u8(&mut tb, t.sup.health.code());
            put_u32(&mut tb, t.sup.retries_used);
            put_u32(&mut tb, t.sup.retries_total);
            put_u64(&mut tb, t.sup.backoff_until);
            put_u64(&mut tb, t.sup.backoff_len);
            put_u32(&mut tb, t.sup.stall_slices);
            put_u8(&mut tb, t.sup.demotions);
            put_u8(&mut tb, t.sup.refresh_guard as u8);
            put_u64(&mut tb, t.completed);
            put_u64(&mut tb, t.slices);
            put_u64(&mut tb, (t.pass >> 64) as u64);
            put_u64(&mut tb, t.pass as u64);
            put_u8(&mut tb, t.done as u8);
            match &t.failed {
                Some(e) => {
                    put_u8(&mut tb, 1);
                    // Error text is diagnostic; clip to the container's
                    // name cap rather than asserting on a long message.
                    let clipped: String = e.chars().take(1024).collect();
                    put_str(&mut tb, &clipped);
                }
                None => put_u8(&mut tb, 0),
            }
        }
        ck.push_section(SEC_TENANTS, tb);

        let mut sb = Vec::new();
        put_u32(&mut sb, self.schedule.len() as u32);
        for s in &self.schedule {
            put_u64(&mut sb, s.round);
            put_u32(&mut sb, s.tenant as u32);
            put_u64(&mut sb, s.from_step);
            put_u64(&mut sb, s.to_step);
        }
        ck.push_section(SEC_SCHEDULE, sb);

        ck.save(path)
    }

    pub fn load(path: &Path) -> Result<FleetManifest> {
        let ck = Checkpoint::load(path)?;
        let meta = ck
            .section(SEC_META)
            .with_context(|| format!("fleet manifest {} has no {SEC_META}", path.display()))?;
        let mut rd = Rd::new(meta);
        let version = rd.u8("manifest version")?;
        if version != MANIFEST_VERSION {
            bail!(
                "fleet manifest {} is version {version}, this build reads {MANIFEST_VERSION}",
                path.display()
            );
        }
        let round = rd.u64("manifest round")?;
        let quantum = rd.u64("manifest quantum")?;
        rd.expect_done(SEC_META)?;

        let tb = ck
            .section(SEC_TENANTS)
            .with_context(|| format!("fleet manifest {} has no {SEC_TENANTS}", path.display()))?;
        let mut rd = Rd::new(tb);
        let n = rd.u32("tenant count")? as usize;
        let mut tenants = Vec::with_capacity(n);
        for _ in 0..n {
            let id = rd.str("tenant id")?;
            let sup = TenantSup {
                health: Health::from_code(rd.u8("health")?)?,
                retries_used: rd.u32("retries_used")?,
                retries_total: rd.u32("retries_total")?,
                backoff_until: rd.u64("backoff_until")?,
                backoff_len: rd.u64("backoff_len")?,
                stall_slices: rd.u32("stall_slices")?,
                demotions: rd.u8("demotions")?,
                refresh_guard: rd.u8("refresh_guard")? != 0,
            };
            let completed = rd.u64("completed")?;
            let slices = rd.u64("slices")?;
            let pass = ((rd.u64("pass hi")? as u128) << 64) | rd.u64("pass lo")? as u128;
            let done = rd.u8("done")? != 0;
            let failed = match rd.u8("failed flag")? {
                0 => None,
                _ => Some(rd.str("failure text")?),
            };
            tenants.push(ManifestTenant { id, sup, completed, slices, pass, failed, done });
        }
        rd.expect_done(SEC_TENANTS)?;

        let sb = ck
            .section(SEC_SCHEDULE)
            .with_context(|| format!("fleet manifest {} has no {SEC_SCHEDULE}", path.display()))?;
        let mut rd = Rd::new(sb);
        let n = rd.u32("schedule length")? as usize;
        let mut schedule = Vec::with_capacity(n);
        for _ in 0..n {
            schedule.push(Slice {
                round: rd.u64("slice round")?,
                tenant: rd.u32("slice tenant")? as usize,
                from_step: rd.u64("slice from")?,
                to_step: rd.u64("slice to")?,
            });
        }
        rd.expect_done(SEC_SCHEDULE)?;

        Ok(FleetManifest { round, quantum, tenants, schedule })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sup(retries: u32, backoff: u64) -> Supervisor {
        let opts = SupervisorOptions { retries, backoff, ..SupervisorOptions::new() };
        Supervisor::new(opts, 2)
    }

    #[test]
    fn failure_ladder_retries_then_demotes_then_dies() {
        let mut s = sup(2, 1);
        // Two retries at rung 0 with doubling backoff.
        assert_eq!(s.on_failure(0, 0, false), FailureVerdict::Retry { release_round: 2 });
        assert_eq!(s.tenant(0).health, Health::Backoff);
        assert!(!s.eligible(0, 1), "still backing off");
        assert!(s.eligible(0, 2), "released");
        assert_eq!(s.on_failure(0, 2, false), FailureVerdict::Retry { release_round: 5 });
        // Budget spent: demote to rung 1, budget refreshes.
        assert_eq!(s.on_failure(0, 5, false), FailureVerdict::Demote { rung: 1 });
        assert_eq!(s.tenant(0).health, Health::Quarantined);
        assert!(s.take_refresh_guard(0), "demotion schedules a guard refresh");
        assert!(!s.take_refresh_guard(0), "one-shot");
        // Fresh budget at rung 1; backoff escalation restarted.
        assert_eq!(s.on_failure(0, 6, false), FailureVerdict::Retry { release_round: 8 });
        assert_eq!(s.on_failure(0, 8, false), FailureVerdict::Retry { release_round: 11 });
        assert_eq!(s.on_failure(0, 11, false), FailureVerdict::Demote { rung: 2 });
        // Rung 2 budget, then Dead.
        assert_eq!(s.on_failure(0, 12, false), FailureVerdict::Retry { release_round: 14 });
        assert_eq!(s.on_failure(0, 14, false), FailureVerdict::Retry { release_round: 17 });
        assert_eq!(s.on_failure(0, 17, false), FailureVerdict::Dead);
        assert_eq!(s.tenant(0).health, Health::Dead);
        assert!(!s.eligible(0, 99));
        // The neighbor's ledger never moved.
        assert_eq!(s.tenant(1).health, Health::Healthy);
    }

    #[test]
    fn guard_exhaustion_skips_the_retry_branch() {
        let mut s = sup(3, 1);
        assert_eq!(s.on_failure(0, 4, true), FailureVerdict::Demote { rung: 1 });
        assert_eq!(s.tenant(0).retries_total, 0, "no retries were burned");
        assert_eq!(s.tenant(0).demotions, 1);
    }

    #[test]
    fn progress_resets_trust_but_quarantine_sticks() {
        let mut s = sup(1, 1);
        assert!(matches!(s.on_failure(0, 0, false), FailureVerdict::Retry { .. }));
        s.on_release(0);
        assert_eq!(s.tenant(0).health, Health::Degraded);
        s.on_progress(0);
        assert_eq!(s.tenant(0).health, Health::Healthy);
        assert_eq!(s.tenant(0).backoff_len, 0, "escalation reset");
        // After a demotion, progress restores Quarantined, not Healthy.
        assert!(matches!(s.on_failure(0, 1, true), FailureVerdict::Demote { .. }));
        s.on_progress(0);
        assert_eq!(s.tenant(0).health, Health::Quarantined);
    }

    #[test]
    fn stall_watchdog_counts_consecutive_no_progress_slices() {
        let opts = SupervisorOptions { stall_after: 2, ..SupervisorOptions::new() };
        let mut s = Supervisor::new(opts, 1);
        assert!(s.on_no_progress(0, 7).is_none(), "first stall tolerated");
        s.on_progress(0);
        assert!(s.on_no_progress(0, 7).is_none(), "progress reset the count");
        let msg = s.on_no_progress(0, 7).expect("second consecutive stall trips");
        assert!(msg.contains("stalled"), "{msg}");
        assert!(msg.contains("stuck at step 7"), "{msg}");
    }

    #[test]
    fn manifest_round_trips_and_rejects_corruption() {
        let dir =
            std::env::temp_dir().join(format!("mor_sup_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.manifest");
        let manifest = FleetManifest {
            round: 3,
            quantum: 4,
            tenants: vec![
                ManifestTenant {
                    id: "a".into(),
                    sup: TenantSup {
                        health: Health::Backoff,
                        retries_used: 1,
                        retries_total: 2,
                        backoff_until: 5,
                        backoff_len: 4,
                        stall_slices: 1,
                        demotions: 1,
                        refresh_guard: true,
                    },
                    completed: 6,
                    slices: 2,
                    pass: (7u128 << 64) | 9,
                    failed: None,
                    done: false,
                },
                ManifestTenant {
                    id: "b".into(),
                    sup: TenantSup { health: Health::Dead, ..TenantSup::new() },
                    completed: 2,
                    slices: 3,
                    pass: 11,
                    failed: Some("step panicked: injected".into()),
                    done: false,
                },
            ],
            schedule: vec![Slice { round: 0, tenant: 1, from_step: 0, to_step: 2 }],
        };
        manifest.save(&path).unwrap();
        assert_eq!(FleetManifest::load(&path).unwrap(), manifest);

        // Any flipped byte in the container fails the CRC loudly.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(FleetManifest::load(&path).is_err(), "corrupt manifest must not load");

        // A torn (truncated) file fails too.
        manifest.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        assert!(FleetManifest::load(&path).is_err(), "torn manifest must not load");
    }

    #[test]
    fn env_autos_resolve_strictly() {
        std::env::remove_var("MOR_RETRIES");
        std::env::remove_var("MOR_STALL_AFTER");
        assert_eq!(auto_retries(7), 7);
        assert_eq!(auto_stall_after(5), 5);
    }

    #[test]
    fn demotion_rewrites_policy_guard_and_kernels_cumulatively() {
        use super::super::guard::GuardConfig;
        let fleet_par = Parallelism::serial();
        let mut o = TrainerOptions::new("art", 8, std::path::PathBuf::from("/tmp/x"));
        o.guard = Some(GuardConfig::default());
        apply_demotion(&mut o, 1, &fleet_par);
        assert!(o.repin);
        assert_eq!(o.policy.as_ref().unwrap().pin(), demotion_policy().pin());
        assert_eq!(
            o.guard.unwrap().max_rewinds,
            GuardConfig::default().max_rewinds * 2 + 2
        );
        assert!(o.parallelism.is_none(), "rung 1 leaves kernels alone");
        apply_demotion(&mut o, 2, &fleet_par);
        assert!(o.parallelism.is_some(), "rung 2 pins scalar kernels");
    }
}
