//! Layer-3 coordinator: the training/eval loops that drive the AOT
//! artifacts, metrics logging, and checkpointing. The paper's
//! contribution lives at L1/L2 (a numeric-format recipe), so this layer
//! is the *launcher*: process lifecycle, LR schedule, data pipeline,
//! stats collection, experiment orchestration.

pub mod checkpoint;
pub mod eval;
pub mod guard;
pub mod logging;
pub mod scheduler;
pub mod supervisor;
pub mod trainer;

pub use logging::{MetricsLogger, StepRecord};
pub use scheduler::{FleetOptions, FleetOutcome, Tenant, TenantReport};
pub use supervisor::{FleetManifest, Health, Supervisor, SupervisorOptions};
pub use trainer::{TrainOutcome, Trainer, TrainerOptions};
