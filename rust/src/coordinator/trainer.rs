//! The training loop driver: schedule → data → compiled step → metrics,
//! with deterministic checkpoint/resume.
//!
//! One `Trainer::run` produces everything a paper figure needs from one
//! run: the loss/param-norm series (Figs. 5/6/8/20), the eval-suite
//! trajectory (Figs. 7/9/21), and the per-tensor decision statistics
//! (Figs. 10–19) via [`StatsCollector`].
//!
//! ## The resume ≡ continuous contract
//!
//! With `ckpt_every > 0` the trainer writes a full `MORCKPT2`
//! [`TrainCheckpoint`] (params, Adam moments, data-loader cursors, RNG
//! stream states, delayed-scaling amax histories, stats collector, a
//! metrics row-count+content-hash digest — or the embedded rows under
//! `embed_metrics` — and the suite trajectory) after every k-th
//! completed step.
//! Restarting with `resume: Some(path)` and the **same total `steps`,
//! config and artifact** reproduces the uninterrupted run **bitwise**:
//! identical parameters, identical `metrics.csv` rows (minus the
//! wall-clock `step_ms` column, which is timing, not state), identical
//! MoR decision fractions and heatmaps — at every `MOR_THREADS`
//! setting, because the parallel engine's merge order is already
//! deterministic. Two design points make any resumable checkpoint an
//! exact prefix of the continuous run:
//!
//! * checkpoints are written *after* a step's record is logged, so a
//!   checkpoint at step `k` is exactly the continuous run's state
//!   after `k` completed steps;
//! * the numerics-affecting options — total `steps` (the LR
//!   schedule), `threshold`, `val_every`, `suite_every`,
//!   `per_channel` — are pinned inside the checkpoint and validated
//!   on resume, so the forced final-step validation/suite pass (which
//!   consumes an extra validation batch) can only ever fire on the
//!   run's true last step — a step no resumable checkpoint precedes.

use super::checkpoint::{scan_ring, section, sweep_stale_tmp, MetricsState, TrainCheckpoint};
use super::eval::{eval_suite, EvalScores};
use super::guard::{GuardConfig, GuardEvent, GuardVerdict, NumericGuard, REWIND_EXHAUSTED_MSG};
use super::logging::{csv_lines_digest, MetricsLogger, StepRecord};
use crate::data::loader::BatchLoader;
use crate::data::synthetic::CorpusProfile;
use crate::data::tasks::EvalSuite;
use crate::faults::{FaultPlan, FaultSpec};
use crate::model::config::{ModelConfig, TrainConfig};
use crate::model::naming::{param_specs, QuantTensorId};
use crate::mor::policy::{PolicyRef, QuarantinePolicy};
use crate::mor::stats::StatsCollector;
use crate::runtime::{Runtime, SessionCtx, TrainSession};
use crate::util::par::Parallelism;
use anyhow::{bail, Context, Result};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    /// Manifest name of the train artifact (selects the recipe).
    pub artifact: String,
    pub steps: u64,
    /// E4M3 acceptance threshold fed to the compiled step (4.5% paper
    /// default; 5.0% ablation).
    pub threshold: f32,
    /// Validate every N steps (0 = never).
    pub val_every: u64,
    /// Run the eval-task suite every N steps (0 = never).
    pub suite_every: u64,
    /// Checkpoint every N completed steps (0 = never; the final step
    /// always checkpoints when enabled).
    pub ckpt_every: u64,
    /// Histogram reset window (Fig. 14); paper uses 6000 of its steps.
    pub stats_window: u64,
    /// Output directory for metrics.csv / stats CSV / checkpoints.
    pub out_dir: PathBuf,
    /// Whether the artifact's partition is per-channel (direction-
    /// resolved stats keys).
    pub per_channel: bool,
    /// Run quietly (no per-step stdout).
    pub quiet: bool,
    /// Resume from a `MORCKPT2` training checkpoint. The run continues
    /// at the checkpoint's completed-step count. The artifact, train
    /// config, and every pinned numerics-affecting option (total
    /// `steps`, `threshold`, `val_every`, `suite_every`,
    /// `per_channel`, the decision `policy`) must match the original
    /// run — all are validated, so a mismatch errors instead of
    /// silently breaking the bitwise resume ≡ continuous contract.
    pub resume: Option<PathBuf>,
    /// Embed the full metrics history in checkpoints (the legacy
    /// `metrics/records` representation) instead of the default O(1)
    /// row-count + content-hash digest. The digest keeps checkpoint
    /// size flat over long runs — the old embedded mode cost
    /// O(steps²/ckpt_every) bytes across a run — with the prefix
    /// replayed from the original run's on-disk metrics.csv at resume
    /// time, verified against the hash before anything is trusted.
    /// Both representations load either way.
    pub embed_metrics: bool,
    /// Per-run engine handle for the quantization/GEMM hot paths
    /// (`None` inherits the runtime's default; see `util::par`). The
    /// handle is owned by this run's sessions, so no run ever mutates
    /// a process-global setting. Runs inheriting one runtime's default
    /// share that runtime's pool (safely — results are bit-identical
    /// for any thread count); give each run a `Some(...)` override for
    /// pool isolation.
    pub parallelism: Option<Parallelism>,
    /// Per-run decision policy for the MoR quantization paths (`None`
    /// inherits the runtime's default; see `mor::policy`). Pinned into
    /// checkpoints by its [`crate::mor::policy::DecisionPolicy::pin`]
    /// fingerprint, so resuming under a different policy errors instead
    /// of silently diverging.
    pub policy: Option<PolicyRef>,
    /// Deterministic fault-injection schedule (`--faults` /
    /// `MOR_FAULTS`; see [`crate::faults`]). Host backend only;
    /// deliberately NOT pinned into checkpoints — a rewind replay or a
    /// clean restart continues without re-firing consumed one-shot
    /// faults, which is exactly what makes recovery testable.
    pub faults: Option<FaultSpec>,
    /// Numeric guard configuration (`--guard` / `MOR_GUARD`; see
    /// [`super::guard`]). `None` trains unguarded, bit-for-bit the
    /// historical behavior.
    pub guard: Option<GuardConfig>,
    /// Checkpoint-ring retention: keep the newest K checkpoints,
    /// pruning older ones after each save (0 keeps everything).
    pub ckpt_keep: u64,
    /// Resume from the newest loadable checkpoint in `out_dir`,
    /// walking the ring past corrupt/torn files; fresh start when the
    /// ring is empty. Mutually exclusive with `resume`.
    pub auto_resume: bool,
    /// Cooperative preemption: suspend the run after this many
    /// completed steps (when `< steps`), writing a checkpoint at the
    /// suspension point even when the cadence would not — so a later
    /// `auto_resume` continues bitwise where the slice stopped. This is
    /// how `coordinator::scheduler` time-slices tenants; like `faults`
    /// it is scheduling, not numerics, so it is deliberately NOT pinned
    /// into checkpoints. `None` (or `>= steps`) runs to completion.
    pub stop_after: Option<u64>,
    /// Cooperative stop flag, polled at every step boundary (and while
    /// a `stall` fault spins): when another thread sets it, the run
    /// suspends at the next completed step exactly like `stop_after` —
    /// suspension checkpoint included — enabling mid-quantum preemption
    /// without wall-clock timers. Like `stop_after` it is scheduling,
    /// not numerics, and is NOT pinned into checkpoints.
    pub stop_flag: Option<Arc<AtomicBool>>,
    /// Accept checkpoint pin mismatches for `opt/policy` and
    /// `opt/guard` only (printing what changed instead of bailing).
    /// This is the fleet supervisor's demotion escape hatch: a demoted
    /// tenant resumes its own ring under a forced BF16 policy and a
    /// widened guard, deliberately diverging from the pinned originals.
    /// All other pins (steps, threshold, cadences) still bail.
    pub repin: bool,
    /// Skip importing checkpointed guard state on resume, starting the
    /// guard clean (strikes, quarantines and rewind budget all zero).
    /// Used with `repin` when the supervisor swaps in a widened guard
    /// whose saved state belongs to the old configuration.
    pub fresh_guard: bool,
}

impl TrainerOptions {
    pub fn new(artifact: &str, steps: u64, out_dir: PathBuf) -> Self {
        TrainerOptions {
            artifact: artifact.to_string(),
            steps,
            threshold: 0.045,
            val_every: 20,
            suite_every: 0,
            ckpt_every: 0,
            stats_window: 50,
            out_dir,
            per_channel: false,
            quiet: false,
            resume: None,
            embed_metrics: false,
            parallelism: None,
            policy: None,
            faults: None,
            guard: None,
            ckpt_keep: 0,
            auto_resume: false,
            stop_after: None,
            stop_flag: None,
            repin: false,
            fresh_guard: false,
        }
    }
}

/// Summary of a finished run.
#[derive(Debug)]
pub struct TrainOutcome {
    pub final_train_loss: f32,
    pub final_val_loss: f32,
    pub records: Vec<StepRecord>,
    pub stats: StatsCollector,
    /// (step, scores) trajectory of the eval-task suite.
    pub suite_history: Vec<(u64, EvalScores)>,
    pub metrics_path: PathBuf,
    pub mean_step_ms: f32,
    /// Every intervention the numeric guard performed (empty when the
    /// guard was off); also written to `{artifact}.{config}.guard.csv`.
    pub guard_events: Vec<GuardEvent>,
}

/// The training coordinator.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    pub model: ModelConfig,
    pub train_config: TrainConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, train_config: TrainConfig) -> Self {
        Trainer { runtime, model: runtime.model, train_config }
    }

    pub fn run(&self, opts: &TrainerOptions) -> Result<TrainOutcome> {
        // One Parallelism handle and one DecisionPolicy per run, owned
        // by the run's sessions: the per-run overrides (or the runtime
        // defaults) ride the session API instead of a scoped
        // process-global override.
        let par = opts
            .parallelism
            .clone()
            .unwrap_or_else(|| self.runtime.parallelism().clone());
        let base_policy =
            opts.policy.clone().unwrap_or_else(|| self.runtime.policy().clone());
        // A guarded run interposes the quarantine wrapper between the
        // session and the base policy (transparent while no tensor is
        // quarantined, so fault-free guarded == unguarded bitwise); an
        // unguarded run keeps the base policy untouched.
        let (policy, mut guard) = match opts.guard {
            Some(cfg) => {
                let qp = QuarantinePolicy::new(base_policy.clone());
                let g = NumericGuard::new(cfg, qp, self.model.n_layers);
                (g.policy(), Some(g))
            }
            None => (base_policy, None),
        };
        let tc = &self.train_config;
        let faults: Option<Arc<FaultPlan>> = opts
            .faults
            .as_ref()
            .map(|spec| Arc::new(FaultPlan::new(spec.clone(), tc.seed)));
        let ctx = SessionCtx { parallelism: par.clone(), policy: policy.clone() };
        let mut session = self
            .runtime
            .train_session_ctx(&opts.artifact, tc.seed, ctx)
            .with_context(|| format!("starting session for {}", opts.artifact))?;
        session.set_faults(faults.clone())?;
        if guard.is_some() {
            session.set_guard_skip(true);
        }
        let profile = CorpusProfile::from_id(tc.data_profile);

        // Resolve what to resume from: an explicit checkpoint path, or
        // (auto-resume) the newest loadable ring entry — walking past
        // corrupt/torn files — or nothing.
        if opts.resume.is_some() && opts.auto_resume {
            bail!("resume and auto_resume are mutually exclusive");
        }
        let resume_path: Option<PathBuf> = match &opts.resume {
            Some(p) => Some(p.clone()),
            None if opts.auto_resume => self.find_auto_resume(opts),
            None => None,
        };
        // Restore the full training state when resuming: session
        // (params + moments + step + amax histories), loader cursors,
        // stats, metrics rows, suite trajectory.
        let resumed = match &resume_path {
            Some(path) => Some(self.restore(path, &mut session, opts, &policy)?),
            None => None,
        };
        if !opts.fresh_guard {
            if let (Some(g), Some(ck)) = (&mut guard, &resumed) {
                if let Some(bytes) = &ck.guard_state {
                    g.import_state(bytes, false)
                        .context("restoring checkpointed guard state")?;
                }
            }
        }
        // Resolve the resumed metrics prefix (bit-exact records + the
        // raw CSV lines to replay) BEFORE the logger is created: a
        // digest checkpoint replays from the original run's on-disk
        // metrics file, and resuming into the same out_dir would
        // otherwise read the file the logger just truncated.
        let resumed_metrics: Option<(Vec<StepRecord>, Vec<String>)> =
            match (&resumed, &resume_path) {
                (Some(ck), Some(path)) => {
                    Some(restore_metrics(ck, path, &opts.artifact, self.train_config.name)?)
                }
                _ => None,
            };
        let (mut train_loader, mut val_loader) = match &resumed {
            Some(ck) => (
                BatchLoader::resume(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    0,
                    &ck.train_cursor,
                ),
                BatchLoader::resume(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    1,
                    &ck.val_cursor,
                ),
            ),
            None => (
                BatchLoader::new(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    0,
                ),
                BatchLoader::new(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    1,
                ),
            ),
        };
        let eval = self.runtime.eval_session_with("eval", par).ok();
        let suite = EvalSuite::new(session.seq, self.model.vocab_size, 8, tc.seed ^ 0xE7A1);

        std::fs::create_dir_all(&opts.out_dir)?;
        let metrics_path = opts.out_dir.join(format!("{}.{}.csv", opts.artifact, tc.name));
        let mut logger = MetricsLogger::create(&metrics_path)?;
        let (start_step, mut stats, mut suite_history, mut records, mut last_val, mut ckpts) =
            match resumed {
                Some(ck) => {
                    // Replay the restored rows verbatim so the resumed
                    // metrics.csv is the continuous file's prefix
                    // byte-for-byte (digest checkpoints verified the
                    // lines against the content hash above; embedded
                    // checkpoints re-format from the exact bits, which
                    // produces the identical text).
                    let (records, lines) =
                        resumed_metrics.expect("resumed run resolved its metrics prefix");
                    for line in &lines {
                        logger.log_raw(line)?;
                    }
                    let ckpts = ck.counter("ckpts_written").unwrap_or(0);
                    (ck.step, ck.stats, ck.suite_history, records, ck.last_val, ckpts)
                }
                None => (
                    0,
                    StatsCollector::new(opts.stats_window),
                    Vec::new(),
                    Vec::new(),
                    f32::NAN,
                    0,
                ),
            };
        let mut total_ms = records.iter().map(|r| r.step_ms).sum::<f32>();
        let n_slots = QuantTensorId::count(&self.model);

        // Preemption horizon: a slice stops early at `stop_after`
        // completed steps; everything downstream of the loop condition
        // (val/suite "final step" rules, LR schedule, pins) still keys
        // off the true `opts.steps`, so a slice is an exact prefix of
        // the continuous run.
        let suspend_at = opts.stop_after.filter(|s| *s < opts.steps);
        let horizon = suspend_at.unwrap_or(opts.steps);

        let mut step = start_step;
        while step < horizon {
            // Injected stall (`stall:step@step=N`): the deterministic
            // stand-in for a wedged tenant. The "hung" step polls the
            // cooperative stop flag for a bounded budget, then
            // self-preempts — checkpointing whatever this slice already
            // completed and ending the slice early, so the scheduler
            // observes a tenant that stopped making progress (which is
            // what the supervisor's stall watchdog counts).
            if faults.as_deref().is_some_and(|p| p.stall_due(step + 1)) {
                poll_stop(opts.stop_flag.as_deref());
                if !opts.quiet {
                    println!("[{}] stalled before step {step}; suspending", opts.artifact);
                }
                if step > start_step {
                    ckpts += 1;
                    self.save_checkpoint(
                        &session,
                        &train_loader,
                        &val_loader,
                        &stats,
                        &records,
                        &suite_history,
                        last_val,
                        ckpts,
                        opts,
                        &policy,
                        faults.as_deref(),
                        guard.as_ref(),
                    )?;
                }
                break;
            }
            let mut stop_now = false;
            let lr = tc.schedule.lr_at(step);
            let batch = train_loader.next_batch();
            let t0 = Instant::now();
            // Tenancy hygiene: make sure no stale injected-panic flag
            // from an earlier aborted run on this thread fires inside
            // this step (see `faults::clear_worker_panic`).
            crate::faults::clear_worker_panic();
            // The step runs under catch_unwind so an injected (or real)
            // worker panic is recoverable: nothing has committed when a
            // step unwinds — params, moments and the session's step
            // counter only mutate on success — so a guarded run can
            // rewind, and an unguarded run re-raises unchanged.
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                session.step(&batch.tokens, lr, opts.threshold)
            }));
            let step_ms = t0.elapsed().as_secs_f32() * 1e3;
            let rewind_reason: Option<String> = match stepped {
                // No guard: an unguarded run re-raises unchanged.
                Err(payload) => match &guard {
                    None => resume_unwind(payload),
                    Some(_) => {
                        Some(format!("step panicked: {}", panic_text(payload.as_ref())))
                    }
                },
                Ok(Err(e)) => return Err(e),
                Ok(Ok(out)) => {
                    total_ms += step_ms;

                    // Record per-slot decisions into the heatmap stats.
                    stats.set_step(step);
                    debug_assert_eq!(out.relerr.len(), n_slots);
                    let mut fb_sum = 0f32;
                    let mut re_sum = 0f32;
                    for (i, (re, fb)) in
                        out.relerr.iter().zip(out.fallback.iter()).enumerate()
                    {
                        let id = QuantTensorId::from_flat(i);
                        // Direction-1 slots only carry signal for
                        // per-channel partitions; other partitions
                        // mirror direction 0 and we skip them to avoid
                        // double counting.
                        if id.direction == 1 && !opts.per_channel {
                            continue;
                        }
                        stats.record(
                            id.key(opts.per_channel),
                            *re as f64,
                            *fb >= 0.5,
                            *fb as f64,
                        );
                        fb_sum += fb;
                        re_sum += re;
                    }
                    let denom =
                        if opts.per_channel { n_slots } else { n_slots / 2 } as f32;

                    // Validation loss on a held-out stream. The forced
                    // final-step pass only fires on the run's true last
                    // step: `steps` is pinned in every checkpoint, so
                    // no resumable checkpoint can sit after a forced
                    // pass — mid-run checkpoints stay exact prefixes of
                    // the continuous run.
                    let is_val_step = opts.val_every > 0
                        && (step % opts.val_every == 0 || step + 1 == opts.steps);
                    if is_val_step {
                        if let Some(ev) = &eval {
                            let vb = val_loader.next_batch();
                            let mask = full_mask(session.batch, session.seq);
                            // Tensor-native interchange: on the host
                            // backend the eval borrows the trainer's
                            // params directly — no Tensor→Literal→
                            // Tensor round-trip per validation.
                            let (vl, _) =
                                ev.eval_params(session.params_ref(), &vb.tokens, &mask)?;
                            last_val = vl;
                        }
                    }

                    // Eval-task suite (the downstream-benchmark
                    // substitute); same final-step rule as validation.
                    if opts.suite_every > 0
                        && (step % opts.suite_every == 0 || step + 1 == opts.steps)
                    {
                        if let Some(ev) = &eval {
                            let scores = eval_suite(ev, session.params_ref(), &suite)?;
                            suite_history.push((step, scores));
                        }
                    }

                    let rec = StepRecord {
                        step,
                        lr,
                        train_loss: out.loss,
                        val_loss: if is_val_step { last_val } else { f32::NAN },
                        param_norm: session.param_norm()?,
                        bf16_fallback_rate: fb_sum / denom,
                        mean_relerr: re_sum / denom,
                        step_ms,
                    };
                    logger.log(&rec)?;
                    if !opts.quiet && (step % 10 == 0 || step + 1 == opts.steps) {
                        println!(
                            "[{}] step {step:>5} loss {:.4} val {:.4} lr {:.2e} fb {:.2}% \
                             relerr {:.3}% ({:.0} ms)",
                            opts.artifact,
                            rec.train_loss,
                            rec.val_loss,
                            rec.lr,
                            rec.bf16_fallback_rate * 100.0,
                            rec.mean_relerr * 100.0,
                            step_ms
                        );
                    }
                    let param_norm = rec.param_norm;
                    records.push(rec);

                    // Judge the completed step AFTER its record is
                    // logged (a rewind truncates the anomalous suffix)
                    // and BEFORE any checkpoint: a state the guard
                    // condemns must never enter the ring.
                    let verdict = match &mut guard {
                        Some(g) => g.assess(step, &out, param_norm),
                        None => GuardVerdict::Healthy,
                    };
                    match verdict {
                        GuardVerdict::Rewind { reason } => Some(reason),
                        GuardVerdict::Healthy | GuardVerdict::Intervened => {
                            // Checkpoint after the record is logged:
                            // the file captures exactly `completed`
                            // finished steps of the continuous run.
                            let completed = step + 1;
                            let on_cadence = completed % opts.ckpt_every.max(1) == 0
                                || completed == opts.steps;
                            // A suspension point always checkpoints —
                            // even off-cadence, even with the cadence
                            // disabled — or the slice's work would be
                            // lost at eviction. The cooperative stop
                            // flag suspends the same way, just at a
                            // step boundary the setter didn't pick in
                            // advance.
                            let flag_stop = opts
                                .stop_flag
                                .as_ref()
                                .is_some_and(|f| f.load(Ordering::Relaxed));
                            let suspending =
                                Some(completed) == suspend_at || flag_stop;
                            stop_now = flag_stop;
                            if (opts.ckpt_every > 0 && on_cadence) || suspending {
                                ckpts += 1;
                                self.save_checkpoint(
                                    &session,
                                    &train_loader,
                                    &val_loader,
                                    &stats,
                                    &records,
                                    &suite_history,
                                    last_val,
                                    ckpts,
                                    opts,
                                    &policy,
                                    faults.as_deref(),
                                    guard.as_ref(),
                                )?;
                                // Ring retention: keep the newest K
                                // checkpoints, prune the rest.
                                if opts.ckpt_keep > 0 {
                                    for (_, old) in
                                        scan_ring(&opts.out_dir, &opts.artifact)
                                            .into_iter()
                                            .skip(opts.ckpt_keep as usize)
                                    {
                                        let _ = std::fs::remove_file(old);
                                    }
                                }
                            }
                            None
                        }
                    }
                }
            };

            if let Some(reason) = rewind_reason {
                let g = guard.as_mut().expect("rewind verdicts only come from the guard");
                if g.rewinds() >= g.config().max_rewinds {
                    bail!(
                        "{REWIND_EXHAUSTED_MSG} ({}) at step {step}: {reason}",
                        g.config().max_rewinds
                    );
                }
                // Newest loadable checkpoint at or before the failed
                // step; corrupt/torn ring entries are walked past.
                let mut target: Option<PathBuf> = None;
                for (ck_step, path) in scan_ring(&opts.out_dir, &opts.artifact) {
                    if ck_step > step {
                        continue;
                    }
                    match TrainCheckpoint::load(&path) {
                        Ok(_) => {
                            target = Some(path);
                            break;
                        }
                        Err(e) => {
                            if !opts.quiet {
                                println!(
                                    "[guard] skipping corrupt checkpoint {}: {e:#}",
                                    path.display()
                                );
                            }
                        }
                    }
                }
                let Some(path) = target else {
                    bail!(
                        "numeric guard must rewind ({reason}) but no loadable checkpoint \
                         exists in {} — enable --ckpt-every to make recovery possible",
                        opts.out_dir.display()
                    );
                };
                if !opts.quiet {
                    println!("[guard] rewinding to {}: {reason}", path.display());
                }
                let ck = self.restore(&path, &mut session, opts, &policy)?;
                train_loader = BatchLoader::resume(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    0,
                    &ck.train_cursor,
                );
                val_loader = BatchLoader::resume(
                    profile,
                    self.model.vocab_size,
                    session.batch,
                    session.seq,
                    tc.seed,
                    1,
                    &ck.val_cursor,
                );
                // Roll the coordinator state back and rebuild
                // metrics.csv as the checkpoint's exact prefix (the
                // in-memory records ARE the continuous file's rows;
                // csv_line is shortest-round-trip stable).
                records.truncate(ck.metrics.rows() as usize);
                ckpts = ck.counter("ckpts_written").unwrap_or(0);
                drop(logger);
                logger = MetricsLogger::create(&metrics_path)?;
                for r in &records {
                    logger.log_raw(&r.csv_line())?;
                }
                // Guard state rolls back too (quarantines, strikes,
                // loss window) — except the rewind budget, which must
                // survive the restore or retries become unbounded. The
                // rewind itself is recorded after the rollback so its
                // event outlives it.
                if let Some(bytes) = &ck.guard_state {
                    g.import_state(bytes, true)
                        .context("restoring guard state during rewind")?;
                }
                let granted = g.begin_rewind(step, &reason);
                assert!(granted, "budget was checked before the restore");
                last_val = ck.last_val;
                stats = ck.stats;
                suite_history = ck.suite_history;
                total_ms = records.iter().map(|r| r.step_ms).sum();
                step = ck.step;
                continue;
            }
            step += 1;
            if stop_now {
                break;
            }
        }
        logger.flush()?;

        // Persist the stats heatmap CSV next to the metrics.
        let stats_path = opts.out_dir.join(format!("{}.{}.stats.csv", opts.artifact, tc.name));
        std::fs::write(&stats_path, stats.heatmap_csv())?;

        // Guard telemetry: the intervention log rides the outcome and
        // lands next to the metrics as guard.csv.
        let guard_events = match &guard {
            Some(g) => {
                let gpath =
                    opts.out_dir.join(format!("{}.{}.guard.csv", opts.artifact, tc.name));
                let mut text = String::from("step,action,detail\n");
                for e in g.events() {
                    text.push_str(&format!(
                        "{},{},\"{}\"\n",
                        e.step,
                        e.action.name(),
                        e.detail.replace('"', "'")
                    ));
                }
                std::fs::write(&gpath, text)?;
                g.events().to_vec()
            }
            None => Vec::new(),
        };

        let final_train_loss = records.last().map(|r| r.train_loss).unwrap_or(f32::NAN);
        Ok(TrainOutcome {
            final_train_loss,
            final_val_loss: last_val,
            mean_step_ms: total_ms / records.len().max(1) as f32,
            records,
            stats,
            suite_history,
            metrics_path,
            guard_events,
        })
    }

    /// Auto-resume target discovery: sweep stale save temp files, then
    /// walk the checkpoint ring newest → oldest and pick the first
    /// entry that loads cleanly, noting each corrupt/torn file skipped.
    fn find_auto_resume(&self, opts: &TrainerOptions) -> Option<PathBuf> {
        let swept = sweep_stale_tmp(&opts.out_dir);
        if swept > 0 && !opts.quiet {
            println!("[auto-resume] swept {swept} stale checkpoint temp file(s)");
        }
        for (ck_step, path) in scan_ring(&opts.out_dir, &opts.artifact) {
            match TrainCheckpoint::load(&path) {
                Ok(_) => return Some(path),
                Err(e) => {
                    if !opts.quiet {
                        println!(
                            "[auto-resume] skipping corrupt checkpoint {} (step {ck_step}): \
                             {e:#}",
                            path.display()
                        );
                    }
                }
            }
        }
        None
    }

    /// Load and validate a resume checkpoint, importing the session
    /// state. Returns the decoded checkpoint for the loader/stats
    /// restore in `run`.
    fn restore(
        &self,
        path: &std::path::Path,
        session: &mut TrainSession,
        opts: &TrainerOptions,
        policy: &PolicyRef,
    ) -> Result<TrainCheckpoint> {
        let ck = TrainCheckpoint::load(path)?;
        if ck.artifact != opts.artifact {
            bail!(
                "checkpoint {} was trained with artifact {:?}, this run uses {:?}",
                path.display(),
                ck.artifact,
                opts.artifact
            );
        }
        if ck.config != self.train_config.name {
            bail!(
                "checkpoint {} was trained with config {:?}, this run uses {:?}",
                path.display(),
                ck.config,
                self.train_config.name
            );
        }
        // Auto-resuming a run that already finished is a pure replay:
        // zero steps execute, and the outcome (records, stats, suite
        // history, final losses) is reconstructed from the checkpoint
        // byte-identically. The fleet scheduler leans on this to
        // materialize reports for tenants that completed before a
        // supervisor crash. An *explicit* `resume` of a finished run —
        // or any overshoot — still errors: that is the classic
        // pass-the-remaining-steps mistake.
        let finished_replay = opts.auto_resume && ck.step == opts.steps;
        if ck.step >= opts.steps && !finished_replay {
            bail!(
                "checkpoint {} already has {} completed steps; nothing to do for a {}-step run \
                 (pass the run's total steps, not the remaining steps)",
                path.display(),
                ck.step,
                opts.steps
            );
        }
        let specs = param_specs(&self.model);
        if ck.param_names.len() != specs.len()
            || ck.param_names.iter().zip(specs.iter()).any(|(n, s)| *n != s.name)
        {
            bail!("checkpoint {} params do not match model {}", path.display(), self.model.name);
        }
        // Numerics-affecting options must match the original run, or
        // the resumed trajectory silently diverges from the continuous
        // one: total steps shape the LR schedule (resuming with the
        // *remaining* count is the classic mistake), threshold and the
        // decision policy change decisions, and the val/suite cadence
        // changes which validation batches are consumed.
        let pinned = [
            ("opt/steps", opts.steps, "--steps (the run's TOTAL, not remaining)"),
            ("opt/threshold_bits", opts.threshold.to_bits() as u64, "--threshold"),
            ("opt/val_every", opts.val_every, "--val-every"),
            ("opt/suite_every", opts.suite_every, "--suite-every"),
            ("opt/per_channel", opts.per_channel as u64, "per-channel stats"),
            ("opt/stats_window", opts.stats_window, "--stats-window"),
            ("opt/policy", policy.pin(), "--policy"),
            ("opt/guard", opts.guard.map_or(0, |g| g.pin()), "--guard"),
        ];
        for (key, got, flag) in pinned {
            if let Some(want) = ck.counter(key) {
                if want != got {
                    // The supervisor's demotion escape hatch: a demoted
                    // tenant deliberately resumes under a different
                    // policy/guard, which is a visible precision change
                    // — never a silent one — so only those two pins may
                    // be overridden.
                    if opts.repin && matches!(key, "opt/policy" | "opt/guard") {
                        if !opts.quiet {
                            println!(
                                "[repin] {flag} changes from {key}={want} to {got} \
                                 (supervised demotion)"
                            );
                        }
                        continue;
                    }
                    bail!(
                        "checkpoint {} pins {flag} ({key}={want}) but this run uses {got}; \
                         resume with the original settings to keep the bitwise contract",
                        path.display()
                    );
                }
            }
        }
        session
            .import_state(&ck.session)
            .with_context(|| format!("importing session state from {}", path.display()))?;
        Ok(ck)
    }

    /// Write a full `MORCKPT2` training checkpoint: session state plus
    /// every piece of coordinator-owned dynamic state a bitwise resume
    /// needs.
    #[allow(clippy::too_many_arguments)]
    fn save_checkpoint(
        &self,
        session: &TrainSession,
        train_loader: &BatchLoader,
        val_loader: &BatchLoader,
        stats: &StatsCollector,
        records: &[StepRecord],
        suite_history: &[(u64, EvalScores)],
        last_val: f32,
        ckpts_written: u64,
        opts: &TrainerOptions,
        policy: &PolicyRef,
        faults: Option<&FaultPlan>,
        guard: Option<&NumericGuard>,
    ) -> Result<PathBuf> {
        let state = session.export_state()?;
        let train_cursor = train_loader.cursor();
        let val_cursor = val_loader.cursor();
        let rng_streams = vec![
            (section::DATA_TRAIN.to_string(), train_cursor.state.rng_state),
            (section::DATA_VAL.to_string(), val_cursor.state.rng_state),
        ];
        let counters = vec![
            ("train_batches".to_string(), train_cursor.batches),
            ("val_batches".to_string(), val_cursor.batches),
            ("suite_passes".to_string(), suite_history.len() as u64),
            ("ckpts_written".to_string(), ckpts_written),
            // Numerics-affecting options, pinned so a resume with a
            // different setting errors instead of silently breaking
            // the bitwise resume ≡ continuous contract. `steps` pins
            // the LR schedule AND guarantees the forced final-step
            // val/suite pass can never precede a resumable checkpoint.
            ("opt/steps".to_string(), opts.steps),
            ("opt/threshold_bits".to_string(), opts.threshold.to_bits() as u64),
            ("opt/val_every".to_string(), opts.val_every),
            ("opt/suite_every".to_string(), opts.suite_every),
            ("opt/per_channel".to_string(), opts.per_channel as u64),
            ("opt/stats_window".to_string(), opts.stats_window),
            ("opt/policy".to_string(), policy.pin()),
            // The guard config is pinned (0 = off); the fault schedule
            // deliberately is NOT — consumed one-shot faults must not
            // re-fire on a rewind replay or a clean restart.
            ("opt/guard".to_string(), opts.guard.map_or(0, |g| g.pin())),
        ];
        let ck = TrainCheckpoint {
            step: state.step,
            artifact: opts.artifact.clone(),
            config: self.train_config.name.to_string(),
            last_val,
            param_names: param_specs(&self.model).iter().map(|s| s.name.clone()).collect(),
            session: state,
            train_cursor,
            val_cursor,
            rng_streams,
            stats: stats.clone(),
            // Digest by default: O(1) per save instead of embedding the
            // ever-growing row history (the old O(steps²/ckpt_every)
            // cost); `--embed-metrics` keeps the legacy representation.
            metrics: if opts.embed_metrics {
                MetricsState::Embedded(records.to_vec())
            } else {
                MetricsState::Digest {
                    rows: records.len() as u64,
                    hash: csv_lines_digest(records.iter().map(|r| r.csv_line())),
                }
            },
            suite_history: suite_history.to_vec(),
            counters,
            guard_state: guard.map(|g| g.export_state()),
        };
        let path = opts.out_dir.join(format!("{}.step{}.ckpt", opts.artifact, ck.step));
        ck.save_with_faults(&path, faults, ckpts_written)?;
        Ok(path)
    }
}

/// How many cooperative yields a stalled step spends watching the stop
/// flag before it self-preempts. A fixed iteration budget (not a
/// wall-clock timeout) keeps stalled runs bitwise-reproducible: the
/// outcome — suspend at this step boundary — is the same whether the
/// flag arrives on the first yield or never.
const STALL_POLL_BUDGET: u32 = 4096;

/// Poll the cooperative stop flag while "hung", yielding between reads;
/// returns whether the flag was observed set before the budget ran out.
/// With no flag wired the budget is skipped entirely — the stall is
/// about scheduling, not about burning CPU.
fn poll_stop(flag: Option<&AtomicBool>) -> bool {
    let Some(flag) = flag else { return false };
    for _ in 0..STALL_POLL_BUDGET {
        if flag.load(Ordering::Relaxed) {
            return true;
        }
        std::thread::yield_now();
    }
    false
}

/// Best-effort text of a panic payload, for guard event details.
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Resolve the metrics prefix of a resumed run: the bit-exact records
/// plus the raw CSV lines to replay verbatim into the new metrics file.
///
/// Embedded checkpoints carry the records directly (lines re-formatted
/// from the exact bits). Digest checkpoints replay from the original
/// run's on-disk `metrics.csv` — located next to the checkpoint, since
/// both were written to the same out_dir — after verifying the row
/// count and FNV-1a content hash, so a modified or foreign file fails
/// loudly instead of silently corrupting the resume≡continuous
/// contract. The replayed rows parse back bit-exactly because
/// [`StepRecord::csv_line`] uses shortest-round-trip float formatting.
fn restore_metrics(
    ck: &TrainCheckpoint,
    resume_path: &std::path::Path,
    artifact: &str,
    config_name: &str,
) -> Result<(Vec<StepRecord>, Vec<String>)> {
    match &ck.metrics {
        MetricsState::Embedded(records) => {
            let lines = records.iter().map(|r| r.csv_line()).collect();
            Ok((records.clone(), lines))
        }
        MetricsState::Digest { rows, hash } => {
            let dir = resume_path.parent().unwrap_or_else(|| std::path::Path::new("."));
            let csv = dir.join(format!("{artifact}.{config_name}.csv"));
            let text = std::fs::read_to_string(&csv).with_context(|| {
                format!(
                    "checkpoint {} stores a metrics digest; its prefix replays from the \
                     original run's metrics file {}",
                    resume_path.display(),
                    csv.display()
                )
            })?;
            let lines: Vec<String> =
                text.lines().skip(1).take(*rows as usize).map(str::to_string).collect();
            if (lines.len() as u64) != *rows {
                bail!(
                    "metrics file {} has {} data rows; checkpoint {} covers {}",
                    csv.display(),
                    lines.len(),
                    resume_path.display(),
                    rows
                );
            }
            let got = csv_lines_digest(lines.iter());
            if got != *hash {
                bail!(
                    "metrics file {} does not match the checkpoint digest (got {got:#018x}, \
                     want {hash:#018x}); the file was modified or belongs to a different run",
                    csv.display()
                );
            }
            let mut records = Vec::with_capacity(lines.len());
            for (i, line) in lines.iter().enumerate() {
                records.push(StepRecord::parse_csv_line(line).ok_or_else(|| {
                    anyhow::anyhow!(
                        "metrics file {} row {i} is unparseable: {line:?}",
                        csv.display()
                    )
                })?);
            }
            Ok((records, lines))
        }
    }
}

/// A mask scoring every position except the last (plain LM validation).
pub fn full_mask(batch: usize, seq: usize) -> Vec<f32> {
    let mut m = vec![1.0f32; batch * seq];
    for b in 0..batch {
        m[b * seq + seq - 1] = 0.0;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_shape() {
        let m = full_mask(2, 4);
        assert_eq!(m, vec![1., 1., 1., 0., 1., 1., 1., 0.]);
    }

    #[test]
    fn options_defaults() {
        let o = TrainerOptions::new("train_baseline", 10, PathBuf::from("/tmp/x"));
        assert_eq!(o.threshold, 0.045);
        assert!(o.val_every > 0);
        assert!(o.resume.is_none());
        assert!(!o.embed_metrics, "digest mode is the default");
    }
}
