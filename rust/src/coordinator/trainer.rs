//! The training loop driver: schedule → data → compiled step → metrics.
//!
//! One `Trainer::run` produces everything a paper figure needs from one
//! run: the loss/param-norm series (Figs. 5/6/8/20), the eval-suite
//! trajectory (Figs. 7/9/21), and the per-tensor decision statistics
//! (Figs. 10–19) via [`StatsCollector`].

use super::checkpoint::Checkpoint;
use super::eval::{eval_suite, EvalScores};
use super::logging::{MetricsLogger, StepRecord};
use crate::data::loader::BatchLoader;
use crate::data::synthetic::CorpusProfile;
use crate::data::tasks::EvalSuite;
use crate::model::config::{ModelConfig, TrainConfig};
use crate::model::naming::{param_specs, QuantTensorId};
use crate::mor::stats::StatsCollector;
use crate::runtime::Runtime;
use crate::util::par::Parallelism;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    /// Manifest name of the train artifact (selects the recipe).
    pub artifact: String,
    pub steps: u64,
    /// E4M3 acceptance threshold fed to the compiled step (4.5% paper
    /// default; 5.0% ablation).
    pub threshold: f32,
    /// Validate every N steps (0 = never).
    pub val_every: u64,
    /// Run the eval-task suite every N steps (0 = never).
    pub suite_every: u64,
    /// Checkpoint every N steps (0 = never).
    pub ckpt_every: u64,
    /// Histogram reset window (Fig. 14); paper uses 6000 of its steps.
    pub stats_window: u64,
    /// Output directory for metrics.csv / stats CSV / checkpoints.
    pub out_dir: PathBuf,
    /// Whether the artifact's partition is per-channel (direction-
    /// resolved stats keys).
    pub per_channel: bool,
    /// Run quietly (no per-step stdout).
    pub quiet: bool,
    /// Per-run engine handle for the quantization/GEMM hot paths
    /// (`None` inherits the runtime's default; see `util::par`). The
    /// handle is owned by this run's sessions, so no run ever mutates
    /// a process-global setting. Runs inheriting one runtime's default
    /// share that runtime's pool (safely — results are bit-identical
    /// for any thread count); give each run a `Some(...)` override for
    /// pool isolation.
    pub parallelism: Option<Parallelism>,
}

impl TrainerOptions {
    pub fn new(artifact: &str, steps: u64, out_dir: PathBuf) -> Self {
        TrainerOptions {
            artifact: artifact.to_string(),
            steps,
            threshold: 0.045,
            val_every: 20,
            suite_every: 0,
            ckpt_every: 0,
            stats_window: 50,
            out_dir,
            per_channel: false,
            quiet: false,
            parallelism: None,
        }
    }
}

/// Summary of a finished run.
#[derive(Debug)]
pub struct TrainOutcome {
    pub final_train_loss: f32,
    pub final_val_loss: f32,
    pub records: Vec<StepRecord>,
    pub stats: StatsCollector,
    /// (step, scores) trajectory of the eval-task suite.
    pub suite_history: Vec<(u64, EvalScores)>,
    pub metrics_path: PathBuf,
    pub mean_step_ms: f32,
}

/// The training coordinator.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    pub model: ModelConfig,
    pub train_config: TrainConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, train_config: TrainConfig) -> Self {
        Trainer { runtime, model: runtime.model, train_config }
    }

    pub fn run(&self, opts: &TrainerOptions) -> Result<TrainOutcome> {
        // One Parallelism handle per run, owned by the run's sessions:
        // the per-run override (or the runtime default) rides the
        // session API instead of a scoped process-global override.
        let par = opts
            .parallelism
            .clone()
            .unwrap_or_else(|| self.runtime.parallelism().clone());
        let tc = &self.train_config;
        let mut session = self
            .runtime
            .train_session_with(&opts.artifact, tc.seed, par.clone())
            .with_context(|| format!("starting session for {}", opts.artifact))?;
        let profile = CorpusProfile::from_id(tc.data_profile);
        let train_loader = BatchLoader::new(
            profile,
            self.model.vocab_size,
            session.batch,
            session.seq,
            tc.seed,
            0,
        );
        let val_loader = BatchLoader::new(
            profile,
            self.model.vocab_size,
            session.batch,
            session.seq,
            tc.seed,
            1,
        );
        let eval = self.runtime.eval_session_with("eval", par).ok();
        let suite = EvalSuite::new(session.seq, self.model.vocab_size, 8, tc.seed ^ 0xE7A1);

        std::fs::create_dir_all(&opts.out_dir)?;
        let metrics_path = opts.out_dir.join(format!("{}.{}.csv", opts.artifact, tc.name));
        let mut logger = MetricsLogger::create(&metrics_path)?;
        let mut stats = StatsCollector::new(opts.stats_window);
        let mut suite_history = Vec::new();
        let mut records = Vec::new();
        let mut total_ms = 0f32;
        let mut last_val = f32::NAN;
        let n_slots = QuantTensorId::count(&self.model);

        for step in 0..opts.steps {
            let lr = tc.schedule.lr_at(step);
            let batch = train_loader.next_batch();
            let t0 = Instant::now();
            let out = session.step(&batch.tokens, lr, opts.threshold)?;
            let step_ms = t0.elapsed().as_secs_f32() * 1e3;
            total_ms += step_ms;

            // Record per-slot decisions into the heatmap stats.
            stats.set_step(step);
            debug_assert_eq!(out.relerr.len(), n_slots);
            let mut fb_sum = 0f32;
            let mut re_sum = 0f32;
            for (i, (re, fb)) in out.relerr.iter().zip(out.fallback.iter()).enumerate() {
                let id = QuantTensorId::from_flat(i);
                // Direction-1 slots only carry signal for per-channel
                // partitions; other partitions mirror direction 0 and we
                // skip them to avoid double counting.
                if id.direction == 1 && !opts.per_channel {
                    continue;
                }
                stats.record(id.key(opts.per_channel), *re as f64, *fb >= 0.5, *fb as f64);
                fb_sum += fb;
                re_sum += re;
            }
            let denom = if opts.per_channel { n_slots } else { n_slots / 2 } as f32;

            // Validation loss on a held-out stream.
            let is_val_step = opts.val_every > 0
                && (step % opts.val_every == 0 || step + 1 == opts.steps);
            if is_val_step {
                if let Some(ev) = &eval {
                    let vb = val_loader.next_batch();
                    let mask = full_mask(session.batch, session.seq);
                    // Tensor-native interchange: on the host backend the
                    // eval borrows the trainer's params directly — no
                    // Tensor→Literal→Tensor round-trip per validation.
                    let (vl, _) = ev.eval_params(session.params_ref(), &vb.tokens, &mask)?;
                    last_val = vl;
                }
            }

            // Eval-task suite (the downstream-benchmark substitute).
            if opts.suite_every > 0
                && (step % opts.suite_every == 0 || step + 1 == opts.steps)
            {
                if let Some(ev) = &eval {
                    let scores = eval_suite(ev, session.params_ref(), &suite)?;
                    suite_history.push((step, scores));
                }
            }

            if opts.ckpt_every > 0 && step > 0 && step % opts.ckpt_every == 0 {
                self.save_checkpoint(&session, step, opts)?;
            }

            let rec = StepRecord {
                step,
                lr,
                train_loss: out.loss,
                val_loss: if is_val_step { last_val } else { f32::NAN },
                param_norm: session.param_norm()?,
                bf16_fallback_rate: fb_sum / denom,
                mean_relerr: re_sum / denom,
                step_ms,
            };
            logger.log(&rec)?;
            if !opts.quiet && (step % 10 == 0 || step + 1 == opts.steps) {
                println!(
                    "[{}] step {step:>5} loss {:.4} val {:.4} lr {:.2e} fb {:.2}% \
                     relerr {:.3}% ({:.0} ms)",
                    opts.artifact,
                    rec.train_loss,
                    rec.val_loss,
                    rec.lr,
                    rec.bf16_fallback_rate * 100.0,
                    rec.mean_relerr * 100.0,
                    step_ms
                );
            }
            records.push(rec);
        }
        logger.flush()?;

        // Persist the stats heatmap CSV next to the metrics.
        let stats_path = opts.out_dir.join(format!("{}.{}.stats.csv", opts.artifact, tc.name));
        std::fs::write(&stats_path, stats.heatmap_csv())?;

        let final_train_loss = records.last().map(|r| r.train_loss).unwrap_or(f32::NAN);
        Ok(TrainOutcome {
            final_train_loss,
            final_val_loss: last_val,
            mean_step_ms: total_ms / records.len().max(1) as f32,
            records,
            stats,
            suite_history,
            metrics_path,
        })
    }

    fn save_checkpoint(
        &self,
        session: &crate::runtime::TrainSession,
        step: u64,
        opts: &TrainerOptions,
    ) -> Result<()> {
        let specs = param_specs(&self.model);
        let params = session.params()?;
        let tensors = specs
            .iter()
            .map(|s| s.name.clone())
            .zip(params.into_iter())
            .collect();
        Checkpoint { step, tensors }
            .save(&opts.out_dir.join(format!("{}.step{step}.ckpt", opts.artifact)))
    }
}

/// A mask scoring every position except the last (plain LM validation).
pub fn full_mask(batch: usize, seq: usize) -> Vec<f32> {
    let mut m = vec![1.0f32; batch * seq];
    for b in 0..batch {
        m[b * seq + seq - 1] = 0.0;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_shape() {
        let m = full_mask(2, 4);
        assert_eq!(m, vec![1., 1., 1., 0., 1., 1., 1., 0.]);
    }

    #[test]
    fn options_defaults() {
        let o = TrainerOptions::new("train_baseline", 10, PathBuf::from("/tmp/x"));
        assert_eq!(o.threshold, 0.045);
        assert!(o.val_every > 0);
    }
}
