//! Checkpointing: the `MORCKPT2` sectioned binary container (no serde
//! offline) plus the full-training-state [`TrainCheckpoint`] built on
//! it — the on-disk half of the bitwise **resume ≡ continuous**
//! contract.
//!
//! ## Container layout (all integers/floats little-endian, explicitly
//! via `to_le_bytes`/`from_le_bytes` — the format is endian-stable)
//!
//! ```text
//! MORCKPT2:
//!   magic "MORCKPT2" | u64 step | u32 nsections |
//!     per section: u32 name_len | name bytes | u64 payload_len | payload
//!
//! tensor-list payload (sections "params", "opt/m", "opt/v"):
//!   u32 ntensors |
//!     per tensor: u32 name_len | name bytes | u32 ndims | u64 dims... |
//!                 f32 data (LE) ...
//! ```
//!
//! `step` counts **completed** optimizer steps; a resumed run continues
//! at exactly that step index. The legacy `MORCKPT1` layout (magic +
//! step + bare tensor list, params only) still loads — it simply has no
//! sections.
//!
//! Section names and payloads of a full training checkpoint (see
//! [`section`]): optimizer moments (`opt/m`, `opt/v`), data-loader
//! positions (`data/train`, `data/val`), raw `util::rng` stream states
//! (`rng/streams`), delayed-scaling amax histories
//! (`scaling/amax_hist`), the `mor::stats` collector (`mor/stats`),
//! the metrics rows logged so far (`metrics/records` — either the
//! embedded history, or the O(1) row-count + FNV-1a content digest of
//! the on-disk `metrics.csv` prefix that replaces it for long runs;
//! see [`MetricsState`]), the eval-suite trajectory (`eval/suite`),
//! run identity (`meta`), and extensible named telemetry counters
//! (`telemetry/counters`). Unknown sections are preserved on load, so
//! older readers skip newer state instead of failing.
//!
//! Every read is bounded: lengths are validated against the remaining
//! buffer **before** any allocation, name/dims counts have hard caps,
//! and malformed input (bad magic, truncated payloads, oversized
//! length fields) returns an `anyhow` error — never a panic or an
//! unchecked allocation (`rust/tests/checkpoint_roundtrip.rs` pins one
//! test per malformed-file class).

use crate::coordinator::eval::EvalScores;
use crate::coordinator::logging::StepRecord;
use crate::data::loader::LoaderCursor;
use crate::data::synthetic::CorpusState;
use crate::data::tasks::EvalTask;
use crate::mor::stats::{StatsCollector, TensorKey, TensorWindow, HIST_BINS};
use crate::runtime::TrainState;
use crate::scaling::delayed::AmaxHistory;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC_V1: &[u8; 8] = b"MORCKPT1";
const MAGIC_V2: &[u8; 8] = b"MORCKPT2";

/// Hard cap on any encoded name (tensor, section, counter, task).
pub const MAX_NAME_LEN: usize = 4096;
/// Hard cap on tensor rank.
pub const MAX_NDIMS: usize = 16;
/// Hard cap on the section count of one container.
pub const MAX_SECTIONS: usize = 256;

/// Canonical section names of a [`TrainCheckpoint`].
pub mod section {
    pub const PARAMS: &str = "params";
    pub const OPT_M: &str = "opt/m";
    pub const OPT_V: &str = "opt/v";
    pub const DATA_TRAIN: &str = "data/train";
    pub const DATA_VAL: &str = "data/val";
    pub const RNG: &str = "rng/streams";
    pub const SCALING: &str = "scaling/amax_hist";
    pub const STATS: &str = "mor/stats";
    pub const METRICS: &str = "metrics/records";
    pub const SUITE: &str = "eval/suite";
    pub const META: &str = "meta";
    pub const TELEMETRY: &str = "telemetry/counters";
    pub const GUARD: &str = "guard/state";
}

// ---------------------------------------------------------------------------
// Little-endian writer/reader primitives
// ---------------------------------------------------------------------------

pub(crate) fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= MAX_NAME_LEN, "name {s:?} exceeds MAX_NAME_LEN");
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Raw f32 payload, element-wise `to_le_bytes` (endian-stable; no
/// pointer punning anywhere in the format).
fn put_f32s(out: &mut Vec<u8>, data: &[f32]) {
    out.reserve(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked cursor over an in-memory checkpoint image. Every
/// `take` verifies the requested length against the remaining bytes, so
/// no length field can trigger an allocation larger than the file
/// itself.
pub(crate) struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("checkpoint truncated: {what} needs {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn f32(&mut self, what: &str) -> Result<f32> {
        let b = self.take(4, what)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        let b = self.take(8, what)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn str(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        if n > MAX_NAME_LEN {
            bail!("checkpoint corrupt: {what} length {n} exceeds cap {MAX_NAME_LEN}");
        }
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).with_context(|| format!("{what} is not utf8"))
    }

    /// `n` little-endian f32s, length-validated before allocating.
    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("checkpoint corrupt: {what} count overflows"))?;
        let raw = self.take(bytes, what)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub(crate) fn expect_done(&self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            bail!("checkpoint corrupt: {} trailing bytes after {what}", self.remaining());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// CRC-32 integrity trailer
// ---------------------------------------------------------------------------

/// Trailer magic appended after the section list by [`Checkpoint::
/// to_bytes_v2_crc`]. Files without it (every MORCKPT2 written before
/// the trailer existed) still load; files with trailing bytes that are
/// *not* a trailer are rejected as corrupt, as before.
const TRAILER_MAGIC: &[u8; 8] = b"MORCRC32";
const TRAILER_V1: u8 = 1;

/// CRC-32/ISO-HDLC (the zlib/PNG crc32): reflected, polynomial
/// 0xEDB88320, init and xor-out 0xFFFFFFFF. Bitwise implementation —
/// checkpoint writes are dominated by tensor serialization, not the
/// checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------------------
// Tensor-list codec (sections "params", "opt/m", "opt/v"; also the v1
// body)
// ---------------------------------------------------------------------------

fn put_tensor_entry(out: &mut Vec<u8>, name: &str, t: &Tensor) {
    put_str(out, name);
    debug_assert!(t.shape().len() <= MAX_NDIMS);
    put_u32(out, t.shape().len() as u32);
    for d in t.shape() {
        put_u64(out, *d as u64);
    }
    put_f32s(out, t.data());
}

fn put_tensors(out: &mut Vec<u8>, tensors: &[(String, Tensor)]) {
    put_u32(out, tensors.len() as u32);
    for (name, t) in tensors {
        put_tensor_entry(out, name, t);
    }
}

/// Tensor-list payload from parallel name/tensor slices — lets the
/// optimizer-moment sections serialize straight from borrowed session
/// state without cloning every tensor first.
fn put_named_tensors(out: &mut Vec<u8>, names: &[String], tensors: &[Tensor]) {
    debug_assert_eq!(names.len(), tensors.len());
    put_u32(out, tensors.len() as u32);
    for (name, t) in names.iter().zip(tensors) {
        put_tensor_entry(out, name, t);
    }
}

fn read_tensors(rd: &mut Rd) -> Result<Vec<(String, Tensor)>> {
    let n = rd.u32("tensor count")? as usize;
    // Each tensor costs ≥ 8 header bytes; a count the file cannot hold
    // is rejected before the Vec is sized.
    if n > rd.remaining() / 8 + 1 {
        bail!("checkpoint corrupt: tensor count {n} exceeds file capacity");
    }
    let mut tensors = Vec::with_capacity(n);
    for i in 0..n {
        let name = rd.str(&format!("tensor {i} name"))?;
        let ndims = rd.u32(&format!("tensor {name} ndims"))? as usize;
        if ndims > MAX_NDIMS {
            bail!("checkpoint corrupt: tensor {name} rank {ndims} exceeds cap {MAX_NDIMS}");
        }
        let mut shape = Vec::with_capacity(ndims);
        let mut vol = 1usize;
        for d in 0..ndims {
            let dim = rd.u64(&format!("tensor {name} dim {d}"))?;
            let dim = usize::try_from(dim)
                .map_err(|_| anyhow::anyhow!("tensor {name} dim {d} out of range"))?;
            vol = vol
                .checked_mul(dim)
                .ok_or_else(|| anyhow::anyhow!("tensor {name} volume overflows"))?;
            shape.push(dim);
        }
        let data = rd.f32s(vol, &format!("tensor {name} data"))?;
        tensors.push((name, Tensor::from_vec(&shape, data)));
    }
    Ok(tensors)
}

// ---------------------------------------------------------------------------
// The container
// ---------------------------------------------------------------------------

/// A checkpoint container: named tensors (the `params` section), the
/// completed-step count, and any number of opaque named state sections.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Completed optimizer steps at save time.
    pub step: u64,
    /// The `params` tensors (v1 files carry only these).
    pub tensors: Vec<(String, Tensor)>,
    /// Extra state sections, in on-disk order (`params` excluded).
    pub sections: Vec<(String, Vec<u8>)>,
}

impl Checkpoint {
    pub fn new(step: u64, tensors: Vec<(String, Tensor)>) -> Checkpoint {
        Checkpoint { step, tensors, sections: Vec::new() }
    }

    /// Append a named state section (keeps on-disk order). Callers own
    /// the write-side caps: at most [`MAX_SECTIONS`] sections, names at
    /// most [`MAX_NAME_LEN`] bytes and unique — the loader rejects
    /// violations, and `put_str` asserts on oversized names (a
    /// programmer error; the atomic temp+rename save means a panic
    /// here can never corrupt a published checkpoint).
    pub fn push_section(&mut self, name: &str, payload: Vec<u8>) {
        self.sections.push((name.to_string(), payload));
    }

    /// A section's payload by name.
    pub fn section(&self, name: &str) -> Option<&[u8]> {
        self.sections.iter().find(|(n, _)| n == name).map(|(_, p)| p.as_slice())
    }

    /// The `MORCKPT2` image plus the per-section payload CRCs, in
    /// on-disk section order (`params` first). Shared by the plain and
    /// trailer-carrying serializers so both produce the identical
    /// section image.
    fn v2_image(&self) -> (Vec<u8>, Vec<u32>) {
        let mut out = Vec::new();
        let mut crcs = Vec::with_capacity(1 + self.sections.len());
        out.extend_from_slice(MAGIC_V2);
        put_u64(&mut out, self.step);
        put_u32(&mut out, 1 + self.sections.len() as u32);
        let mut params = Vec::new();
        put_tensors(&mut params, &self.tensors);
        put_str(&mut out, section::PARAMS);
        put_u64(&mut out, params.len() as u64);
        out.extend_from_slice(&params);
        crcs.push(crc32(&params));
        for (name, payload) in &self.sections {
            put_str(&mut out, name);
            put_u64(&mut out, payload.len() as u64);
            out.extend_from_slice(payload);
            crcs.push(crc32(payload));
        }
        (out, crcs)
    }

    /// Serialize in the `MORCKPT2` layout (`params` section first, then
    /// the extra sections in order), without the integrity trailer —
    /// byte-identical to every pre-trailer writer, which keeps the
    /// committed golden fixture pinned.
    pub fn to_bytes_v2(&self) -> Vec<u8> {
        self.v2_image().0
    }

    /// Serialize with the CRC-32 integrity trailer appended:
    /// `"MORCRC32" | u8 version | u32 n | n × u32 payload CRC |
    /// u32 prefix CRC` (the last one covers every byte before it —
    /// container header and trailer head included — so header
    /// corruption is caught too). This is what [`Checkpoint::save`]
    /// writes; trailer-less v2 files still load.
    pub fn to_bytes_v2_crc(&self) -> Vec<u8> {
        let (mut out, crcs) = self.v2_image();
        out.extend_from_slice(TRAILER_MAGIC);
        put_u8(&mut out, TRAILER_V1);
        put_u32(&mut out, crcs.len() as u32);
        for c in &crcs {
            put_u32(&mut out, *c);
        }
        let prefix = crc32(&out);
        put_u32(&mut out, prefix);
        out
    }

    /// Serialize in the legacy `MORCKPT1` layout (params only; any
    /// extra sections are dropped). Kept for compatibility tests and
    /// interop with v1-only readers.
    pub fn to_bytes_v1(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        put_u64(&mut out, self.step);
        put_tensors(&mut out, &self.tensors);
        out
    }

    /// Parse either container version from an in-memory image.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        let mut rd = Rd::new(buf);
        let magic = rd.take(8, "magic")?;
        if magic == MAGIC_V1 {
            let step = rd.u64("step")?;
            let tensors = read_tensors(&mut rd)?;
            rd.expect_done("v1 tensor list")?;
            return Ok(Checkpoint { step, tensors, sections: Vec::new() });
        }
        if magic != MAGIC_V2 {
            bail!("not a MoR checkpoint (bad magic)");
        }
        let step = rd.u64("step")?;
        let nsections = rd.u32("section count")? as usize;
        if nsections > MAX_SECTIONS {
            bail!("checkpoint corrupt: {nsections} sections exceeds cap {MAX_SECTIONS}");
        }
        let mut tensors = Vec::new();
        let mut seen_params = false;
        let mut sections = Vec::new();
        // Per-payload CRCs in on-disk order, checked against the
        // trailer (when one is present) after the section list.
        let mut crcs = Vec::with_capacity(nsections);
        let mut names = Vec::with_capacity(nsections);
        for i in 0..nsections {
            let name = rd.str(&format!("section {i} name"))?;
            let len = rd.u64(&format!("section {name} length"))?;
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("section {name} length out of range"))?;
            let payload = rd.take(len, &format!("section {name} payload"))?;
            // Duplicate names would make lookups ambiguous (first-wins
            // vs last-wins); reject them as corrupt.
            if (name == section::PARAMS && seen_params)
                || sections.iter().any(|(n, _)| *n == name)
            {
                bail!("checkpoint corrupt: duplicate section {name:?}");
            }
            crcs.push(crc32(payload));
            names.push(name.clone());
            if name == section::PARAMS {
                let mut prd = Rd::new(payload);
                tensors = read_tensors(&mut prd)?;
                prd.expect_done("params section")?;
                seen_params = true;
            } else {
                sections.push((name, payload.to_vec()));
            }
        }
        if rd.remaining() > 0 {
            // Anything after the section list must be a valid CRC
            // trailer; arbitrary trailing bytes stay a corrupt file.
            let trailer_start = rd.pos;
            let magic = rd.take(8, "CRC trailer magic")?;
            if magic != TRAILER_MAGIC {
                bail!(
                    "checkpoint corrupt: {} trailing bytes after section list \
                     are not a CRC trailer",
                    buf.len() - trailer_start
                );
            }
            let version = rd.u8("CRC trailer version")?;
            if version != TRAILER_V1 {
                bail!("checkpoint corrupt: unknown CRC trailer version {version}");
            }
            let n = rd.u32("CRC trailer entry count")? as usize;
            if n != crcs.len() {
                bail!(
                    "checkpoint corrupt: CRC trailer lists {n} sections, file has {}",
                    crcs.len()
                );
            }
            for (i, want) in crcs.iter().enumerate() {
                let got = rd.u32(&format!("section {} CRC", names[i]))?;
                if got != *want {
                    bail!(
                        "checkpoint corrupt: section {:?} CRC mismatch \
                         (stored {got:#010x}, computed {want:#010x})",
                        names[i]
                    );
                }
            }
            let prefix_end = rd.pos;
            let stored_prefix = rd.u32("prefix CRC")?;
            let computed_prefix = crc32(&buf[..prefix_end]);
            if stored_prefix != computed_prefix {
                bail!(
                    "checkpoint corrupt: prefix CRC mismatch \
                     (stored {stored_prefix:#010x}, computed {computed_prefix:#010x})"
                );
            }
            rd.expect_done("CRC trailer")?;
        }
        if !seen_params {
            bail!("checkpoint corrupt: no params section");
        }
        Ok(Checkpoint { step, tensors, sections })
    }

    /// Save in the current (`MORCKPT2`) format, with the CRC trailer.
    pub fn save(&self, path: &Path) -> Result<()> {
        write_file(path, &self.to_bytes_v2_crc())
    }

    /// [`Checkpoint::save`] with an optional fault-injection plan: when
    /// the plan schedules a torn save for this 1-based save index, the
    /// first half of the image is written DIRECTLY to the final path —
    /// deliberately skipping the temp+rename+fsync discipline — to
    /// model a crash mid-write. `--auto-resume` must skip the result.
    pub fn save_with_faults(
        &self,
        path: &Path,
        faults: Option<&crate::faults::FaultPlan>,
        save_index: u64,
    ) -> Result<()> {
        if let Some(fp) = faults {
            if fp.torn_save_due(save_index) {
                let bytes = self.to_bytes_v2_crc();
                if let Some(parent) = path.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                std::fs::write(path, &bytes[..bytes.len() / 2])
                    .with_context(|| format!("torn-writing checkpoint {}", path.display()))?;
                return Ok(());
            }
        }
        self.save(path)
    }

    /// Save in the legacy (`MORCKPT1`) format.
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        write_file(path, &self.to_bytes_v1())
    }

    /// Load either container version.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let buf = std::fs::read(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        Self::from_bytes(&buf)
            .with_context(|| format!("parsing checkpoint {}", path.display()))
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// Atomic, durable write: a crash mid-save (the exact scenario resume
/// exists for) must never leave a truncated file at the checkpoint
/// path, so the bytes land in a same-directory temp file first —
/// fsynced before the rename, with the parent directory fsynced after,
/// so neither the content nor the directory entry can be lost to a
/// power cut after `save` returns. The temp file is removed on every
/// error path; stale temps from killed processes are reaped by
/// [`sweep_stale_tmp`].
fn write_file(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(&format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    let write_synced = || -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    };
    if let Err(e) = write_synced() {
        std::fs::remove_file(&tmp).ok();
        return Err(e).with_context(|| format!("writing checkpoint {}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e).with_context(|| format!("publishing checkpoint {}", path.display()));
    }
    // Durability of the rename itself: fsync the parent directory.
    // Best-effort — not every filesystem lets you open a directory for
    // sync (the rename already happened, so this can only strengthen).
    #[cfg(unix)]
    {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        if let Ok(dir) = std::fs::File::open(parent) {
            dir.sync_all().ok();
        }
    }
    Ok(())
}

/// Remove stale `*.ckpt.tmp.*` files left behind by processes killed
/// mid-save. Returns how many were removed. Called when opening a
/// checkpoint directory for auto-resume; ignores unreadable dirs.
pub fn sweep_stale_tmp(dir: &Path) -> usize {
    let mut removed = 0;
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return 0,
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.contains(".ckpt.tmp.") && std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Scan a run directory for the checkpoint ring of one artifact:
/// every `{artifact}.step{N}.ckpt` file, returned as (step, path)
/// sorted newest-first. Purely name-based — corrupt/torn files are
/// still listed; the auto-resume walk decides loadability.
pub fn scan_ring(dir: &Path, artifact: &str) -> Vec<(u64, std::path::PathBuf)> {
    let mut ring = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return ring,
    };
    let prefix = format!("{artifact}.step");
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix(&prefix) {
            if let Some(num) = rest.strip_suffix(".ckpt") {
                if let Ok(step) = num.parse::<u64>() {
                    ring.push((step, entry.path()));
                }
            }
        }
    }
    ring.sort_by(|a, b| b.0.cmp(&a.0));
    ring
}

// ---------------------------------------------------------------------------
// Section codecs for the full training state
// ---------------------------------------------------------------------------

/// `data/*` payload: Markov context + pending pattern tail + consumed
/// batch count. The RNG state of the stream lives in `rng/streams`
/// (one logical home per kind of state, no duplication).
fn put_data_cursor(out: &mut Vec<u8>, cur: &LoaderCursor) {
    put_u8(out, cur.state.context.0);
    put_u8(out, cur.state.context.1);
    put_u32(out, cur.state.pending.len() as u32);
    out.extend_from_slice(&cur.state.pending);
    put_u64(out, cur.batches);
}

fn read_data_cursor(rd: &mut Rd, rng_state: u64) -> Result<LoaderCursor> {
    let a = rd.u8("cursor context")?;
    let b = rd.u8("cursor context")?;
    let npend = rd.u32("cursor pending length")? as usize;
    let pending = rd.take(npend, "cursor pending")?.to_vec();
    let batches = rd.u64("cursor batches")?;
    Ok(LoaderCursor { state: CorpusState { rng_state, context: (a, b), pending }, batches })
}

/// `rng/streams` payload: named raw `util::rng` stream states.
fn put_rng_streams(out: &mut Vec<u8>, streams: &[(String, u64)]) {
    put_u32(out, streams.len() as u32);
    for (name, state) in streams {
        put_str(out, name);
        put_u64(out, *state);
    }
}

fn read_rng_streams(rd: &mut Rd) -> Result<Vec<(String, u64)>> {
    let n = rd.u32("rng stream count")? as usize;
    if n > rd.remaining() / 12 + 1 {
        bail!("checkpoint corrupt: rng stream count {n} exceeds file capacity");
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let name = rd.str(&format!("rng stream {i} name"))?;
        let state = rd.u64(&format!("rng stream {name}"))?;
        out.push((name, state));
    }
    Ok(out)
}

/// `scaling/amax_hist` payload: per-slot (window, values) histories.
fn put_amax_histories(out: &mut Vec<u8>, hists: &[AmaxHistory]) {
    put_u32(out, hists.len() as u32);
    for h in hists {
        put_u32(out, h.window() as u32);
        let vals: Vec<f32> = h.values().collect();
        put_u32(out, vals.len() as u32);
        put_f32s(out, &vals);
    }
}

fn read_amax_histories(rd: &mut Rd) -> Result<Vec<AmaxHistory>> {
    let n = rd.u32("amax history count")? as usize;
    if n > rd.remaining() / 8 + 1 {
        bail!("checkpoint corrupt: amax history count {n} exceeds file capacity");
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let window = rd.u32(&format!("amax history {i} window"))? as usize;
        let len = rd.u32(&format!("amax history {i} length"))? as usize;
        let vals = rd.f32s(len, &format!("amax history {i} values"))?;
        if vals.len() > window.max(1) {
            bail!("checkpoint corrupt: amax history {i} longer than its window");
        }
        out.push(AmaxHistory::from_values(window, &vals));
    }
    Ok(out)
}

/// `mor/stats` payload: the full collector (windows + running totals).
fn put_stats(out: &mut Vec<u8>, stats: &StatsCollector) {
    put_u64(out, stats.reset_every);
    put_u64(out, stats.step());
    let put_key = |out: &mut Vec<u8>, key: &TensorKey| {
        let (layer, linear, tensor, dir) = key.codes();
        put_u32(out, layer);
        put_u8(out, linear);
        put_u8(out, tensor);
        put_u8(out, dir);
    };
    let put_window = |out: &mut Vec<u8>, w: &TensorWindow| {
        for c in &w.hist.counts {
            put_u64(out, *c);
        }
        put_u64(out, w.fallback_count);
        put_u64(out, w.steps);
        put_f64(out, w.bf16_fraction_sum);
    };
    let windows: Vec<_> = stats.window_entries().collect();
    put_u32(out, windows.len() as u32);
    for ((win, key), w) in windows {
        put_u64(out, *win);
        put_key(out, key);
        put_window(out, w);
    }
    let totals: Vec<_> = stats.total_entries().collect();
    put_u32(out, totals.len() as u32);
    for (key, w) in totals {
        put_key(out, key);
        put_window(out, w);
    }
}

fn read_stats_key(rd: &mut Rd) -> Result<TensorKey> {
    let layer = rd.u32("stats key layer")?;
    let linear = rd.u8("stats key linear")?;
    let tensor = rd.u8("stats key tensor")?;
    let dir = rd.u8("stats key direction")?;
    TensorKey::from_codes(layer, linear, tensor, dir)
        .ok_or_else(|| anyhow::anyhow!("checkpoint corrupt: bad stats key codes"))
}

fn read_stats_window(rd: &mut Rd) -> Result<TensorWindow> {
    let mut w = TensorWindow::default();
    for c in w.hist.counts.iter_mut() {
        *c = rd.u64("stats histogram bin")?;
    }
    debug_assert_eq!(w.hist.counts.len(), HIST_BINS);
    w.fallback_count = rd.u64("stats fallback count")?;
    w.steps = rd.u64("stats step count")?;
    w.bf16_fraction_sum = rd.f64("stats bf16 fraction")?;
    Ok(w)
}

fn read_stats(rd: &mut Rd) -> Result<StatsCollector> {
    let reset_every = rd.u64("stats reset_every")?;
    let step = rd.u64("stats step")?;
    // Window entries cost ≥ 8+7+HIST_BINS*8 bytes each.
    let per_entry = 8 + 7 + HIST_BINS * 8 + 24;
    let nw = rd.u32("stats window count")? as usize;
    if nw > rd.remaining() / per_entry + 1 {
        bail!("checkpoint corrupt: stats window count {nw} exceeds file capacity");
    }
    let mut windows = Vec::with_capacity(nw);
    for _ in 0..nw {
        let win = rd.u64("stats window index")?;
        let key = read_stats_key(rd)?;
        let w = read_stats_window(rd)?;
        windows.push(((win, key), w));
    }
    let nt = rd.u32("stats total count")? as usize;
    if nt > rd.remaining() / (per_entry - 8) + 1 {
        bail!("checkpoint corrupt: stats total count {nt} exceeds file capacity");
    }
    let mut totals = Vec::with_capacity(nt);
    for _ in 0..nt {
        let key = read_stats_key(rd)?;
        let w = read_stats_window(rd)?;
        totals.push((key, w));
    }
    Ok(StatsCollector::restore(reset_every, step, windows, totals))
}

/// How a checkpoint carries the metrics rows logged so far.
///
/// `Embedded` is the original scheme: the exact `StepRecord`s (f32 bit
/// patterns preserved, so re-logging them reproduces the continuous
/// run's CSV text byte-for-byte). Its cost grows with the step count —
/// O(steps²/ckpt_every) bytes written over a long run.
///
/// `Digest` is the O(1) replacement: a row count plus the FNV-1a 64
/// hash of the CSV data lines
/// ([`crate::coordinator::logging::csv_lines_digest`]). On resume the
/// trainer replays the prefix from the original run's on-disk
/// `metrics.csv` — verified against the digest before anything is
/// trusted — which is lossless because [`StepRecord::csv_line`] uses
/// shortest-round-trip float formatting.
#[derive(Debug, Clone)]
pub enum MetricsState {
    /// Full history embedded in the checkpoint (legacy mode; every
    /// MORCKPT2 written before the digest existed decodes to this).
    Embedded(Vec<StepRecord>),
    /// Row count + content hash of the on-disk metrics CSV prefix.
    Digest { rows: u64, hash: u64 },
}

impl MetricsState {
    /// The embedded rows, if this is the legacy representation.
    pub fn embedded(&self) -> Option<&[StepRecord]> {
        match self {
            MetricsState::Embedded(r) => Some(r),
            MetricsState::Digest { .. } => None,
        }
    }

    /// Number of metrics rows the checkpoint accounts for.
    pub fn rows(&self) -> u64 {
        match self {
            MetricsState::Embedded(r) => r.len() as u64,
            MetricsState::Digest { rows, .. } => *rows,
        }
    }
}

/// Digest-payload marker: a leading record count of `u32::MAX` cannot
/// occur in a legacy embedded payload (the capacity check below rejects
/// any count the file cannot hold), so the same `metrics/records`
/// section name stays readable across both representations.
const METRICS_DIGEST_SENTINEL: u32 = u32::MAX;
/// Digest payload version (after the sentinel).
const METRICS_DIGEST_V1: u8 = 1;

/// `metrics/records` payload, either representation.
fn put_metrics(out: &mut Vec<u8>, metrics: &MetricsState) {
    match metrics {
        MetricsState::Embedded(records) => {
            put_u32(out, records.len() as u32);
            for r in records {
                put_u64(out, r.step);
                put_f32(out, r.lr);
                put_f32(out, r.train_loss);
                put_f32(out, r.val_loss);
                put_f32(out, r.param_norm);
                put_f32(out, r.bf16_fallback_rate);
                put_f32(out, r.mean_relerr);
                put_f32(out, r.step_ms);
            }
        }
        MetricsState::Digest { rows, hash } => {
            put_u32(out, METRICS_DIGEST_SENTINEL);
            put_u8(out, METRICS_DIGEST_V1);
            put_u64(out, *rows);
            put_u64(out, *hash);
        }
    }
}

fn read_metrics(rd: &mut Rd) -> Result<MetricsState> {
    let n = rd.u32("record count")?;
    if n == METRICS_DIGEST_SENTINEL {
        let version = rd.u8("metrics digest version")?;
        if version != METRICS_DIGEST_V1 {
            bail!("checkpoint corrupt: unknown metrics digest version {version}");
        }
        let rows = rd.u64("metrics digest rows")?;
        let hash = rd.u64("metrics digest hash")?;
        return Ok(MetricsState::Digest { rows, hash });
    }
    let n = n as usize;
    if n > rd.remaining() / 36 + 1 {
        bail!("checkpoint corrupt: record count {n} exceeds file capacity");
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let what = format!("record {i}");
        out.push(StepRecord {
            step: rd.u64(&what)?,
            lr: rd.f32(&what)?,
            train_loss: rd.f32(&what)?,
            val_loss: rd.f32(&what)?,
            param_norm: rd.f32(&what)?,
            bf16_fallback_rate: rd.f32(&what)?,
            mean_relerr: rd.f32(&what)?,
            step_ms: rd.f32(&what)?,
        });
    }
    Ok(MetricsState::Embedded(out))
}

/// `eval/suite` payload: the (step, per-task scores) trajectory.
fn put_suite(out: &mut Vec<u8>, suite: &[(u64, EvalScores)]) {
    put_u32(out, suite.len() as u32);
    for (step, scores) in suite {
        put_u64(out, *step);
        put_u32(out, scores.per_task.len() as u32);
        for (name, loss, acc) in &scores.per_task {
            put_str(out, name);
            put_f32(out, *loss);
            put_f32(out, *acc);
        }
    }
}

fn read_suite(rd: &mut Rd) -> Result<Vec<(u64, EvalScores)>> {
    let n = rd.u32("suite entry count")? as usize;
    if n > rd.remaining() / 12 + 1 {
        bail!("checkpoint corrupt: suite entry count {n} exceeds file capacity");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let step = rd.u64("suite step")?;
        let ntasks = rd.u32("suite task count")? as usize;
        if ntasks > rd.remaining() / 12 + 1 {
            bail!("checkpoint corrupt: suite task count {ntasks} exceeds file capacity");
        }
        let mut per_task = Vec::with_capacity(ntasks);
        for _ in 0..ntasks {
            let name = rd.str("suite task name")?;
            let loss = rd.f32("suite task loss")?;
            let acc = rd.f32("suite task acc")?;
            // Map back to the task vocabulary's 'static name.
            let task = EvalTask::ALL
                .iter()
                .find(|t| t.name() == name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint has unknown eval task {name:?}"))?;
            per_task.push((task.name(), loss, acc));
        }
        out.push((step, EvalScores { per_task }));
    }
    Ok(out)
}

/// `telemetry/counters` payload: extensible named u64 counters.
fn put_counters(out: &mut Vec<u8>, counters: &[(String, u64)]) {
    put_u32(out, counters.len() as u32);
    for (name, v) in counters {
        put_str(out, name);
        put_u64(out, *v);
    }
}

fn read_counters(rd: &mut Rd) -> Result<Vec<(String, u64)>> {
    let n = rd.u32("counter count")? as usize;
    if n > rd.remaining() / 12 + 1 {
        bail!("checkpoint corrupt: counter count {n} exceeds file capacity");
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let name = rd.str(&format!("counter {i} name"))?;
        let v = rd.u64(&format!("counter {name}"))?;
        out.push((name, v));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// The full training-state checkpoint
// ---------------------------------------------------------------------------

/// Everything a bitwise resume needs, decoded: the session state
/// ([`TrainState`]), both data-loader cursors, raw RNG stream states,
/// the stats collector, the metrics rows and eval-suite trajectory
/// logged so far, and run identity/telemetry. `Trainer::run` writes one
/// of these every `--ckpt-every` steps and `--resume` restores it.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// Completed optimizer steps (== `session.step`).
    pub step: u64,
    /// Artifact (recipe) the run was training.
    pub artifact: String,
    /// Train-config name (`config1`/`config2`).
    pub config: String,
    /// Last validation loss (NaN if never validated).
    pub last_val: f32,
    /// Parameter names, canonical `param_specs` order.
    pub param_names: Vec<String>,
    pub session: TrainState,
    pub train_cursor: LoaderCursor,
    pub val_cursor: LoaderCursor,
    /// Named raw `util::rng` stream states (includes the two corpus
    /// streams; extensible).
    pub rng_streams: Vec<(String, u64)>,
    pub stats: StatsCollector,
    /// Metrics rows logged so far: embedded history (legacy) or an
    /// O(1) row-count + content-hash digest of the on-disk CSV prefix.
    pub metrics: MetricsState,
    pub suite_history: Vec<(u64, EvalScores)>,
    /// Extensible named telemetry counters.
    pub counters: Vec<(String, u64)>,
    /// Opaque numeric-guard state (`guard/state` section), present only
    /// when a run trains with `--guard` — see `coordinator::guard`.
    /// Carried opaquely so old readers skip it, per the section
    /// contract.
    pub guard_state: Option<Vec<u8>>,
}

impl TrainCheckpoint {
    /// Assemble the sectioned container (the `params`/`opt` tensor
    /// lists are named by `param_names`).
    pub fn to_container(&self) -> Checkpoint {
        // The container owns its `params` tensors (one clone); the
        // moment sections serialize straight from borrowed state.
        let params = self
            .param_names
            .iter()
            .cloned()
            .zip(self.session.params.iter().cloned())
            .collect();
        let mut ck = Checkpoint::new(self.step, params);
        let mut buf = Vec::new();
        put_str(&mut buf, &self.artifact);
        put_str(&mut buf, &self.config);
        put_f32(&mut buf, self.last_val);
        ck.push_section(section::META, buf);

        let mut buf = Vec::new();
        put_named_tensors(&mut buf, &self.param_names, &self.session.opt_m);
        ck.push_section(section::OPT_M, buf);
        let mut buf = Vec::new();
        put_named_tensors(&mut buf, &self.param_names, &self.session.opt_v);
        ck.push_section(section::OPT_V, buf);

        let mut buf = Vec::new();
        put_data_cursor(&mut buf, &self.train_cursor);
        ck.push_section(section::DATA_TRAIN, buf);
        let mut buf = Vec::new();
        put_data_cursor(&mut buf, &self.val_cursor);
        ck.push_section(section::DATA_VAL, buf);

        let mut buf = Vec::new();
        put_rng_streams(&mut buf, &self.rng_streams);
        ck.push_section(section::RNG, buf);

        let mut buf = Vec::new();
        put_amax_histories(&mut buf, &self.session.amax_hist);
        ck.push_section(section::SCALING, buf);

        let mut buf = Vec::new();
        put_stats(&mut buf, &self.stats);
        ck.push_section(section::STATS, buf);

        let mut buf = Vec::new();
        put_metrics(&mut buf, &self.metrics);
        ck.push_section(section::METRICS, buf);

        let mut buf = Vec::new();
        put_suite(&mut buf, &self.suite_history);
        ck.push_section(section::SUITE, buf);

        let mut buf = Vec::new();
        put_counters(&mut buf, &self.counters);
        ck.push_section(section::TELEMETRY, buf);

        if let Some(gs) = &self.guard_state {
            ck.push_section(section::GUARD, gs.clone());
        }
        ck
    }

    /// Decode a container holding a full training state. Fails with a
    /// descriptive error on a params-only (v1 or bare-v2) file.
    pub fn from_container(ck: &Checkpoint) -> Result<TrainCheckpoint> {
        fn sect<'c>(ck: &'c Checkpoint, name: &str) -> Result<Rd<'c>> {
            ck.section(name).map(Rd::new).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint has no {name:?} section — params-only files \
                     (e.g. MORCKPT1) cannot seed a bitwise resume"
                )
            })
        }

        let mut rd = sect(ck, section::META)?;
        let artifact = rd.str("meta artifact")?;
        let config = rd.str("meta config")?;
        let last_val = rd.f32("meta last_val")?;
        rd.expect_done("meta section")?;

        let split = |ts: &[(String, Tensor)]| -> (Vec<String>, Vec<Tensor>) {
            let names = ts.iter().map(|(n, _)| n.clone()).collect();
            let tensors = ts.iter().map(|(_, t)| t.clone()).collect();
            (names, tensors)
        };
        let (param_names, params) = split(&ck.tensors);
        let mut rd = sect(ck, section::OPT_M)?;
        let (m_names, opt_m) = split(&read_tensors(&mut rd)?);
        rd.expect_done("opt/m section")?;
        let mut rd = sect(ck, section::OPT_V)?;
        let (v_names, opt_v) = split(&read_tensors(&mut rd)?);
        rd.expect_done("opt/v section")?;
        if m_names != param_names || v_names != param_names {
            bail!("optimizer moment names do not match params");
        }

        let mut rd = sect(ck, section::RNG)?;
        let rng_streams = read_rng_streams(&mut rd)?;
        rd.expect_done("rng section")?;
        let stream = |name: &str| -> Result<u64> {
            rng_streams
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, s)| *s)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing rng stream {name:?}"))
        };

        let mut rd = sect(ck, section::DATA_TRAIN)?;
        let train_cursor = read_data_cursor(&mut rd, stream(section::DATA_TRAIN)?)?;
        rd.expect_done("data/train section")?;
        let mut rd = sect(ck, section::DATA_VAL)?;
        let val_cursor = read_data_cursor(&mut rd, stream(section::DATA_VAL)?)?;
        rd.expect_done("data/val section")?;

        let mut rd = sect(ck, section::SCALING)?;
        let amax_hist = read_amax_histories(&mut rd)?;
        rd.expect_done("scaling section")?;

        let mut rd = sect(ck, section::STATS)?;
        let stats = read_stats(&mut rd)?;
        rd.expect_done("stats section")?;

        let mut rd = sect(ck, section::METRICS)?;
        let metrics = read_metrics(&mut rd)?;
        rd.expect_done("metrics section")?;

        let mut rd = sect(ck, section::SUITE)?;
        let suite_history = read_suite(&mut rd)?;
        rd.expect_done("suite section")?;

        let mut rd = sect(ck, section::TELEMETRY)?;
        let counters = read_counters(&mut rd)?;
        rd.expect_done("telemetry section")?;

        // Optional: only guarded runs write it.
        let guard_state = ck.section(section::GUARD).map(|p| p.to_vec());

        Ok(TrainCheckpoint {
            step: ck.step,
            artifact,
            config,
            last_val,
            param_names,
            session: TrainState { step: ck.step, params, opt_m, opt_v, amax_hist },
            train_cursor,
            val_cursor,
            rng_streams,
            stats,
            metrics,
            suite_history,
            counters,
            guard_state,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_container().save(path)
    }

    /// [`TrainCheckpoint::save`] under an optional fault plan (torn
    /// saves); `save_index` is the run's 1-based checkpoint count.
    pub fn save_with_faults(
        &self,
        path: &Path,
        faults: Option<&crate::faults::FaultPlan>,
        save_index: u64,
    ) -> Result<()> {
        self.to_container().save_with_faults(path, faults, save_index)
    }

    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let ck = Checkpoint::load(path)?;
        Self::from_container(&ck)
            .with_context(|| format!("decoding training state from {}", path.display()))
    }

    /// A named counter's value, if present.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Timing-free fingerprint of the checkpointed training state:
    /// FNV-1a 64 over the v2 container image with the two
    /// non-state fields normalized away — the metrics digest hash
    /// (it covers CSV rows that carry the wall-clock `step_ms`
    /// column) and the `ckpts_written` counter (a preempted run
    /// writes extra suspension checkpoints its solo twin never
    /// does). Everything else — params, Adam moments, loader
    /// cursors, RNG streams, amax histories, decision stats, suite
    /// trajectory, metrics row count, pinned options, guard state —
    /// feeds the hash bit-for-bit, so two checkpoints fingerprint
    /// equal iff they would resume into bitwise-identical runs.
    /// This is what `tests/scheduler_equivalence.rs` compares.
    pub fn state_fingerprint(&self) -> u64 {
        let mut canon = self.clone();
        canon.metrics = MetricsState::Digest { rows: self.metrics.rows(), hash: 0 };
        canon.counters.retain(|(name, _)| name != "ckpts_written");
        let image = canon.to_container().to_bytes_v2();
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in image {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mor_ckpt_{tag}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let dir = tmp("test");
        let path = dir.join("step10.ckpt");
        let ck = Checkpoint::new(
            10,
            vec![
                ("a".into(), Tensor::normal(&[3, 4], 1.0, 1)),
                ("b.weight".into(), Tensor::uniform(&[7], 2.0, 2)),
            ],
        );
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.get("a").unwrap().shape(), &[3, 4]);
        assert!(back.get("zzz").is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v1_roundtrip_still_loads() {
        let dir = tmp("v1");
        let path = dir.join("legacy.ckpt");
        let ck = Checkpoint::new(
            3,
            vec![("w".into(), Tensor::normal(&[2, 5], 0.5, 9))],
        );
        ck.save_v1(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert!(back.sections.is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sections_roundtrip_with_order() {
        let mut ck = Checkpoint::new(1, vec![("p".into(), Tensor::zeros(&[2]))]);
        ck.push_section("zeta", vec![9, 9]);
        ck.push_section("alpha", vec![1, 2, 3]);
        let back = Checkpoint::from_bytes(&ck.to_bytes_v2()).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.section("alpha"), Some(&[1u8, 2, 3][..]));
        assert_eq!(back.section("nope"), None);
        // On-disk order is preserved exactly (byte-stable container).
        assert_eq!(back.sections[0].0, "zeta");
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn train_checkpoint_sections_roundtrip() {
        use crate::mor::stats::TensorKey;
        let mut stats = StatsCollector::new(7);
        stats.set_step(5);
        stats.record(TensorKey::new(0, 2, "weight", ""), 0.01, false, 0.0);
        stats.record(TensorKey::new(1, 0, "grad", "row"), 0.06, true, 0.5);
        let tc = TrainCheckpoint {
            step: 5,
            artifact: "train_mor_tensor_block".into(),
            config: "config1".into(),
            last_val: 2.5,
            param_names: vec!["w1".into(), "w2".into()],
            session: TrainState {
                step: 5,
                params: vec![Tensor::normal(&[2, 3], 1.0, 1), Tensor::normal(&[4], 1.0, 2)],
                opt_m: vec![Tensor::normal(&[2, 3], 0.1, 3), Tensor::zeros(&[4])],
                opt_v: vec![Tensor::normal(&[2, 3], 0.2, 4), Tensor::zeros(&[4])],
                amax_hist: vec![AmaxHistory::from_values(4, &[1.0, 2.0]); 3],
            },
            train_cursor: LoaderCursor {
                state: CorpusState { rng_state: 0xDEAD, context: (7, 9), pending: vec![1, 2] },
                batches: 5,
            },
            val_cursor: LoaderCursor {
                state: CorpusState { rng_state: 0xBEEF, context: (0, 0), pending: vec![] },
                batches: 2,
            },
            rng_streams: vec![
                (section::DATA_TRAIN.into(), 0xDEAD),
                (section::DATA_VAL.into(), 0xBEEF),
            ],
            stats,
            metrics: MetricsState::Embedded(vec![StepRecord {
                step: 4,
                lr: 3e-4,
                train_loss: 2.75,
                val_loss: f32::NAN,
                param_norm: 10.5,
                bf16_fallback_rate: 0.25,
                mean_relerr: 0.01,
                step_ms: 12.5,
            }]),
            suite_history: vec![(
                3,
                EvalScores { per_task: vec![("copy", 1.5, 40.0), ("cycle", 0.5, 80.0)] },
            )],
            counters: vec![("ckpts_written".into(), 1)],
            guard_state: None,
        };
        let back = TrainCheckpoint::from_container(&tc.to_container()).unwrap();
        assert_eq!(back.step, 5);
        assert_eq!(back.artifact, tc.artifact);
        assert_eq!(back.config, tc.config);
        assert_eq!(back.last_val.to_bits(), tc.last_val.to_bits());
        assert_eq!(back.param_names, tc.param_names);
        assert_eq!(back.session.params, tc.session.params);
        assert_eq!(back.session.opt_m, tc.session.opt_m);
        assert_eq!(back.session.opt_v, tc.session.opt_v);
        assert_eq!(back.session.amax_hist, tc.session.amax_hist);
        assert_eq!(back.train_cursor, tc.train_cursor);
        assert_eq!(back.val_cursor, tc.val_cursor);
        assert_eq!(back.rng_streams, tc.rng_streams);
        assert_eq!(back.stats.heatmap_csv(), tc.stats.heatmap_csv());
        let records = back.metrics.embedded().expect("embedded metrics survive");
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].train_loss.to_bits(), 2.75f32.to_bits());
        assert!(records[0].val_loss.is_nan(), "NaN bits must survive");
        assert_eq!(back.metrics.rows(), 1);
        assert_eq!(back.suite_history.len(), 1);
        assert_eq!(back.suite_history[0].1.per_task, tc.suite_history[0].1.per_task);
        assert_eq!(back.counter("ckpts_written"), Some(1));
        assert_eq!(back.counter("nope"), None);

        // The digest representation round-trips through the same
        // section, and cannot be confused with an embedded payload.
        let mut tc2 = tc.clone();
        tc2.metrics = MetricsState::Digest { rows: 123_456, hash: 0xDEAD_BEEF_F00D_CAFE };
        let back2 = TrainCheckpoint::from_container(&tc2.to_container()).unwrap();
        match back2.metrics {
            MetricsState::Digest { rows, hash } => {
                assert_eq!(rows, 123_456);
                assert_eq!(hash, 0xDEAD_BEEF_F00D_CAFE);
            }
            MetricsState::Embedded(_) => panic!("digest decoded as embedded"),
        }
        assert_eq!(back2.metrics.rows(), 123_456);
        assert!(back2.metrics.embedded().is_none());

        // Guard state rides an optional section and round-trips.
        let mut tc3 = tc.clone();
        tc3.guard_state = Some(vec![1, 2, 3, 4]);
        let back3 = TrainCheckpoint::from_container(&tc3.to_container()).unwrap();
        assert_eq!(back3.guard_state, Some(vec![1, 2, 3, 4]));
        assert_eq!(back.guard_state, None, "unguarded runs carry no guard section");

        // The timing-free fingerprint ignores exactly the two
        // wall-clock artifacts — the metrics content hash (step_ms
        // rides the hashed CSV rows) and the save counter — and is
        // sensitive to everything else.
        let fp = tc.state_fingerprint();
        assert_eq!(back.state_fingerprint(), fp, "round-trip preserves the fingerprint");
        let mut timing = tc.clone();
        timing.metrics = MetricsState::Digest { rows: 1, hash: 0x1234 };
        timing.counters = vec![("ckpts_written".into(), 99)];
        assert_eq!(timing.state_fingerprint(), fp, "timing artifacts must not feed it");
        let mut drifted = tc.clone();
        drifted.session.params[0].data_mut()[0] += 1.0;
        assert_ne!(drifted.state_fingerprint(), fp, "a param bit change must show");
        let mut more_rows = tc.clone();
        more_rows.metrics = MetricsState::Digest { rows: 2, hash: 0 };
        assert_ne!(more_rows.state_fingerprint(), fp, "the row count is state");
        let mut counted = tc.clone();
        counted.counters.push(("train_batches".into(), 7));
        assert_ne!(counted.state_fingerprint(), fp, "non-save counters are state");
        assert_ne!(tc3.state_fingerprint(), fp, "guard state is state");
    }

    #[test]
    fn crc32_known_answer() {
        // CRC-32/ISO-HDLC check value from the catalogue.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc_trailer_roundtrip_and_detection() {
        let mut ck = Checkpoint::new(4, vec![("p".into(), Tensor::normal(&[3, 3], 1.0, 5))]);
        ck.push_section("alpha", vec![1, 2, 3]);
        let bytes = ck.to_bytes_v2_crc();
        // The trailer-carrying image loads back identically.
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        // The plain image is a strict prefix (trailer is append-only).
        let plain = ck.to_bytes_v2();
        assert_eq!(&bytes[..plain.len()], &plain[..]);
        // A flipped payload byte is caught by the per-section CRC.
        let mut bad = bytes.clone();
        let idx = plain.len() - 2; // inside the last section payload
        bad[idx] ^= 0x01;
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
        // A flipped trailer byte is caught by the prefix CRC.
        let mut bad = bytes.clone();
        let last = bad.len() - 5; // inside the per-section CRC list
        bad[last] ^= 0x01;
        assert!(Checkpoint::from_bytes(&bad).is_err());
        // Truncation anywhere inside the trailer is caught.
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn trailerless_v2_still_loads_and_garbage_tail_is_rejected() {
        let ck = Checkpoint::new(2, vec![("p".into(), Tensor::zeros(&[2]))]);
        let plain = ck.to_bytes_v2();
        assert_eq!(Checkpoint::from_bytes(&plain).unwrap(), ck);
        // Arbitrary trailing bytes are still corrupt, not a trailer.
        let mut tail = plain.clone();
        tail.extend_from_slice(&[0xAA; 12]);
        let err = Checkpoint::from_bytes(&tail).unwrap_err();
        assert!(format!("{err:#}").contains("not a CRC trailer"), "{err:#}");
    }

    #[test]
    fn torn_save_truncates_in_place() {
        let dir = tmp("torn");
        let path = dir.join("t.step2.ckpt");
        let ck = Checkpoint::new(2, vec![("p".into(), Tensor::normal(&[4, 4], 1.0, 3))]);
        let spec = crate::faults::parse_faults(Some("torn-save@ckpt=1")).unwrap().unwrap();
        let plan = crate::faults::FaultPlan::new(spec, 1);
        ck.save_with_faults(&path, Some(&plan), 1).unwrap();
        let len = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(len, ck.to_bytes_v2_crc().len() / 2, "half the image");
        assert!(Checkpoint::load(&path).is_err(), "torn file must not parse");
        // The one-shot fired; the next save index writes normally.
        ck.save_with_faults(&path, Some(&plan), 2).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ring_scan_and_tmp_sweep() {
        let dir = tmp("ring");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = Checkpoint::new(0, vec![("p".into(), Tensor::zeros(&[2]))]);
        for step in [2u64, 6, 4] {
            ck.save(&dir.join(format!("run.step{step}.ckpt"))).unwrap();
        }
        std::fs::write(dir.join("other.step9.ckpt"), b"x").unwrap();
        std::fs::write(dir.join("run.step9.ckpt.tmp.123"), b"x").unwrap();
        std::fs::write(dir.join("run.stepXX.ckpt"), b"x").unwrap();
        let ring = scan_ring(&dir, "run");
        let steps: Vec<u64> = ring.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![6, 4, 2], "newest first, other artifacts excluded");
        assert_eq!(sweep_stale_tmp(&dir), 1);
        assert!(!dir.join("run.step9.ckpt.tmp.123").exists());
        assert_eq!(sweep_stale_tmp(&dir), 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn metrics_digest_payload_rejects_malformed() {
        // Unknown digest version.
        let mut buf = Vec::new();
        put_u32(&mut buf, METRICS_DIGEST_SENTINEL);
        put_u8(&mut buf, 9);
        put_u64(&mut buf, 1);
        put_u64(&mut buf, 2);
        let mut rd = Rd::new(&buf);
        assert!(read_metrics(&mut rd).is_err(), "unknown version must be rejected");
        // Truncated digest payload.
        let mut buf = Vec::new();
        put_u32(&mut buf, METRICS_DIGEST_SENTINEL);
        put_u8(&mut buf, METRICS_DIGEST_V1);
        put_u64(&mut buf, 1);
        let mut rd = Rd::new(&buf);
        assert!(read_metrics(&mut rd).is_err(), "truncated digest must be rejected");
        // An embedded count the payload cannot hold still fails fast.
        let mut buf = Vec::new();
        put_u32(&mut buf, 1_000_000);
        let mut rd = Rd::new(&buf);
        assert!(read_metrics(&mut rd).is_err(), "oversized count must be rejected");
    }

    #[test]
    fn params_only_file_is_not_a_train_checkpoint() {
        let ck = Checkpoint::new(1, vec![("w".into(), Tensor::zeros(&[2]))]);
        let err = TrainCheckpoint::from_container(&ck).unwrap_err();
        assert!(format!("{err:#}").contains("section"), "{err:#}");
    }
}
