//! Checkpointing: a minimal binary tensor container (no serde offline).
//!
//! Format (little-endian):
//! ```text
//! magic "MORCKPT1" | u64 step | u32 ntensors |
//!   per tensor: u32 name_len | name bytes | u32 ndims | u64 dims... |
//!               f32 data...
//! ```

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MORCKPT1";

/// A checkpoint: named tensors + the step they were saved at.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating checkpoint {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for d in t.shape() {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
            // Bulk-write the f32 payload.
            let data = t.data();
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening checkpoint {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a MoR checkpoint", path.display());
        }
        let mut u64b = [0u8; 8];
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u64b)?;
        let step = u64::from_le_bytes(u64b);
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b) as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("checkpoint tensor name not utf8")?;
            f.read_exact(&mut u32b)?;
            let ndims = u32::from_le_bytes(u32b) as usize;
            let mut shape = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                f.read_exact(&mut u64b)?;
                shape.push(u64::from_le_bytes(u64b) as usize);
            }
            let vol: usize = shape.iter().product();
            let mut data = vec![0f32; vol];
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, vol * 4)
            };
            f.read_exact(bytes)?;
            tensors.push((name, Tensor::from_vec(&shape, data)));
        }
        Ok(Checkpoint { step, tensors })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("mor_ckpt_test_{}", std::process::id()));
        let path = dir.join("step10.ckpt");
        let ck = Checkpoint {
            step: 10,
            tensors: vec![
                ("a".into(), Tensor::normal(&[3, 4], 1.0, 1)),
                ("b.weight".into(), Tensor::uniform(&[7], 2.0, 2)),
            ],
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.get("a").unwrap().shape(), &[3, 4]);
        assert!(back.get("zzz").is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("mor_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
