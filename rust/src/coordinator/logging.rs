//! CSV metrics logging — the raw series behind Figures 5/6/8/20 (loss +
//! parameter norm curves) and the eval-over-training figures (7/9/21).

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// One training-step record.
#[derive(Debug, Clone, Default)]
pub struct StepRecord {
    pub step: u64,
    pub lr: f32,
    pub train_loss: f32,
    /// NaN when not evaluated this step.
    pub val_loss: f32,
    pub param_norm: f32,
    /// Fraction of quantized-tensor slots that fell back to BF16.
    pub bf16_fallback_rate: f32,
    /// Mean E4M3 relative error across slots.
    pub mean_relerr: f32,
    pub step_ms: f32,
}

/// Append-only CSV logger, one file per run.
pub struct MetricsLogger {
    path: PathBuf,
    file: std::fs::File,
}

impl MetricsLogger {
    pub const HEADER: &'static str =
        "step,lr,train_loss,val_loss,param_norm,bf16_fallback_rate,mean_relerr,step_ms";

    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating metrics log {}", path.display()))?;
        writeln!(file, "{}", Self::HEADER)?;
        Ok(MetricsLogger { path: path.to_path_buf(), file })
    }

    pub fn log(&mut self, r: &StepRecord) -> Result<()> {
        let mut line = String::new();
        let _ = write!(
            line,
            "{},{:.6e},{:.6},{:.6},{:.6},{:.6},{:.6},{:.2}",
            r.step,
            r.lr,
            r.train_loss,
            r.val_loss,
            r.param_norm,
            r.bf16_fallback_rate,
            r.mean_relerr,
            r.step_ms
        );
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read a metrics CSV back into records (for the report harness).
    pub fn read(path: &Path) -> Result<Vec<StepRecord>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading metrics log {}", path.display()))?;
        let mut out = Vec::new();
        for line in text.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 8 {
                continue;
            }
            out.push(StepRecord {
                step: f[0].parse().unwrap_or(0),
                lr: f[1].parse().unwrap_or(0.0),
                train_loss: f[2].parse().unwrap_or(f32::NAN),
                val_loss: f[3].parse().unwrap_or(f32::NAN),
                param_norm: f[4].parse().unwrap_or(f32::NAN),
                bf16_fallback_rate: f[5].parse().unwrap_or(0.0),
                mean_relerr: f[6].parse().unwrap_or(0.0),
                step_ms: f[7].parse().unwrap_or(0.0),
            });
        }
        Ok(out)
    }
}

/// Render an ASCII line chart of one or more labelled series — the
/// terminal stand-in for the paper's loss/eval figures.
pub fn ascii_chart(
    title: &str,
    series: &[(String, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let mut out = format!("── {title} ──\n");
    let pts: Vec<(f64, f64)> =
        series.iter().flat_map(|(_, s)| s.iter().copied()).filter(|(_, y)| y.is_finite()).collect();
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (xmin, xmax) =
        pts.iter().fold((f64::MAX, f64::MIN), |(a, b), (x, _)| (a.min(*x), b.max(*x)));
    let (ymin, ymax) =
        pts.iter().fold((f64::MAX, f64::MIN), |(a, b), (_, y)| (a.min(*y), b.max(*y)));
    let yspan = (ymax - ymin).max(1e-12);
    let xspan = (xmax - xmin).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    const MARKS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];
    for (si, (_, s)) in series.iter().enumerate() {
        for (x, y) in s {
            if !y.is_finite() {
                continue;
            }
            let c = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let r = (((ymax - y) / yspan) * (height - 1) as f64).round() as usize;
            grid[r.min(height - 1)][c.min(width - 1)] = MARKS[si % MARKS.len()];
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:10.4} ")
        } else if r == height - 1 {
            format!("{ymin:10.4} ")
        } else {
            " ".repeat(11)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    let _ = writeln!(
        out,
        "{}x: {:.0} → {:.0}   legend: {}",
        " ".repeat(11),
        xmin,
        xmax,
        series
            .iter()
            .enumerate()
            .map(|(i, (n, _))| format!("{}={}", MARKS[i % MARKS.len()], n))
            .collect::<Vec<_>>()
            .join("  ")
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csv() {
        let dir = std::env::temp_dir().join(format!("mor_log_test_{}", std::process::id()));
        let path = dir.join("metrics.csv");
        let mut l = MetricsLogger::create(&path).unwrap();
        l.log(&StepRecord {
            step: 1,
            lr: 3e-4,
            train_loss: 2.5,
            val_loss: f32::NAN,
            param_norm: 10.0,
            bf16_fallback_rate: 0.05,
            mean_relerr: 0.02,
            step_ms: 12.0,
        })
        .unwrap();
        l.log(&StepRecord { step: 2, train_loss: 2.4, ..Default::default() }).unwrap();
        l.flush().unwrap();
        let recs = MetricsLogger::read(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].step, 1);
        assert!((recs[0].train_loss - 2.5).abs() < 1e-6);
        assert!(recs[0].val_loss.is_nan());
        assert_eq!(recs[1].step, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn chart_renders_all_series() {
        let s = vec![
            ("a".to_string(), vec![(0.0, 1.0), (10.0, 0.5)]),
            ("b".to_string(), vec![(0.0, 0.9), (10.0, 0.6)]),
        ];
        let c = ascii_chart("loss", &s, 40, 10);
        assert!(c.contains('*') && c.contains('+'));
        assert!(c.contains("legend"));
        let empty = ascii_chart("x", &[("e".into(), vec![])], 10, 5);
        assert!(empty.contains("no data"));
    }
}
