//! CSV metrics logging — the raw series behind Figures 5/6/8/20 (loss +
//! parameter norm curves) and the eval-over-training figures (7/9/21).

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// One training-step record.
#[derive(Debug, Clone, Default)]
pub struct StepRecord {
    pub step: u64,
    pub lr: f32,
    pub train_loss: f32,
    /// NaN when not evaluated this step.
    pub val_loss: f32,
    pub param_norm: f32,
    /// Fraction of quantized-tensor slots that fell back to BF16.
    pub bf16_fallback_rate: f32,
    /// Mean E4M3 relative error across slots.
    pub mean_relerr: f32,
    pub step_ms: f32,
}

impl StepRecord {
    /// The record's CSV data line (no trailing newline). Formatting is
    /// Rust's shortest-round-trip float rendering, so
    /// `line.parse()` → [`StepRecord`] reproduces every f32 **bit for
    /// bit** — the property the checkpoint metrics digest relies on to
    /// replay a resumed run's metrics prefix from the on-disk CSV
    /// instead of embedding the full history in every checkpoint.
    pub fn csv_line(&self) -> String {
        format!(
            "{},{:e},{},{},{},{},{},{}",
            self.step,
            self.lr,
            self.train_loss,
            self.val_loss,
            self.param_norm,
            self.bf16_fallback_rate,
            self.mean_relerr,
            self.step_ms
        )
    }

    /// Parse one CSV data line (the inverse of [`StepRecord::csv_line`]
    /// — bit-exact for lines that function produced). `None` for lines
    /// with the wrong field count or unparseable fields.
    pub fn parse_csv_line(line: &str) -> Option<StepRecord> {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 8 {
            return None;
        }
        Some(StepRecord {
            step: f[0].parse().ok()?,
            lr: f[1].parse().ok()?,
            train_loss: f[2].parse().ok()?,
            val_loss: f[3].parse().ok()?,
            param_norm: f[4].parse().ok()?,
            bf16_fallback_rate: f[5].parse().ok()?,
            mean_relerr: f[6].parse().ok()?,
            step_ms: f[7].parse().ok()?,
        })
    }
}

/// FNV-1a 64 over the given CSV data lines, each terminated by `\n` —
/// the checkpoint metrics digest. Computable identically from
/// in-memory records (`records.iter().map(|r| r.csv_line())`) and from
/// the on-disk file's lines, which is what lets a resume *verify* the
/// prefix it replays.
pub fn csv_lines_digest<I, S>(lines: I) -> u64
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for line in lines {
        for b in line.as_ref().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h ^= b'\n' as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Append-only CSV logger, one file per run.
pub struct MetricsLogger {
    path: PathBuf,
    file: std::fs::File,
}

impl MetricsLogger {
    pub const HEADER: &'static str =
        "step,lr,train_loss,val_loss,param_norm,bf16_fallback_rate,mean_relerr,step_ms";

    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating metrics log {}", path.display()))?;
        writeln!(file, "{}", Self::HEADER)?;
        Ok(MetricsLogger { path: path.to_path_buf(), file })
    }

    pub fn log(&mut self, r: &StepRecord) -> Result<()> {
        writeln!(self.file, "{}", r.csv_line())?;
        Ok(())
    }

    /// Append one already-formatted data line verbatim — the resume
    /// path replays the original run's CSV prefix byte for byte.
    pub fn log_raw(&mut self, line: &str) -> Result<()> {
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read a metrics CSV back into records (for the report harness).
    /// Tolerant: malformed lines are skipped (derived-artifact files).
    pub fn read(path: &Path) -> Result<Vec<StepRecord>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading metrics log {}", path.display()))?;
        Ok(text.lines().skip(1).filter_map(StepRecord::parse_csv_line).collect())
    }
}

/// Render an ASCII line chart of one or more labelled series — the
/// terminal stand-in for the paper's loss/eval figures.
pub fn ascii_chart(
    title: &str,
    series: &[(String, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let mut out = format!("── {title} ──\n");
    let pts: Vec<(f64, f64)> =
        series.iter().flat_map(|(_, s)| s.iter().copied()).filter(|(_, y)| y.is_finite()).collect();
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (xmin, xmax) =
        pts.iter().fold((f64::MAX, f64::MIN), |(a, b), (x, _)| (a.min(*x), b.max(*x)));
    let (ymin, ymax) =
        pts.iter().fold((f64::MAX, f64::MIN), |(a, b), (_, y)| (a.min(*y), b.max(*y)));
    let yspan = (ymax - ymin).max(1e-12);
    let xspan = (xmax - xmin).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    const MARKS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];
    for (si, (_, s)) in series.iter().enumerate() {
        for (x, y) in s {
            if !y.is_finite() {
                continue;
            }
            let c = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let r = (((ymax - y) / yspan) * (height - 1) as f64).round() as usize;
            grid[r.min(height - 1)][c.min(width - 1)] = MARKS[si % MARKS.len()];
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:10.4} ")
        } else if r == height - 1 {
            format!("{ymin:10.4} ")
        } else {
            " ".repeat(11)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    let _ = writeln!(
        out,
        "{}x: {:.0} → {:.0}   legend: {}",
        " ".repeat(11),
        xmin,
        xmax,
        series
            .iter()
            .enumerate()
            .map(|(i, (n, _))| format!("{}={}", MARKS[i % MARKS.len()], n))
            .collect::<Vec<_>>()
            .join("  ")
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csv() {
        let dir = std::env::temp_dir().join(format!("mor_log_test_{}", std::process::id()));
        let path = dir.join("metrics.csv");
        let mut l = MetricsLogger::create(&path).unwrap();
        l.log(&StepRecord {
            step: 1,
            lr: 3e-4,
            train_loss: 2.5,
            val_loss: f32::NAN,
            param_norm: 10.0,
            bf16_fallback_rate: 0.05,
            mean_relerr: 0.02,
            step_ms: 12.0,
        })
        .unwrap();
        l.log(&StepRecord { step: 2, train_loss: 2.4, ..Default::default() }).unwrap();
        l.flush().unwrap();
        let recs = MetricsLogger::read(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].step, 1);
        assert!((recs[0].train_loss - 2.5).abs() < 1e-6);
        assert!(recs[0].val_loss.is_nan());
        assert_eq!(recs[1].step, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn csv_line_roundtrips_bit_exact() {
        let r = StepRecord {
            step: 7,
            lr: 2.9999999e-4,
            train_loss: 2.772_588_7,
            val_loss: f32::NAN,
            param_norm: 10.510_203,
            bf16_fallback_rate: 1.0 / 3.0,
            mean_relerr: 0.012_345_679,
            step_ms: 12.34,
        };
        let line = r.csv_line();
        let back = StepRecord::parse_csv_line(&line).unwrap();
        assert_eq!(back.step, r.step);
        for (a, b) in [
            (back.lr, r.lr),
            (back.train_loss, r.train_loss),
            (back.val_loss, r.val_loss),
            (back.param_norm, r.param_norm),
            (back.bf16_fallback_rate, r.bf16_fallback_rate),
            (back.mean_relerr, r.mean_relerr),
            (back.step_ms, r.step_ms),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "field {a} vs {b} in {line:?}");
        }
        // Fuzz: random bit patterns (finite) survive the text round
        // trip exactly — the shortest-round-trip formatting guarantee.
        let mut s = 0x5DEE_CE66_D715_1234u64;
        for _ in 0..20_000 {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let v = f32::from_bits((s >> 32) as u32);
            if !v.is_finite() {
                continue;
            }
            let r = StepRecord { train_loss: v, ..Default::default() };
            let back = StepRecord::parse_csv_line(&r.csv_line()).unwrap();
            assert_eq!(back.train_loss.to_bits(), v.to_bits(), "{v:e}");
        }
        assert!(StepRecord::parse_csv_line("1,2,3").is_none());
        assert!(StepRecord::parse_csv_line("a,b,c,d,e,f,g,h").is_none());
    }

    #[test]
    fn digest_agrees_between_records_and_file_lines() {
        let recs = vec![
            StepRecord { step: 0, train_loss: 2.5, ..Default::default() },
            StepRecord { step: 1, train_loss: 2.25, step_ms: 7.5, ..Default::default() },
        ];
        let from_records = csv_lines_digest(recs.iter().map(|r| r.csv_line()));
        let text: String = recs.iter().map(|r| format!("{}\n", r.csv_line())).collect();
        let from_lines = csv_lines_digest(text.lines());
        assert_eq!(from_records, from_lines);
        // Any bit change shows up.
        let mut other = recs.clone();
        other[1].step_ms = 7.5000005;
        assert_ne!(from_records, csv_lines_digest(other.iter().map(|r| r.csv_line())));
        // Empty input has a stable non-zero basis.
        assert_eq!(csv_lines_digest(Vec::<String>::new()), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn chart_renders_all_series() {
        let s = vec![
            ("a".to_string(), vec![(0.0, 1.0), (10.0, 0.5)]),
            ("b".to_string(), vec![(0.0, 0.9), (10.0, 0.6)]),
        ];
        let c = ascii_chart("loss", &s, 40, 10);
        assert!(c.contains('*') && c.contains('+'));
        assert!(c.contains("legend"));
        let empty = ascii_chart("x", &[("e".into(), vec![])], 10, 5);
        assert!(empty.contains("no data"));
    }
}
