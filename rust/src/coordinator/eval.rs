//! Eval-suite driver: runs the OOD task suite (the downstream-benchmark
//! substitute) through the compiled masked-eval artifact.

use crate::data::tasks::{EvalSuite, EvalTask};
use crate::runtime::{EvalSession, ParamsRef};
use anyhow::Result;

/// Scores for one pass over the suite.
#[derive(Debug, Clone)]
pub struct EvalScores {
    /// (task name, masked loss, masked next-token accuracy %).
    pub per_task: Vec<(&'static str, f32, f32)>,
}

impl EvalScores {
    /// Mean accuracy over tasks — the "MMLU-like" scalar tracked over
    /// training in Figures 7/9/21.
    pub fn mean_accuracy(&self) -> f32 {
        if self.per_task.is_empty() {
            return 0.0;
        }
        self.per_task.iter().map(|(_, _, a)| a).sum::<f32>() / self.per_task.len() as f32
    }

    pub fn get(&self, name: &str) -> Option<(f32, f32)> {
        self.per_task.iter().find(|(n, _, _)| *n == name).map(|(_, l, a)| (*l, *a))
    }
}

/// Evaluate the full suite. Examples are packed into eval-session
/// batches; ragged tails are padded with zero masks (unscored).
/// Parameters arrive as a borrowed [`ParamsRef`]
/// (`TrainSession::params_ref`), so a host-backend suite pass runs on
/// the trainer's tensors directly — no Literal copies per batch.
pub fn eval_suite(
    session: &EvalSession,
    params: ParamsRef<'_>,
    suite: &EvalSuite,
) -> Result<EvalScores> {
    let mut per_task = Vec::new();
    for task in EvalTask::ALL {
        let examples = suite.examples(task);
        let (mut loss_sum, mut acc_sum, mut batches) = (0f64, 0f64, 0u32);
        for chunk in examples.chunks(session.batch) {
            let mut tokens = vec![0i32; session.batch * session.seq];
            let mut mask = vec![0f32; session.batch * session.seq];
            for (i, (t, m)) in chunk.iter().enumerate() {
                tokens[i * session.seq..(i + 1) * session.seq].copy_from_slice(t);
                mask[i * session.seq..(i + 1) * session.seq].copy_from_slice(m);
            }
            let (loss, acc) = session.eval_params(params, &tokens, &mask)?;
            loss_sum += loss as f64;
            acc_sum += acc as f64;
            batches += 1;
        }
        let n = batches.max(1) as f64;
        per_task.push((task.name(), (loss_sum / n) as f32, (acc_sum / n * 100.0) as f32));
    }
    Ok(EvalScores { per_task })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_aggregate() {
        let s = EvalScores {
            per_task: vec![("copy", 1.0, 80.0), ("cycle", 0.5, 90.0)],
        };
        assert_eq!(s.mean_accuracy(), 85.0);
        assert_eq!(s.get("copy"), Some((1.0, 80.0)));
        assert_eq!(s.get("nope"), None);
    }
}
