//! The numeric guard: per-step failure detection plus a bounded
//! recovery ladder for low-precision training.
//!
//! FP8 training lives near the overflow cliff; this module is the
//! subsystem that notices a run going numerically bad and recovers it
//! instead of letting NaNs silently corrupt every later step. Detection
//! inputs, all computed by the existing step path: the per-slot amax
//! values of [`StepOutputs`], the non-finite gradient count, the step
//! loss and the post-update parameter norm. The response ladder, in
//! escalation order:
//!
//! 1. **Skip-step** — the host trainer zeroes the update (Adam state
//!    untouched) whenever a gradient scan finds non-finite values.
//! 2. **BF16 quarantine** — every quantized `(class, layer)` pair is
//!    demoted to the BF16 fallback for `quarantine_steps` steps via
//!    [`QuarantinePolicy`], composing with the PR 7 policy layer. The
//!    demotion is global because a non-finite produced inside one
//!    quantized tensor propagates through the step before any per-slot
//!    amax can attribute it.
//! 3. **Rewind** — when strikes outlast the skip tolerance (or the
//!    parameters themselves go non-finite, which no skip can undo), the
//!    trainer rewinds to the newest loadable checkpoint. Retries are
//!    capped at `max_rewinds`; backoff is an escalating skip tolerance
//!    (`skip_limit + rewinds_so_far`) so each retry tolerates more
//!    turbulence before rewinding again.
//!
//! Guard state (strikes, rewind count, loss window, active quarantine
//! entries, the event log) is checkpointed in the `guard/state` section
//! so resume ≡ continuous holds bitwise for guarded runs too.

use crate::coordinator::checkpoint::{put_f32, put_str, put_u32, put_u64, put_u8, Rd};
use crate::mor::policy::{PolicyRef, QuarantinePolicy};
use crate::runtime::StepOutputs;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// The grammar every guard spec error repeats.
pub const SPEC_GRAMMAR: &str =
    "on, off, or comma-separated skip=N, quarantine=N, rewinds=N, spike=X";

/// Prefix of the trainer's bail message when the rewind budget runs out.
/// The fleet supervisor matches on this to tell guard exhaustion (demote
/// straight away — retrying the same precision would burn the budget
/// again) from transient crashes (retry with backoff first).
pub const REWIND_EXHAUSTED_MSG: &str = "numeric guard exhausted its rewind budget";

/// Guard configuration, parsed from `--guard` / `MOR_GUARD`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GuardConfig {
    /// Consecutive skipped steps tolerated before a rewind (the base of
    /// the escalating tolerance).
    pub skip_limit: u64,
    /// How many steps a quarantine demotion lasts.
    pub quarantine_steps: u64,
    /// Hard cap on rewind-to-checkpoint retries per run.
    pub max_rewinds: u64,
    /// Loss-spike monitor: a finite loss above `spike_factor ×` the
    /// trailing-window mean counts as an anomaly.
    pub spike_factor: f32,
}

impl Default for GuardConfig {
    fn default() -> Self {
        GuardConfig { skip_limit: 2, quarantine_steps: 8, max_rewinds: 3, spike_factor: 10.0 }
    }
}

impl GuardConfig {
    /// Canonical spelling; `parse_guard(describe())` round-trips.
    pub fn describe(&self) -> String {
        format!(
            "skip={},quarantine={},rewinds={},spike={}",
            self.skip_limit, self.quarantine_steps, self.max_rewinds, self.spike_factor
        )
    }

    /// The same guard with a deeper rewind budget, for a tenant the
    /// fleet supervisor demotes into BF16 quarantine: the fault that
    /// exhausted the old budget may refire on replay, so the demoted
    /// retry gets `2r + 2` rewinds to absorb it.
    pub fn widened(&self) -> GuardConfig {
        GuardConfig { max_rewinds: self.max_rewinds * 2 + 2, ..*self }
    }

    /// Configuration fingerprint for the `opt/guard` checkpoint pin
    /// (0 is reserved for "guard off").
    pub fn pin(&self) -> u64 {
        1 | (self.skip_limit & 0x3F) << 4
            | (self.quarantine_steps & 0xFFF) << 10
            | (self.max_rewinds & 0x3F) << 22
            | (self.spike_factor.to_bits() as u64) << 28
    }
}

/// Strictly parse a `--guard` / `MOR_GUARD` spec: `Ok(None)` when unset
/// or `off`, defaults for `on`, and `k=v` overrides onto the defaults
/// otherwise. Malformed specs are loud errors (caller prefixes the
/// flag/env name).
pub fn parse_guard(raw: Option<&str>) -> Result<Option<GuardConfig>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err(format!("is set but empty; use {SPEC_GRAMMAR}, or unset it"));
    }
    if trimmed == "off" {
        return Ok(None);
    }
    let mut cfg = GuardConfig::default();
    for part in trimmed.split(',') {
        let part = part.trim();
        if part == "on" {
            continue;
        }
        if part == "off" {
            return Err(format!("off cannot be combined with other settings, got {trimmed:?}"));
        }
        let Some((key, val)) = part.split_once('=') else {
            return Err(format!("setting {part:?} is not key=value; use {SPEC_GRAMMAR}"));
        };
        let (key, val) = (key.trim(), val.trim());
        let parse_u64 = |what: &str| -> Result<u64, String> {
            val.parse::<u64>()
                .map_err(|_| format!("{what} must be a non-negative integer, got {val:?}"))
        };
        match key {
            "skip" => cfg.skip_limit = parse_u64("skip")?,
            "quarantine" => {
                let n = parse_u64("quarantine")?;
                if n == 0 {
                    return Err("quarantine=0 would demote for zero steps".into());
                }
                cfg.quarantine_steps = n;
            }
            "rewinds" => cfg.max_rewinds = parse_u64("rewinds")?,
            "spike" => {
                let x: f32 = val
                    .parse()
                    .map_err(|_| format!("spike must be a number, got {val:?}"))?;
                if !x.is_finite() || x <= 1.0 {
                    return Err(format!("spike factor must be finite and > 1, got {val:?}"));
                }
                cfg.spike_factor = x;
            }
            other => return Err(format!("unknown setting {other:?}; use {SPEC_GRAMMAR}")),
        }
    }
    Ok(Some(cfg))
}

/// Resolve the `MOR_GUARD` env knob; panics loudly on a malformed
/// value, mirroring the other strict knobs.
pub fn auto() -> Option<GuardConfig> {
    match parse_guard(crate::util::env::var("MOR_GUARD").as_deref()) {
        Ok(opt) => opt,
        Err(msg) => panic!("MOR_GUARD {msg}"),
    }
}

/// What the guard did at a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardAction {
    SkipStep,
    Quarantine,
    LossSpike,
    Rewind,
}

impl GuardAction {
    pub fn name(self) -> &'static str {
        match self {
            GuardAction::SkipStep => "skip_step",
            GuardAction::Quarantine => "quarantine",
            GuardAction::LossSpike => "loss_spike",
            GuardAction::Rewind => "rewind",
        }
    }

    fn code(self) -> u8 {
        match self {
            GuardAction::SkipStep => 0,
            GuardAction::Quarantine => 1,
            GuardAction::LossSpike => 2,
            GuardAction::Rewind => 3,
        }
    }

    fn from_code(c: u8) -> Option<GuardAction> {
        Some(match c {
            0 => GuardAction::SkipStep,
            1 => GuardAction::Quarantine,
            2 => GuardAction::LossSpike,
            3 => GuardAction::Rewind,
            _ => return None,
        })
    }
}

/// One guard intervention, recorded for the run's `guard.csv`.
#[derive(Debug, Clone, PartialEq)]
pub struct GuardEvent {
    /// 0-based trainer step index the intervention happened at.
    pub step: u64,
    pub action: GuardAction,
    pub detail: String,
}

/// The per-step verdict [`NumericGuard::assess`] returns to the
/// trainer.
#[derive(Debug, Clone, PartialEq)]
pub enum GuardVerdict {
    /// Nothing wrong; the step stands.
    Healthy,
    /// An anomaly was absorbed by skip/quarantine; keep training.
    Intervened,
    /// Recovery requires rewinding to the last good checkpoint.
    Rewind { reason: String },
}

/// Trailing-loss window length for the spike monitor.
const LOSS_WINDOW: usize = 8;
/// Event-log cap inside the checkpointed guard state.
const MAX_SAVED_EVENTS: usize = 256;
const GUARD_STATE_V1: u8 = 1;

/// The guard itself: detection state plus the shared quarantine wrapper
/// it escalates through. Owned by `Trainer::run`; one per guarded run.
pub struct NumericGuard {
    cfg: GuardConfig,
    quarantine: Arc<QuarantinePolicy>,
    n_layers: usize,
    /// Consecutive anomalous steps (reset by any healthy step).
    strikes: u64,
    /// Rewinds performed so far this run.
    rewinds: u64,
    loss_window: VecDeque<f32>,
    events: Vec<GuardEvent>,
}

impl NumericGuard {
    pub fn new(cfg: GuardConfig, quarantine: Arc<QuarantinePolicy>, n_layers: usize) -> Self {
        NumericGuard {
            cfg,
            quarantine,
            n_layers,
            strikes: 0,
            rewinds: 0,
            loss_window: VecDeque::with_capacity(LOSS_WINDOW),
            events: Vec::new(),
        }
    }

    /// The quarantine wrapper as a [`PolicyRef`] for the session.
    pub fn policy(&self) -> PolicyRef {
        self.quarantine.clone()
    }

    pub fn config(&self) -> &GuardConfig {
        &self.cfg
    }

    pub fn events(&self) -> &[GuardEvent] {
        &self.events
    }

    pub fn rewinds(&self) -> u64 {
        self.rewinds
    }

    /// Count of events with the given action (test/telemetry helper).
    pub fn count(&self, action: GuardAction) -> u64 {
        self.events.iter().filter(|e| e.action == action).count() as u64
    }

    /// Demote every quantized `(class, layer)` pair until the anomaly's
    /// effects have flushed: attribution of an in-flight non-finite to
    /// one tensor is impossible post-hoc, so the demotion is global.
    fn quarantine_all(&mut self, step0: u64, why: &str) {
        // `step0` is the 0-based trainer index; the quarantine map
        // lives in the 1-based DecisionCtx domain where this step was
        // step0+1, so the demotion covers (step0+2 ..= step0+1+N).
        let until = step0 + 2 + self.cfg.quarantine_steps;
        for class_idx in 0..3 {
            for layer in 0..self.n_layers {
                self.quarantine.quarantine(class_idx, layer, until);
            }
        }
        self.events.push(GuardEvent {
            step: step0,
            action: GuardAction::Quarantine,
            detail: format!("all tensors -> bf16 until step {until} ({why})"),
        });
    }

    /// Judge one completed step. `step0` is the 0-based trainer index,
    /// `out` the step outputs, `param_norm` the post-update norm.
    pub fn assess(&mut self, step0: u64, out: &StepOutputs, param_norm: f32) -> GuardVerdict {
        // Non-finite parameters: the update already destroyed state no
        // skip or demotion can recover. Straight to rewind.
        if !param_norm.is_finite() {
            return GuardVerdict::Rewind { reason: "non-finite parameters".into() };
        }
        // Overflow monitor: a non-finite per-slot amax means some
        // quantized operand overflowed mid-step even if the loss came
        // out finite by accident.
        let overflow = out.amax.iter().filter(|a| !a.is_finite()).count() as u64;
        let skipped = out.skipped || out.nonfinite_grads > 0 || overflow > 0;
        if skipped || !out.loss.is_finite() {
            self.strikes += 1;
            self.events.push(GuardEvent {
                step: step0,
                action: GuardAction::SkipStep,
                detail: format!(
                    "loss {} with {} non-finite gradient value(s) and {} overflowed amax \
                     slot(s); strike {}",
                    out.loss, out.nonfinite_grads, overflow, self.strikes
                ),
            });
            self.quarantine_all(step0, "non-finite step");
            // Escalating tolerance: each rewind already performed buys
            // one more tolerated strike before the next one.
            if self.strikes > self.cfg.skip_limit + self.rewinds {
                return GuardVerdict::Rewind {
                    reason: format!("persistent non-finite steps ({} strikes)", self.strikes),
                };
            }
            return GuardVerdict::Intervened;
        }
        // Loss-spike monitor: only with a full window, so early noisy
        // steps can't trip it.
        if self.loss_window.len() == LOSS_WINDOW {
            let mean: f32 =
                self.loss_window.iter().sum::<f32>() / self.loss_window.len() as f32;
            if mean > 0.0 && out.loss > self.cfg.spike_factor * mean {
                self.events.push(GuardEvent {
                    step: step0,
                    action: GuardAction::LossSpike,
                    detail: format!("loss {} vs trailing mean {mean}", out.loss),
                });
                self.quarantine_all(step0, "loss spike");
                self.strikes = 0;
                return GuardVerdict::Intervened;
            }
        }
        self.strikes = 0;
        if self.loss_window.len() == LOSS_WINDOW {
            self.loss_window.pop_front();
        }
        self.loss_window.push_back(out.loss);
        GuardVerdict::Healthy
    }

    /// Consume one unit of rewind budget; `false` means the budget is
    /// exhausted and the run must fail. Also resets the strike counter
    /// (the restored trajectory starts clean).
    pub fn begin_rewind(&mut self, step0: u64, reason: &str) -> bool {
        if self.rewinds >= self.cfg.max_rewinds {
            return false;
        }
        self.rewinds += 1;
        self.strikes = 0;
        self.events.push(GuardEvent {
            step: step0,
            action: GuardAction::Rewind,
            detail: format!("{reason}; rewind {}/{}", self.rewinds, self.cfg.max_rewinds),
        });
        true
    }

    /// Serialize the guard's dynamic state for the `guard/state`
    /// checkpoint section.
    pub fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u8(&mut out, GUARD_STATE_V1);
        put_u64(&mut out, self.strikes);
        put_u64(&mut out, self.rewinds);
        put_u32(&mut out, self.loss_window.len() as u32);
        for v in &self.loss_window {
            put_f32(&mut out, *v);
        }
        let entries = self.quarantine.active_entries();
        put_u32(&mut out, entries.len() as u32);
        for (c, l, u) in entries {
            put_u32(&mut out, c as u32);
            put_u32(&mut out, l as u32);
            put_u64(&mut out, u);
        }
        let skip = self.events.len().saturating_sub(MAX_SAVED_EVENTS);
        let saved = &self.events[skip..];
        put_u32(&mut out, saved.len() as u32);
        for e in saved {
            put_u64(&mut out, e.step);
            put_u8(&mut out, e.action.code());
            put_str(&mut out, &e.detail);
        }
        out
    }

    /// Restore from a `guard/state` payload. `keep_rewinds` preserves
    /// the in-memory rewind count instead of the checkpointed one —
    /// required on the rewind path, where restoring the (lower) saved
    /// count would hand the guard an unbounded retry budget.
    pub fn import_state(&mut self, bytes: &[u8], keep_rewinds: bool) -> Result<()> {
        let mut rd = Rd::new(bytes);
        let version = rd.u8("guard state version")?;
        if version != GUARD_STATE_V1 {
            bail!("checkpoint corrupt: unknown guard state version {version}");
        }
        let strikes = rd.u64("guard strikes")?;
        let rewinds = rd.u64("guard rewinds")?;
        let nw = rd.u32("guard loss window length")? as usize;
        if nw > LOSS_WINDOW {
            bail!("checkpoint corrupt: guard loss window {nw} exceeds cap {LOSS_WINDOW}");
        }
        let mut window = VecDeque::with_capacity(LOSS_WINDOW);
        for _ in 0..nw {
            window.push_back(rd.f32("guard loss window value")?);
        }
        let ne = rd.u32("guard quarantine entry count")? as usize;
        if ne > rd.remaining() / 16 + 1 {
            bail!("checkpoint corrupt: guard quarantine count {ne} exceeds file capacity");
        }
        let mut entries = Vec::with_capacity(ne);
        for _ in 0..ne {
            let c = rd.u32("guard quarantine class")? as usize;
            let l = rd.u32("guard quarantine layer")? as usize;
            let u = rd.u64("guard quarantine until")?;
            entries.push((c, l, u));
        }
        let nev = rd.u32("guard event count")? as usize;
        if nev > MAX_SAVED_EVENTS {
            bail!("checkpoint corrupt: guard event count {nev} exceeds cap {MAX_SAVED_EVENTS}");
        }
        let mut events = Vec::with_capacity(nev);
        for i in 0..nev {
            let step = rd.u64(&format!("guard event {i} step"))?;
            let code = rd.u8(&format!("guard event {i} action"))?;
            let action = GuardAction::from_code(code).ok_or_else(|| {
                anyhow::anyhow!("checkpoint corrupt: unknown guard action code {code}")
            })?;
            let detail = rd.str(&format!("guard event {i} detail"))?;
            events.push(GuardEvent { step, action, detail });
        }
        rd.expect_done("guard state")?;
        self.strikes = strikes;
        if !keep_rewinds {
            self.rewinds = rewinds;
        }
        self.loss_window = window;
        self.quarantine.restore_entries(&entries);
        self.events = events;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mor::policy::MorThresholdPolicy;

    fn out(loss: f32, nonfinite: u64, skipped: bool) -> StepOutputs {
        StepOutputs {
            loss,
            relerr: vec![0.01],
            fallback: vec![0.0],
            amax: vec![1.0],
            nonfinite_grads: nonfinite,
            skipped,
        }
    }

    fn guard(cfg: GuardConfig) -> NumericGuard {
        NumericGuard::new(cfg, QuarantinePolicy::new(Arc::new(MorThresholdPolicy)), 2)
    }

    #[test]
    fn parse_matrix() {
        assert_eq!(parse_guard(None).unwrap(), None);
        assert_eq!(parse_guard(Some("off")).unwrap(), None);
        assert_eq!(parse_guard(Some("on")).unwrap(), Some(GuardConfig::default()));
        let custom = parse_guard(Some("skip=5,quarantine=3,rewinds=1,spike=4.5"))
            .unwrap()
            .unwrap();
        assert_eq!(
            custom,
            GuardConfig { skip_limit: 5, quarantine_steps: 3, max_rewinds: 1, spike_factor: 4.5 }
        );
        assert_eq!(parse_guard(Some(&custom.describe())).unwrap(), Some(custom));
        // Partial overrides keep the other defaults.
        let part = parse_guard(Some("on,rewinds=9")).unwrap().unwrap();
        assert_eq!(part.max_rewinds, 9);
        assert_eq!(part.skip_limit, GuardConfig::default().skip_limit);
        for bad in [
            "", " ", "banana", "skip", "skip=", "skip=-1", "skip=x", "quarantine=0",
            "spike=1", "spike=0.5", "spike=inf", "spike=abc", "off,skip=1", "frob=2",
        ] {
            assert!(parse_guard(Some(bad)).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn pin_is_configuration_sensitive_and_nonzero() {
        let a = GuardConfig::default().pin();
        let b = GuardConfig { skip_limit: 3, ..GuardConfig::default() }.pin();
        let c = GuardConfig { spike_factor: 5.0, ..GuardConfig::default() }.pin();
        assert_ne!(a, 0, "0 is reserved for guard-off");
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn ladder_skips_then_quarantines_then_rewinds() {
        let mut g = guard(GuardConfig { skip_limit: 2, ..GuardConfig::default() });
        // Healthy steps record nothing.
        assert_eq!(g.assess(0, &out(2.0, 0, false), 10.0), GuardVerdict::Healthy);
        assert!(g.events().is_empty());
        // First two anomalies: absorbed (skip + quarantine-all).
        assert_eq!(g.assess(1, &out(f32::NAN, 3, true), 10.0), GuardVerdict::Intervened);
        assert_eq!(g.assess(2, &out(f32::NAN, 3, true), 10.0), GuardVerdict::Intervened);
        assert_eq!(g.count(GuardAction::SkipStep), 2);
        assert_eq!(g.count(GuardAction::Quarantine), 2);
        assert!(!g.policy().accept_tensor(
            &crate::mor::policy::DecisionCtx { step: 4, ..Default::default() },
            crate::formats::ReprType::E4M3,
            0.0,
            1.0
        ));
        // Third consecutive strike exceeds the tolerance: rewind.
        match g.assess(3, &out(f32::NAN, 3, true), 10.0) {
            GuardVerdict::Rewind { reason } => assert!(reason.contains("persistent")),
            v => panic!("expected rewind, got {v:?}"),
        }
        // A healthy step resets the strikes.
        let mut g = guard(GuardConfig { skip_limit: 1, ..GuardConfig::default() });
        assert_eq!(g.assess(0, &out(f32::INFINITY, 1, true), 10.0), GuardVerdict::Intervened);
        assert_eq!(g.assess(1, &out(2.0, 0, false), 10.0), GuardVerdict::Healthy);
        assert_eq!(g.assess(2, &out(f32::INFINITY, 1, true), 10.0), GuardVerdict::Intervened);
    }

    #[test]
    fn nonfinite_params_rewind_immediately() {
        let mut g = guard(GuardConfig::default());
        match g.assess(5, &out(2.0, 0, false), f32::NAN) {
            GuardVerdict::Rewind { reason } => assert!(reason.contains("parameters")),
            v => panic!("expected rewind, got {v:?}"),
        }
        assert!(g.events().is_empty(), "the rewind event is recorded by begin_rewind");
    }

    #[test]
    fn loss_spike_trips_only_with_a_full_window() {
        let mut g = guard(GuardConfig { spike_factor: 3.0, ..GuardConfig::default() });
        // Window not yet full: a huge loss is still "healthy".
        assert_eq!(g.assess(0, &out(100.0, 0, false), 1.0), GuardVerdict::Healthy);
        for s in 1..=8 {
            assert_eq!(g.assess(s, &out(2.0, 0, false), 1.0), GuardVerdict::Healthy);
        }
        // Full window of ~2.0; 2.0*3 < 100 → spike.
        assert_eq!(g.assess(9, &out(100.0, 0, false), 1.0), GuardVerdict::Intervened);
        assert_eq!(g.count(GuardAction::LossSpike), 1);
        // The spiking loss is not admitted into the window.
        assert_eq!(g.assess(10, &out(2.1, 0, false), 1.0), GuardVerdict::Healthy);
    }

    #[test]
    fn rewind_budget_is_capped_and_escalates_tolerance() {
        let mut g = guard(GuardConfig { max_rewinds: 2, skip_limit: 0, ..GuardConfig::default() });
        assert!(g.begin_rewind(3, "test"));
        assert!(g.begin_rewind(4, "test"));
        assert!(!g.begin_rewind(5, "test"), "budget of 2 exhausted");
        assert_eq!(g.rewinds(), 2);
        // After 2 rewinds the tolerance is skip_limit + 2: two strikes
        // absorbed, the third rewinds.
        assert_eq!(g.assess(6, &out(f32::NAN, 1, true), 1.0), GuardVerdict::Intervened);
        assert_eq!(g.assess(7, &out(f32::NAN, 1, true), 1.0), GuardVerdict::Intervened);
        assert!(matches!(
            g.assess(8, &out(f32::NAN, 1, true), 1.0),
            GuardVerdict::Rewind { .. }
        ));
    }

    #[test]
    fn state_roundtrips_and_keep_rewinds_guards_the_budget() {
        let mut g = guard(GuardConfig::default());
        g.assess(0, &out(2.0, 0, false), 1.0);
        g.assess(1, &out(f32::NAN, 2, true), 1.0);
        g.begin_rewind(1, "test");
        let state = g.export_state();

        let mut back = guard(GuardConfig::default());
        back.import_state(&state, false).unwrap();
        assert_eq!(back.rewinds(), 1);
        assert_eq!(back.events(), g.events());
        assert_eq!(back.export_state(), state, "round-trip is bytewise stable");
        assert_eq!(
            back.quarantine.active_entries(),
            g.quarantine.active_entries(),
            "quarantine entries restored"
        );

        // On the rewind path the in-memory count wins.
        let mut live = guard(GuardConfig::default());
        live.rewinds = 3;
        live.import_state(&state, true).unwrap();
        assert_eq!(live.rewinds(), 3);

        // Malformed payloads are loud.
        assert!(back.import_state(&[], false).is_err());
        assert!(back.import_state(&[9, 0, 0], false).is_err());
        let mut trailing = state.clone();
        trailing.push(0);
        assert!(back.import_state(&trailing, false).is_err());
    }
}
