//! The fleet scheduler: fair-share multiplexing of N concurrent
//! training runs over one shared [`Parallelism`] pool, with
//! checkpoint-backed preemption and per-tenant failure containment.
//!
//! ## Design
//!
//! A **tenant** is one training run (artifact + config + options +
//! fair-share weight). The scheduler advances tenants in **rounds**:
//! each round it picks up to `max_runs` runnable tenants by [stride
//! scheduling](https://en.wikipedia.org/wiki/Stride_scheduling) — every
//! tenant carries a *pass* value that grows by `STRIDE_ONE / weight`
//! per slice it receives, and the tenants with the smallest pass run
//! next, so over time each tenant's slice share converges to
//! `weight / Σ weights` and no tenant starves. Ties break by the same
//! largest-first rule [`par::weighted_order`] gives sweep items
//! (descending weight, then index), and the selected tenants are
//! submitted to the shared pool through [`par::par_map_weighted`] —
//! run-granularity items on exactly the machinery that already
//! schedules tensor-granularity work, nested chunk-parallelism and
//! all (the pool's help-while-waiting protocol keeps tenant slices
//! that are themselves chunk-parallel deadlock-free).
//!
//! ## Preemption contract
//!
//! A slice runs its tenant for `quantum` steps via
//! `TrainerOptions::stop_after`, which forces a `MORCKPT2` checkpoint
//! at the suspension point; the session is then dropped — eviction
//! costs zero resident state — and the next slice `auto_resume`s from
//! the tenant's own checkpoint ring. The PR 4 resume ≡ continuous
//! contract makes this *bitwise* invisible: an interleaved tenant's
//! trajectory, metrics rows (minus the wall-clock `step_ms` column),
//! decision fractions and final checkpointed state are identical to
//! the same run executed alone, at any thread count. That is not a
//! design hope — `tests/scheduler_equivalence.rs` proves it.
//!
//! ## Containment
//!
//! Each slice runs under `catch_unwind`, so a tenant that panics (e.g.
//! an injected worker panic with no guard to absorb it) or errors
//! (rewind budget exhausted, corrupt state) becomes a *failed tenant*,
//! not a dead fleet: its error is reported, its neighbors keep their
//! slices, and — because guarded recovery (skip → BF16 quarantine →
//! rewind, PR 8) runs *inside* the slice — a tenant with a guard
//! usually never surfaces here at all. Guard state (strikes,
//! quarantines, the rewind budget) lives in the `guard/state`
//! checkpoint section, so it survives eviction like everything else.

use super::guard::REWIND_EXHAUSTED_MSG;
use super::supervisor::{
    self, FailureVerdict, FleetManifest, Health, ManifestTenant, Supervisor, SupervisorOptions,
};
use super::trainer::{TrainOutcome, Trainer, TrainerOptions};
use crate::model::config::{ModelConfig, TrainConfig};
use crate::mor::policy;
use crate::runtime::Runtime;
use crate::util::par::{self, Parallelism};
use anyhow::{bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// One training run under the scheduler.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Unique tenant name (schedule log, reports).
    pub id: String,
    pub model: ModelConfig,
    pub config: TrainConfig,
    /// The run's own options: artifact, steps, out_dir, policy, guard,
    /// faults, checkpoint cadence… The scheduler owns only the
    /// preemption fields: `resume`/`auto_resume`/`stop_after` are
    /// overwritten per slice.
    pub opts: TrainerOptions,
    /// Fair-share weight (≥ 1): slice share converges to
    /// `weight / Σ weights`.
    pub weight: usize,
}

impl Tenant {
    pub fn new(id: &str, model: ModelConfig, config: TrainConfig, opts: TrainerOptions) -> Self {
        Tenant { id: id.to_string(), model, config, opts, weight: 1 }
    }

    pub fn with_weight(mut self, weight: usize) -> Self {
        self.weight = weight;
        self
    }
}

/// Fleet-level knobs.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Maximum tenants resident (advancing) in one round — the
    /// oversubscription cap (`--max-runs` / `MOR_MAX_RUNS`).
    pub max_runs: usize,
    /// Steps per slice; `0` runs every tenant to completion in its
    /// first slice (no preemption — the policy-sweep shape).
    pub quantum: u64,
    /// The shared pool every slice is submitted to (and the default
    /// engine handle for tenants that don't carry their own).
    pub parallelism: Parallelism,
    /// Silence the per-round narration.
    pub quiet: bool,
    /// Adaptive quanta: when more tenants are runnable than `max_runs`
    /// worker slots, carve the quantum into `ceil(runnable/max_runs)`
    /// shares (floor 1) so every tenant cycles through sooner. Pure
    /// scheduling — per-tenant trajectories are bitwise-unchanged
    /// (`tests/scheduler_equivalence.rs` pins adaptive ≡ fixed).
    pub adaptive: bool,
    /// Fleet supervision (retry/backoff, the degradation ladder, the
    /// stall watchdog, the crash-safe manifest); `None` keeps the
    /// historical binary-failure behavior bit-for-bit.
    pub supervisor: Option<SupervisorOptions>,
}

impl FleetOptions {
    pub fn new(parallelism: Parallelism) -> Self {
        let max_runs = parallelism.threads.max(1);
        FleetOptions {
            max_runs,
            quantum: 0,
            parallelism,
            quiet: true,
            adaptive: false,
            supervisor: None,
        }
    }
}

/// One schedule-log entry: tenant `tenant` advanced from `from_step`
/// to `to_step` completed steps during round `round`. The log is
/// deterministic (selection is a pure function of weights and history)
/// and is what the starvation test audits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slice {
    pub round: u64,
    pub tenant: usize,
    pub from_step: u64,
    pub to_step: u64,
}

/// Per-tenant result.
#[derive(Debug)]
pub struct TenantReport {
    pub id: String,
    /// The final slice's outcome — which covers the *whole* run
    /// (records replay the full prefix), so for a completed tenant
    /// this is exactly what a solo `Trainer::run` would have returned.
    /// `None` only for a tenant that failed before any slice finished.
    pub outcome: Option<TrainOutcome>,
    /// The containment verdict: `Some(error)` for a failed tenant.
    pub error: Option<String>,
    /// Slices this tenant received.
    pub slices: u64,
    /// Fair-share weight (echoed for the summary table).
    pub weight: usize,
    /// Terminal supervisor health (unsupervised fleets report Healthy,
    /// or Dead for a failed tenant).
    pub health: Health,
    /// Total failed retries across all demotion rungs.
    pub retries: u32,
    /// Demotion rung reached (0 native, 1 BF16 quarantine, 2 scalar).
    pub demotions: u8,
}

impl TenantReport {
    pub fn completed(&self) -> bool {
        self.error.is_none()
    }
}

/// The fleet's outcome: per-tenant reports (tenant order preserved)
/// plus the full schedule log.
#[derive(Debug)]
pub struct FleetOutcome {
    pub tenants: Vec<TenantReport>,
    pub schedule: Vec<Slice>,
    pub rounds: u64,
    /// The supervisor's `halt_after` testing hook stopped the loop
    /// early (a simulated supervisor crash): reports may be partial.
    pub halted: bool,
}

impl FleetOutcome {
    /// The report for a tenant by id.
    pub fn tenant(&self, id: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.id == id)
    }

    /// Longest run of consecutive rounds (while the tenant was still
    /// runnable) in which tenant `i` received no slice — the quantity
    /// the fair-share bound constrains.
    pub fn max_wait_rounds(&self, i: usize) -> u64 {
        let mut scheduled: Vec<u64> =
            self.schedule.iter().filter(|s| s.tenant == i).map(|s| s.round).collect();
        scheduled.sort_unstable();
        let mut max_gap = 0u64;
        let mut prev: Option<u64> = None;
        for r in scheduled {
            if let Some(p) = prev {
                max_gap = max_gap.max(r - p - 1);
            } else {
                max_gap = max_gap.max(r); // rounds waited before the first slice
            }
            prev = Some(r);
        }
        max_gap
    }

    /// One aligned cross-tenant summary table (what `repro fleet`
    /// prints): final losses, fp8 share, guard interventions, retries
    /// and the terminal health state per tenant.
    pub fn summary_table(&self) -> String {
        let idw = self.tenants.iter().map(|t| t.id.len()).max().unwrap_or(0).max(6);
        let mut out = format!(
            "{:<idw$}  {:>2}  {:>6}  {:>7}  {:>6}  {:<11}  {:>9}  {:>9}  {:>6}  {:>5}  status\n",
            "tenant", "wt", "slices", "retries", "demote", "health", "train", "val", "fp8%",
            "guard",
        );
        for t in &self.tenants {
            let (train, val, fp8, guard) = match &t.outcome {
                Some(o) => (
                    format!("{:.4}", o.final_train_loss),
                    format!("{:.4}", o.final_val_loss),
                    format!("{:.1}", 100.0 - o.stats.overall_fallback_pct()),
                    o.guard_events.len().to_string(),
                ),
                None => ("-".into(), "-".into(), "-".into(), "-".into()),
            };
            let status = match &t.error {
                Some(e) => format!("failed: {}", clip(e, 60)),
                None => "done".to_string(),
            };
            out.push_str(&format!(
                "{:<idw$}  {:>2}  {:>6}  {:>7}  {:>6}  {:<11}  {:>9}  {:>9}  {:>6}  {:>5}  {status}\n",
                t.id,
                t.weight,
                t.slices,
                t.retries,
                t.demotions,
                t.health.name(),
                train,
                val,
                fp8,
                guard,
            ));
        }
        out
    }

    /// The machine-readable twin of [`FleetOutcome::summary_table`]
    /// (written as `fleet_summary.csv` by `repro fleet`). Floats use
    /// shortest-round-trip formatting so downstream diffs are exact.
    pub fn summary_csv(&self) -> String {
        let mut out = String::from(
            "tenant,weight,slices,retries,demotions,health,train_loss,val_loss,fp8_pct,\
             guard_events,status\n",
        );
        for t in &self.tenants {
            let (train, val, fp8, guard) = match &t.outcome {
                Some(o) => (
                    format!("{}", o.final_train_loss),
                    format!("{}", o.final_val_loss),
                    format!("{}", 100.0 - o.stats.overall_fallback_pct()),
                    o.guard_events.len().to_string(),
                ),
                None => Default::default(),
            };
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{}\n",
                t.id,
                t.weight,
                t.slices,
                t.retries,
                t.demotions,
                t.health.name(),
                train,
                val,
                fp8,
                guard,
                if t.error.is_some() { "failed" } else { "done" },
            ));
        }
        out
    }
}

/// Clip a diagnostic string for the table's status column.
fn clip(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let head: String = s.chars().take(n).collect();
        format!("{head}...")
    }
}

/// Pass-value unit: one slice at weight 1 advances pass by this much,
/// a weight-w tenant by `STRIDE_ONE / w`. Large enough that integer
/// division keeps distinct strides for any sane weight.
const STRIDE_ONE: u128 = 1 << 40;

/// Consecutive no-progress slices tolerated before a tenant is failed
/// (a livelock backstop — e.g. a fault plan that tears every save a
/// fresh start ever reaches could otherwise loop forever).
const MAX_STALLS: u32 = 3;

#[derive(Debug, Clone, PartialEq)]
enum Status {
    Runnable,
    Done,
    Failed(String),
}

/// Run every tenant to completion (or containment), multiplexed over
/// `opts.parallelism` — see the module docs for the scheduling,
/// preemption and containment contracts.
pub fn run_fleet(tenants: &[Tenant], opts: &FleetOptions) -> Result<FleetOutcome> {
    if tenants.is_empty() {
        bail!("fleet has no tenants");
    }
    if opts.max_runs == 0 {
        bail!("max_runs must be >= 1");
    }
    for (i, t) in tenants.iter().enumerate() {
        if t.weight == 0 {
            bail!("tenant {:?} has weight 0; weights must be >= 1", t.id);
        }
        if t.opts.resume.is_some() {
            bail!("tenant {:?} sets resume; the scheduler owns resumption", t.id);
        }
        if t.opts.repin || t.opts.fresh_guard {
            bail!(
                "tenant {:?} sets repin/fresh_guard; those are the supervisor's demotion \
                 mechanics, not tenant configuration",
                t.id
            );
        }
        for u in &tenants[..i] {
            if u.id == t.id {
                bail!("duplicate tenant id {:?}", t.id);
            }
            // Metrics/stats files are keyed by (artifact, config) and
            // the checkpoint ring by artifact alone, so colliding runs
            // would corrupt each other's state on disk.
            if u.opts.out_dir == t.opts.out_dir && u.opts.artifact == t.opts.artifact {
                let slicing = opts.quantum > 0
                    || t.opts.ckpt_every > 0
                    || u.opts.ckpt_every > 0;
                if slicing || u.config.name == t.config.name {
                    bail!(
                        "tenants {:?} and {:?} share out_dir {} and artifact {:?}; \
                         their on-disk files would collide",
                        u.id,
                        t.id,
                        t.opts.out_dir.display(),
                        t.opts.artifact
                    );
                }
            }
        }
    }

    let n = tenants.len();
    let mut sup: Option<Supervisor> =
        opts.supervisor.clone().map(|so| Supervisor::new(so, n));
    let mut status: Vec<Status> = vec![Status::Runnable; n];
    let mut completed: Vec<u64> = vec![0; n];
    let mut pass: Vec<u128> = vec![0; n];
    let mut stalls: Vec<u32> = vec![0; n];
    let mut slices: Vec<u64> = vec![0; n];
    let mut outcomes: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
    let mut schedule: Vec<Slice> = Vec::new();
    let mut round: u64 = 0;
    let mut halted = false;

    // Crash recovery: restore the scheduler/supervisor ledger from the
    // fleet manifest. Tenant *state* lives in each tenant's checkpoint
    // ring (and resumes regardless); the manifest carries exactly what
    // the rings cannot — progress counters, stride passes, health,
    // budgets, the schedule log — so the resumed fleet continues the
    // interleaving bitwise. A corrupt/torn manifest fails its CRC and
    // we fall back to a fresh ledger rather than a dead fleet.
    if let Some(s) = &mut sup {
        if s.opts.auto_resume {
            if let Some(path) = s.opts.manifest.clone() {
                if path.exists() {
                    match FleetManifest::load(&path) {
                        Ok(m) => {
                            restore_manifest(
                                &m,
                                tenants,
                                opts,
                                s,
                                &mut status,
                                &mut completed,
                                &mut slices,
                                &mut pass,
                                &mut schedule,
                                &mut round,
                            )?;
                            if !opts.quiet {
                                println!(
                                    "[fleet] resuming from manifest {} at round {round}",
                                    path.display()
                                );
                            }
                        }
                        Err(e) => {
                            if !opts.quiet {
                                println!(
                                    "[fleet] manifest {} unusable ({e:#}); starting a fresh \
                                     ledger (tenant rings still resume)",
                                    path.display()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    while status.iter().any(|s| *s == Status::Runnable) {
        // The supervisor's simulated-crash hook: stop cold before this
        // round. Every completed round's manifest is already on disk.
        if let Some(s) = &sup {
            if s.opts.halt_after.is_some_and(|h| round >= h) {
                halted = true;
                break;
            }
        }
        // Stride selection: smallest pass first, ties by the
        // largest-first weighted order (descending weight, then
        // index) — the same total order `par::weighted_order` gives
        // the dispatch below. Supervision only *removes* tenants from
        // the candidate set (Dead, or backing off), so a fault-free
        // supervised fleet selects identically to an unsupervised one.
        let mut resident: Vec<usize> = (0..n)
            .filter(|&i| {
                status[i] == Status::Runnable
                    && sup.as_ref().map_or(true, |s| s.eligible(i, round))
            })
            .collect();
        let eligible_n = resident.len();
        if eligible_n == 0 {
            // Everyone runnable is backing off: the round ticks by
            // empty (backoff is measured in rounds, so empty rounds
            // ARE the backoff — deterministic at any thread count).
            round += 1;
            save_fleet_manifest(
                &sup, opts, tenants, &status, &completed, &slices, &pass, &schedule, round,
            );
            continue;
        }
        resident.sort_by_key(|&i| (pass[i], std::cmp::Reverse(tenants[i].weight), i));
        resident.truncate(opts.max_runs);
        let quantum = effective_quantum(opts, eligible_n);

        // Per-slice supervisor context, collected before the parallel
        // dispatch (the ledger is not shared with the pool): demotion
        // rung and the one-shot guard-refresh marker.
        let rungs: Vec<u8> = resident
            .iter()
            .map(|&i| sup.as_ref().map_or(0, |s| s.tenant(i).demotions))
            .collect();
        let fresh: Vec<bool> = resident
            .iter()
            .map(|&i| sup.as_mut().map_or(false, |s| s.take_refresh_guard(i)))
            .collect();
        if let Some(s) = &mut sup {
            for &i in &resident {
                s.on_release(i);
            }
        }

        let weights: Vec<usize> = resident.iter().map(|&i| tenants[i].weight).collect();
        let before: Vec<u64> = resident.iter().map(|&i| completed[i]).collect();
        let results: Vec<Result<TrainOutcome, String>> =
            par::par_map_weighted(&opts.parallelism, &weights, |k| {
                advance(&tenants[resident[k]], before[k], opts, quantum, rungs[k], fresh[k])
            });

        for (k, res) in results.into_iter().enumerate() {
            let i = resident[k];
            pass[i] += STRIDE_ONE / tenants[i].weight as u128;
            slices[i] += 1;
            match res {
                Err(e) => match &mut sup {
                    None => {
                        if !opts.quiet {
                            println!("[fleet] tenant {} FAILED: {e}", tenants[i].id);
                        }
                        status[i] = Status::Failed(e);
                    }
                    Some(s) => {
                        // Guard exhaustion skips the retry branch of
                        // the ladder: that tenant already burned a full
                        // rewind budget at this precision.
                        let guard_exhausted = e.contains(REWIND_EXHAUSTED_MSG);
                        apply_failure_verdict(
                            s,
                            i,
                            round,
                            &tenants[i].id,
                            e,
                            guard_exhausted,
                            opts.quiet,
                            &mut status,
                        );
                    }
                },
                Ok(out) => {
                    let now = out.records.len() as u64;
                    schedule.push(Slice {
                        round,
                        tenant: i,
                        from_step: completed[i],
                        to_step: now,
                    });
                    if now <= completed[i] {
                        match &mut sup {
                            None => {
                                stalls[i] += 1;
                                if stalls[i] >= MAX_STALLS {
                                    status[i] = Status::Failed(format!(
                                        "no progress in {MAX_STALLS} consecutive slices \
                                         (stuck at step {now})"
                                    ));
                                }
                            }
                            Some(s) => {
                                // The stall watchdog: tolerated until
                                // `stall_after` consecutive no-progress
                                // slices, then the ladder takes over.
                                if let Some(msg) = s.on_no_progress(i, now) {
                                    apply_failure_verdict(
                                        s,
                                        i,
                                        round,
                                        &tenants[i].id,
                                        msg,
                                        false,
                                        opts.quiet,
                                        &mut status,
                                    );
                                }
                            }
                        }
                    } else {
                        stalls[i] = 0;
                        if let Some(s) = &mut sup {
                            s.on_progress(i);
                        }
                    }
                    completed[i] = now;
                    let done = now >= tenants[i].opts.steps;
                    if done {
                        status[i] = Status::Done;
                    }
                    if !opts.quiet {
                        println!(
                            "[fleet] round {round}: {} -> step {now}/{}{}",
                            tenants[i].id,
                            tenants[i].opts.steps,
                            if done { " (done)" } else { "" }
                        );
                    }
                    outcomes[i] = Some(out);
                }
            }
        }
        round += 1;
        save_fleet_manifest(
            &sup, opts, tenants, &status, &completed, &slices, &pass, &schedule, round,
        );
    }

    // A tenant that completed before a supervisor crash has no slice in
    // this process to carry its outcome: replay it from its ring (zero
    // steps execute — the trainer's finished-replay contract — so the
    // reconstructed outcome is the continuous one, bitwise).
    if !halted {
        for i in 0..n {
            if status[i] == Status::Done && outcomes[i].is_none() {
                let rung = sup.as_ref().map_or(0, |s| s.tenant(i).demotions);
                match advance(&tenants[i], completed[i], opts, 0, rung, false) {
                    Ok(out) => outcomes[i] = Some(out),
                    Err(e) => {
                        status[i] =
                            Status::Failed(format!("replaying finished tenant: {e}"));
                    }
                }
            }
        }
    }

    let reports = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let health = match (&sup, &status[i]) {
                (Some(s), _) => s.tenant(i).health,
                (None, Status::Failed(_)) => Health::Dead,
                (None, _) => Health::Healthy,
            };
            TenantReport {
                id: t.id.clone(),
                outcome: outcomes[i].take(),
                error: match &status[i] {
                    Status::Failed(e) => Some(e.clone()),
                    _ => None,
                },
                slices: slices[i],
                weight: t.weight,
                health,
                retries: sup.as_ref().map_or(0, |s| s.tenant(i).retries_total),
                demotions: sup.as_ref().map_or(0, |s| s.tenant(i).demotions),
            }
        })
        .collect();
    Ok(FleetOutcome { tenants: reports, schedule, rounds: round, halted })
}

/// Adaptive quanta: with more runnable tenants than worker slots, carve
/// the configured quantum into `ceil(runnable/max_runs)` shares
/// (floor 1). Scheduling only — slice boundaries move, trajectories
/// don't.
fn effective_quantum(opts: &FleetOptions, runnable: usize) -> u64 {
    if !opts.adaptive || opts.quantum == 0 || runnable <= opts.max_runs {
        return opts.quantum;
    }
    (opts.quantum / runnable.div_ceil(opts.max_runs) as u64).max(1)
}

/// Route one failed slice through the supervisor's ladder and narrate
/// the verdict. Only a `Dead` verdict terminally fails the tenant.
#[allow(clippy::too_many_arguments)]
fn apply_failure_verdict(
    s: &mut Supervisor,
    i: usize,
    round: u64,
    id: &str,
    error: String,
    guard_exhausted: bool,
    quiet: bool,
    status: &mut [Status],
) {
    match s.on_failure(i, round, guard_exhausted) {
        FailureVerdict::Retry { release_round } => {
            if !quiet {
                println!(
                    "[fleet] tenant {id} failed (retry {}/{} at rung {}, runnable again in \
                     round {release_round}): {error}",
                    s.tenant(i).retries_used,
                    s.opts.retries,
                    s.tenant(i).demotions
                );
            }
        }
        FailureVerdict::Demote { rung } => {
            if !quiet {
                println!(
                    "[fleet] tenant {id} demoted to rung {rung} ({}): {error}",
                    if rung == 1 {
                        "BF16 quarantine + widened guard"
                    } else {
                        "scalar kernels"
                    }
                );
            }
        }
        FailureVerdict::Dead => {
            if !quiet {
                println!("[fleet] tenant {id} DEAD (every rung exhausted): {error}");
            }
            status[i] = Status::Failed(error);
        }
    }
}

/// Persist the fleet manifest after a round (no-op without a supervisor
/// or a manifest path). A failed save degrades crash recovery, not the
/// running fleet — warn and continue.
#[allow(clippy::too_many_arguments)]
fn save_fleet_manifest(
    sup: &Option<Supervisor>,
    opts: &FleetOptions,
    tenants: &[Tenant],
    status: &[Status],
    completed: &[u64],
    slices: &[u64],
    pass: &[u128],
    schedule: &[Slice],
    next_round: u64,
) {
    let Some(s) = sup else { return };
    let Some(path) = &s.opts.manifest else { return };
    let sups = s.export();
    let m = FleetManifest {
        round: next_round,
        quantum: opts.quantum,
        tenants: tenants
            .iter()
            .enumerate()
            .map(|(i, t)| ManifestTenant {
                id: t.id.clone(),
                sup: sups[i].clone(),
                completed: completed[i],
                slices: slices[i],
                pass: pass[i],
                failed: match &status[i] {
                    Status::Failed(e) => Some(e.clone()),
                    _ => None,
                },
                done: status[i] == Status::Done,
            })
            .collect(),
        schedule: schedule.to_vec(),
    };
    if let Err(e) = m.save(path) {
        eprintln!(
            "[fleet] WARNING: failed to save fleet manifest {}: {e:#}",
            path.display()
        );
    }
}

/// Validate a loaded manifest against this fleet and restore the
/// ledger. A mismatched fleet (different tenants or slicing) is a
/// caller error, not corruption — bail instead of silently diverging.
#[allow(clippy::too_many_arguments)]
fn restore_manifest(
    m: &FleetManifest,
    tenants: &[Tenant],
    opts: &FleetOptions,
    s: &mut Supervisor,
    status: &mut [Status],
    completed: &mut [u64],
    slices: &mut [u64],
    pass: &mut [u128],
    schedule: &mut Vec<Slice>,
    round: &mut u64,
) -> Result<()> {
    if m.tenants.len() != tenants.len()
        || m.tenants.iter().zip(tenants).any(|(mt, t)| mt.id != t.id)
    {
        bail!(
            "fleet manifest names a different tenant set ({:?}); refusing to resume — \
             delete the manifest to start this fleet fresh",
            m.tenants.iter().map(|t| t.id.as_str()).collect::<Vec<_>>()
        );
    }
    if m.quantum != opts.quantum {
        bail!(
            "fleet manifest pins quantum {} but this fleet uses {}; resume with the \
             original slicing to keep the bitwise contract",
            m.quantum,
            opts.quantum
        );
    }
    for (i, mt) in m.tenants.iter().enumerate() {
        completed[i] = mt.completed;
        slices[i] = mt.slices;
        pass[i] = mt.pass;
        status[i] = match (&mt.failed, mt.done) {
            (Some(e), _) => Status::Failed(e.clone()),
            (None, true) => Status::Done,
            (None, false) => Status::Runnable,
        };
    }
    s.import(m.tenants.iter().map(|mt| mt.sup.clone()).collect());
    *schedule = m.schedule.clone();
    *round = m.round;
    Ok(())
}

/// One slice: build a fresh host runtime + trainer for the tenant,
/// auto-resume its ring, run to the slice horizon (which force-writes
/// the suspension checkpoint), and drop every session — the tenant
/// holds no resident state between slices. Panics are contained into
/// `Err` here so one tenant's crash never reaches the pool machinery
/// of its neighbors. A demoted tenant's options are rewritten for its
/// rung (BF16 quarantine, widened guard, scalar kernels) just before
/// dispatch, so demotion needs no mutable tenant state.
fn advance(
    tenant: &Tenant,
    from: u64,
    opts: &FleetOptions,
    quantum: u64,
    rung: u8,
    fresh_guard: bool,
) -> Result<TrainOutcome, String> {
    let mut o = tenant.opts.clone();
    o.resume = None;
    o.auto_resume = true;
    o.stop_after = match quantum {
        0 => None,
        q => Some((from + q).min(o.steps)),
    };
    if o.parallelism.is_none() {
        o.parallelism = Some(opts.parallelism.clone());
    }
    if rung > 0 {
        supervisor::apply_demotion(&mut o, rung, &opts.parallelism);
    }
    o.fresh_guard = fresh_guard;
    let run = catch_unwind(AssertUnwindSafe(|| {
        let par_run = o.parallelism.clone().expect("slice parallelism resolved above");
        let pol = o.policy.clone().unwrap_or_else(policy::global);
        let rt = Runtime::host_with(tenant.model, par_run, pol);
        Trainer::new(&rt, tenant.config).run(&o)
    }));
    match run {
        Ok(Ok(out)) => Ok(out),
        Ok(Err(e)) => Err(format!("{e:#}")),
        Err(payload) => Err(format!("slice panicked: {}", panic_text(payload.as_ref()))),
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Resolve `MOR_MAX_RUNS` strictly (library-side twin of the CLI's
/// `--max-runs`); `fallback` when unset, a loud panic when malformed —
/// the same contract as the other env autos.
pub fn auto_max_runs(fallback: usize) -> usize {
    match crate::util::env::parse_pos_int(
        crate::util::env::var("MOR_MAX_RUNS").as_deref(),
        "MOR_MAX_RUNS ",
        "positive run count",
        "unset it to default to the pool width",
    ) {
        Ok(v) => v.unwrap_or(fallback),
        Err(msg) => panic!("{msg}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(id: &str, steps: u64, weight: usize) -> Tenant {
        let dir = std::env::temp_dir()
            .join(format!("mor_sched_unit_{}_{id}", std::process::id()));
        let mut opts = TrainerOptions::new("train_mor_tensor_block", steps, dir);
        opts.quiet = true;
        opts.val_every = 0;
        Tenant::new(id, ModelConfig::TINY, TrainConfig::config1(steps), opts)
            .with_weight(weight)
    }

    #[test]
    fn fleet_rejects_malformed_configurations() {
        let fo = FleetOptions::new(Parallelism::serial());
        assert!(run_fleet(&[], &fo).is_err(), "empty fleet");

        let mut zero_runs = fo.clone();
        zero_runs.max_runs = 0;
        assert!(run_fleet(&[tenant("a", 1, 1)], &zero_runs).is_err());

        assert!(run_fleet(&[tenant("a", 1, 0)], &fo).is_err(), "weight 0");

        let dup = [tenant("a", 1, 1), tenant("a", 1, 1)];
        assert!(run_fleet(&dup, &fo).is_err(), "duplicate id");

        let mut resuming = tenant("a", 1, 1);
        resuming.opts.resume = Some("x.ckpt".into());
        assert!(run_fleet(&[resuming], &fo).is_err(), "caller-owned resume");

        let mut repinned = tenant("a", 1, 1);
        repinned.opts.repin = true;
        assert!(run_fleet(&[repinned], &fo).is_err(), "supervisor-owned repin");
        let mut refreshed = tenant("a", 1, 1);
        refreshed.opts.fresh_guard = true;
        assert!(run_fleet(&[refreshed], &fo).is_err(), "supervisor-owned fresh_guard");

        // Same dir + artifact + config always collides; with slicing
        // on, same dir + artifact collides even across configs (the
        // ring is keyed by artifact alone).
        let mut b = tenant("b", 1, 1);
        b.opts.out_dir = tenant("a", 1, 1).opts.out_dir;
        assert!(run_fleet(&[tenant("a", 1, 1), b.clone()], &fo).is_err());
        b.config = TrainConfig::config2(1);
        assert!(run_fleet(&[tenant("a", 1, 1), b.clone()], &fo).is_ok_and(|f| f
            .tenants
            .iter()
            .all(|t| t.completed())));
        let mut sliced = fo.clone();
        sliced.quantum = 1;
        assert!(run_fleet(&[tenant("a", 1, 1), b], &sliced).is_err());
    }

    #[test]
    fn max_wait_rounds_audits_the_schedule_log() {
        let out = FleetOutcome {
            tenants: Vec::new(),
            schedule: vec![
                Slice { round: 0, tenant: 0, from_step: 0, to_step: 1 },
                Slice { round: 3, tenant: 0, from_step: 1, to_step: 2 },
                Slice { round: 4, tenant: 0, from_step: 2, to_step: 3 },
                Slice { round: 2, tenant: 1, from_step: 0, to_step: 1 },
            ],
            rounds: 5,
            halted: false,
        };
        assert_eq!(out.max_wait_rounds(0), 2, "rounds 1-2 skipped tenant 0");
        assert_eq!(out.max_wait_rounds(1), 2, "tenant 1 first ran in round 2");
        assert_eq!(out.max_wait_rounds(9), 0, "never-scheduled tenant");
    }

    #[test]
    fn adaptive_quantum_shares_the_queue_over_the_worker_cap() {
        let mut fo = FleetOptions::new(Parallelism::serial());
        fo.quantum = 6;
        fo.max_runs = 2;
        assert_eq!(effective_quantum(&fo, 2), 6, "adaptive off: fixed quantum");
        fo.adaptive = true;
        assert_eq!(effective_quantum(&fo, 2), 6, "queue fits the cap");
        assert_eq!(effective_quantum(&fo, 4), 3, "2x oversubscribed: halved");
        assert_eq!(effective_quantum(&fo, 5), 2, "ceil(5/2)=3 shares");
        assert_eq!(effective_quantum(&fo, 100), 1, "floor at one step");
        fo.quantum = 0;
        assert_eq!(effective_quantum(&fo, 100), 0, "run-to-completion stays");
    }

    #[test]
    fn auto_max_runs_resolves_strictly() {
        // Unset in the test environment: the fallback wins.
        std::env::remove_var("MOR_MAX_RUNS");
        assert_eq!(auto_max_runs(7), 7);
    }
}
