//! The fleet scheduler: fair-share multiplexing of N concurrent
//! training runs over one shared [`Parallelism`] pool, with
//! checkpoint-backed preemption and per-tenant failure containment.
//!
//! ## Design
//!
//! A **tenant** is one training run (artifact + config + options +
//! fair-share weight). The scheduler advances tenants in **rounds**:
//! each round it picks up to `max_runs` runnable tenants by [stride
//! scheduling](https://en.wikipedia.org/wiki/Stride_scheduling) — every
//! tenant carries a *pass* value that grows by `STRIDE_ONE / weight`
//! per slice it receives, and the tenants with the smallest pass run
//! next, so over time each tenant's slice share converges to
//! `weight / Σ weights` and no tenant starves. Ties break by the same
//! largest-first rule [`par::weighted_order`] gives sweep items
//! (descending weight, then index), and the selected tenants are
//! submitted to the shared pool through [`par::par_map_weighted`] —
//! run-granularity items on exactly the machinery that already
//! schedules tensor-granularity work, nested chunk-parallelism and
//! all (the pool's help-while-waiting protocol keeps tenant slices
//! that are themselves chunk-parallel deadlock-free).
//!
//! ## Preemption contract
//!
//! A slice runs its tenant for `quantum` steps via
//! `TrainerOptions::stop_after`, which forces a `MORCKPT2` checkpoint
//! at the suspension point; the session is then dropped — eviction
//! costs zero resident state — and the next slice `auto_resume`s from
//! the tenant's own checkpoint ring. The PR 4 resume ≡ continuous
//! contract makes this *bitwise* invisible: an interleaved tenant's
//! trajectory, metrics rows (minus the wall-clock `step_ms` column),
//! decision fractions and final checkpointed state are identical to
//! the same run executed alone, at any thread count. That is not a
//! design hope — `tests/scheduler_equivalence.rs` proves it.
//!
//! ## Containment
//!
//! Each slice runs under `catch_unwind`, so a tenant that panics (e.g.
//! an injected worker panic with no guard to absorb it) or errors
//! (rewind budget exhausted, corrupt state) becomes a *failed tenant*,
//! not a dead fleet: its error is reported, its neighbors keep their
//! slices, and — because guarded recovery (skip → BF16 quarantine →
//! rewind, PR 8) runs *inside* the slice — a tenant with a guard
//! usually never surfaces here at all. Guard state (strikes,
//! quarantines, the rewind budget) lives in the `guard/state`
//! checkpoint section, so it survives eviction like everything else.

use super::trainer::{TrainOutcome, Trainer, TrainerOptions};
use crate::model::config::{ModelConfig, TrainConfig};
use crate::mor::policy;
use crate::runtime::Runtime;
use crate::util::par::{self, Parallelism};
use anyhow::{bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// One training run under the scheduler.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Unique tenant name (schedule log, reports).
    pub id: String,
    pub model: ModelConfig,
    pub config: TrainConfig,
    /// The run's own options: artifact, steps, out_dir, policy, guard,
    /// faults, checkpoint cadence… The scheduler owns only the
    /// preemption fields: `resume`/`auto_resume`/`stop_after` are
    /// overwritten per slice.
    pub opts: TrainerOptions,
    /// Fair-share weight (≥ 1): slice share converges to
    /// `weight / Σ weights`.
    pub weight: usize,
}

impl Tenant {
    pub fn new(id: &str, model: ModelConfig, config: TrainConfig, opts: TrainerOptions) -> Self {
        Tenant { id: id.to_string(), model, config, opts, weight: 1 }
    }

    pub fn with_weight(mut self, weight: usize) -> Self {
        self.weight = weight;
        self
    }
}

/// Fleet-level knobs.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Maximum tenants resident (advancing) in one round — the
    /// oversubscription cap (`--max-runs` / `MOR_MAX_RUNS`).
    pub max_runs: usize,
    /// Steps per slice; `0` runs every tenant to completion in its
    /// first slice (no preemption — the policy-sweep shape).
    pub quantum: u64,
    /// The shared pool every slice is submitted to (and the default
    /// engine handle for tenants that don't carry their own).
    pub parallelism: Parallelism,
    /// Silence the per-round narration.
    pub quiet: bool,
}

impl FleetOptions {
    pub fn new(parallelism: Parallelism) -> Self {
        let max_runs = parallelism.threads.max(1);
        FleetOptions { max_runs, quantum: 0, parallelism, quiet: true }
    }
}

/// One schedule-log entry: tenant `tenant` advanced from `from_step`
/// to `to_step` completed steps during round `round`. The log is
/// deterministic (selection is a pure function of weights and history)
/// and is what the starvation test audits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slice {
    pub round: u64,
    pub tenant: usize,
    pub from_step: u64,
    pub to_step: u64,
}

/// Per-tenant result.
#[derive(Debug)]
pub struct TenantReport {
    pub id: String,
    /// The final slice's outcome — which covers the *whole* run
    /// (records replay the full prefix), so for a completed tenant
    /// this is exactly what a solo `Trainer::run` would have returned.
    /// `None` only for a tenant that failed before any slice finished.
    pub outcome: Option<TrainOutcome>,
    /// The containment verdict: `Some(error)` for a failed tenant.
    pub error: Option<String>,
    /// Slices this tenant received.
    pub slices: u64,
}

impl TenantReport {
    pub fn completed(&self) -> bool {
        self.error.is_none()
    }
}

/// The fleet's outcome: per-tenant reports (tenant order preserved)
/// plus the full schedule log.
#[derive(Debug)]
pub struct FleetOutcome {
    pub tenants: Vec<TenantReport>,
    pub schedule: Vec<Slice>,
    pub rounds: u64,
}

impl FleetOutcome {
    /// The report for a tenant by id.
    pub fn tenant(&self, id: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.id == id)
    }

    /// Longest run of consecutive rounds (while the tenant was still
    /// runnable) in which tenant `i` received no slice — the quantity
    /// the fair-share bound constrains.
    pub fn max_wait_rounds(&self, i: usize) -> u64 {
        let mut scheduled: Vec<u64> =
            self.schedule.iter().filter(|s| s.tenant == i).map(|s| s.round).collect();
        scheduled.sort_unstable();
        let mut max_gap = 0u64;
        let mut prev: Option<u64> = None;
        for r in scheduled {
            if let Some(p) = prev {
                max_gap = max_gap.max(r - p - 1);
            } else {
                max_gap = max_gap.max(r); // rounds waited before the first slice
            }
            prev = Some(r);
        }
        max_gap
    }
}

/// Pass-value unit: one slice at weight 1 advances pass by this much,
/// a weight-w tenant by `STRIDE_ONE / w`. Large enough that integer
/// division keeps distinct strides for any sane weight.
const STRIDE_ONE: u128 = 1 << 40;

/// Consecutive no-progress slices tolerated before a tenant is failed
/// (a livelock backstop — e.g. a fault plan that tears every save a
/// fresh start ever reaches could otherwise loop forever).
const MAX_STALLS: u32 = 3;

#[derive(Debug, Clone, PartialEq)]
enum Status {
    Runnable,
    Done,
    Failed(String),
}

/// Run every tenant to completion (or containment), multiplexed over
/// `opts.parallelism` — see the module docs for the scheduling,
/// preemption and containment contracts.
pub fn run_fleet(tenants: &[Tenant], opts: &FleetOptions) -> Result<FleetOutcome> {
    if tenants.is_empty() {
        bail!("fleet has no tenants");
    }
    if opts.max_runs == 0 {
        bail!("max_runs must be >= 1");
    }
    for (i, t) in tenants.iter().enumerate() {
        if t.weight == 0 {
            bail!("tenant {:?} has weight 0; weights must be >= 1", t.id);
        }
        if t.opts.resume.is_some() {
            bail!("tenant {:?} sets resume; the scheduler owns resumption", t.id);
        }
        for u in &tenants[..i] {
            if u.id == t.id {
                bail!("duplicate tenant id {:?}", t.id);
            }
            // Metrics/stats files are keyed by (artifact, config) and
            // the checkpoint ring by artifact alone, so colliding runs
            // would corrupt each other's state on disk.
            if u.opts.out_dir == t.opts.out_dir && u.opts.artifact == t.opts.artifact {
                let slicing = opts.quantum > 0
                    || t.opts.ckpt_every > 0
                    || u.opts.ckpt_every > 0;
                if slicing || u.config.name == t.config.name {
                    bail!(
                        "tenants {:?} and {:?} share out_dir {} and artifact {:?}; \
                         their on-disk files would collide",
                        u.id,
                        t.id,
                        t.opts.out_dir.display(),
                        t.opts.artifact
                    );
                }
            }
        }
    }

    let n = tenants.len();
    let mut status: Vec<Status> = vec![Status::Runnable; n];
    let mut completed: Vec<u64> = vec![0; n];
    let mut pass: Vec<u128> = vec![0; n];
    let mut stalls: Vec<u32> = vec![0; n];
    let mut slices: Vec<u64> = vec![0; n];
    let mut outcomes: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
    let mut schedule: Vec<Slice> = Vec::new();
    let mut round: u64 = 0;

    while status.iter().any(|s| *s == Status::Runnable) {
        // Stride selection: smallest pass first, ties by the
        // largest-first weighted order (descending weight, then
        // index) — the same total order `par::weighted_order` gives
        // the dispatch below.
        let mut resident: Vec<usize> =
            (0..n).filter(|&i| status[i] == Status::Runnable).collect();
        resident.sort_by_key(|&i| (pass[i], std::cmp::Reverse(tenants[i].weight), i));
        resident.truncate(opts.max_runs);

        let weights: Vec<usize> = resident.iter().map(|&i| tenants[i].weight).collect();
        let before: Vec<u64> = resident.iter().map(|&i| completed[i]).collect();
        let results: Vec<Result<TrainOutcome, String>> =
            par::par_map_weighted(&opts.parallelism, &weights, |k| {
                advance(&tenants[resident[k]], before[k], opts)
            });

        for (k, res) in results.into_iter().enumerate() {
            let i = resident[k];
            pass[i] += STRIDE_ONE / tenants[i].weight as u128;
            slices[i] += 1;
            match res {
                Err(e) => {
                    if !opts.quiet {
                        println!("[fleet] tenant {} FAILED: {e}", tenants[i].id);
                    }
                    status[i] = Status::Failed(e);
                }
                Ok(out) => {
                    let now = out.records.len() as u64;
                    schedule.push(Slice {
                        round,
                        tenant: i,
                        from_step: completed[i],
                        to_step: now,
                    });
                    if now <= completed[i] {
                        stalls[i] += 1;
                        if stalls[i] >= MAX_STALLS {
                            status[i] = Status::Failed(format!(
                                "no progress in {MAX_STALLS} consecutive slices \
                                 (stuck at step {now})"
                            ));
                        }
                    } else {
                        stalls[i] = 0;
                    }
                    completed[i] = now;
                    let done = now >= tenants[i].opts.steps;
                    if done {
                        status[i] = Status::Done;
                    }
                    if !opts.quiet {
                        println!(
                            "[fleet] round {round}: {} -> step {now}/{}{}",
                            tenants[i].id,
                            tenants[i].opts.steps,
                            if done { " (done)" } else { "" }
                        );
                    }
                    outcomes[i] = Some(out);
                }
            }
        }
        round += 1;
    }

    let reports = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| TenantReport {
            id: t.id.clone(),
            outcome: outcomes[i].take(),
            error: match &status[i] {
                Status::Failed(e) => Some(e.clone()),
                _ => None,
            },
            slices: slices[i],
        })
        .collect();
    Ok(FleetOutcome { tenants: reports, schedule, rounds: round })
}

/// One slice: build a fresh host runtime + trainer for the tenant,
/// auto-resume its ring, run to the slice horizon (which force-writes
/// the suspension checkpoint), and drop every session — the tenant
/// holds no resident state between slices. Panics are contained into
/// `Err` here so one tenant's crash never reaches the pool machinery
/// of its neighbors.
fn advance(tenant: &Tenant, from: u64, opts: &FleetOptions) -> Result<TrainOutcome, String> {
    let mut o = tenant.opts.clone();
    o.resume = None;
    o.auto_resume = true;
    o.stop_after = match opts.quantum {
        0 => None,
        q => Some((from + q).min(o.steps)),
    };
    if o.parallelism.is_none() {
        o.parallelism = Some(opts.parallelism.clone());
    }
    let run = catch_unwind(AssertUnwindSafe(|| {
        let par_run = o.parallelism.clone().expect("slice parallelism resolved above");
        let pol = o.policy.clone().unwrap_or_else(policy::global);
        let rt = Runtime::host_with(tenant.model, par_run, pol);
        Trainer::new(&rt, tenant.config).run(&o)
    }));
    match run {
        Ok(Ok(out)) => Ok(out),
        Ok(Err(e)) => Err(format!("{e:#}")),
        Err(payload) => Err(format!("slice panicked: {}", panic_text(payload.as_ref()))),
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Resolve `MOR_MAX_RUNS` strictly (library-side twin of the CLI's
/// `--max-runs`); `fallback` when unset, a loud panic when malformed —
/// the same contract as the other env autos.
pub fn auto_max_runs(fallback: usize) -> usize {
    match crate::util::env::parse_pos_int(
        crate::util::env::var("MOR_MAX_RUNS").as_deref(),
        "MOR_MAX_RUNS ",
        "positive run count",
        "unset it to default to the pool width",
    ) {
        Ok(v) => v.unwrap_or(fallback),
        Err(msg) => panic!("{msg}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(id: &str, steps: u64, weight: usize) -> Tenant {
        let dir = std::env::temp_dir()
            .join(format!("mor_sched_unit_{}_{id}", std::process::id()));
        let mut opts = TrainerOptions::new("train_mor_tensor_block", steps, dir);
        opts.quiet = true;
        opts.val_every = 0;
        Tenant::new(id, ModelConfig::TINY, TrainConfig::config1(steps), opts)
            .with_weight(weight)
    }

    #[test]
    fn fleet_rejects_malformed_configurations() {
        let fo = FleetOptions::new(Parallelism::serial());
        assert!(run_fleet(&[], &fo).is_err(), "empty fleet");

        let mut zero_runs = fo.clone();
        zero_runs.max_runs = 0;
        assert!(run_fleet(&[tenant("a", 1, 1)], &zero_runs).is_err());

        assert!(run_fleet(&[tenant("a", 1, 0)], &fo).is_err(), "weight 0");

        let dup = [tenant("a", 1, 1), tenant("a", 1, 1)];
        assert!(run_fleet(&dup, &fo).is_err(), "duplicate id");

        let mut resuming = tenant("a", 1, 1);
        resuming.opts.resume = Some("x.ckpt".into());
        assert!(run_fleet(&[resuming], &fo).is_err(), "caller-owned resume");

        // Same dir + artifact + config always collides; with slicing
        // on, same dir + artifact collides even across configs (the
        // ring is keyed by artifact alone).
        let mut b = tenant("b", 1, 1);
        b.opts.out_dir = tenant("a", 1, 1).opts.out_dir;
        assert!(run_fleet(&[tenant("a", 1, 1), b.clone()], &fo).is_err());
        b.config = TrainConfig::config2(1);
        assert!(run_fleet(&[tenant("a", 1, 1), b.clone()], &fo).is_ok_and(|f| f
            .tenants
            .iter()
            .all(|t| t.completed())));
        let mut sliced = fo.clone();
        sliced.quantum = 1;
        assert!(run_fleet(&[tenant("a", 1, 1), b], &sliced).is_err());
    }

    #[test]
    fn max_wait_rounds_audits_the_schedule_log() {
        let out = FleetOutcome {
            tenants: Vec::new(),
            schedule: vec![
                Slice { round: 0, tenant: 0, from_step: 0, to_step: 1 },
                Slice { round: 3, tenant: 0, from_step: 1, to_step: 2 },
                Slice { round: 4, tenant: 0, from_step: 2, to_step: 3 },
                Slice { round: 2, tenant: 1, from_step: 0, to_step: 1 },
            ],
            rounds: 5,
        };
        assert_eq!(out.max_wait_rounds(0), 2, "rounds 1-2 skipped tenant 0");
        assert_eq!(out.max_wait_rounds(1), 2, "tenant 1 first ran in round 2");
        assert_eq!(out.max_wait_rounds(9), 0, "never-scheduled tenant");
    }

    #[test]
    fn auto_max_runs_resolves_strictly() {
        // Unset in the test environment: the fallback wins.
        std::env::remove_var("MOR_MAX_RUNS");
        assert_eq!(auto_max_runs(7), 7);
    }
}
