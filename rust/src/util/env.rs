//! The environment-knob registry: one strict parser family and one
//! table of every `MOR_*` variable the crate reads.
//!
//! Historically each knob (`MOR_THREADS`, `MOR_PAR_MIN_BLOCK`,
//! `MOR_SCALAR_KERNELS`, `MOR_NO_SIMD`) carried its own hand-rolled
//! strict parser in `util::par`; adding `MOR_POLICY` would have made a
//! fifth copy. This module centralizes the two parser shapes every
//! knob uses — positive integer and 0/1 boolean — with the original
//! error messages preserved verbatim (tests pin them), plus a
//! [`KNOBS`] registry that the README knobs table is generated from
//! (`knobs_markdown`), so docs cannot drift from the code.
//!
//! Parsing stays **strict** by design: a set-but-malformed knob is a
//! loud error, never a silent fallback — a typo in the CI determinism
//! matrix must fail the job, not quietly run serial.

/// One registered environment knob: the variable, its optional CLI
/// twin, and the two README table columns.
#[derive(Debug, Clone, Copy)]
pub struct Knob {
    /// Environment variable name (`MOR_*`).
    pub env: &'static str,
    /// The CLI flag spelling when one exists (`--threads N`).
    pub flag: Option<&'static str>,
    /// Default shown in the README table.
    pub default_desc: &'static str,
    /// Meaning column of the README table.
    pub meaning: &'static str,
}

/// Every environment knob the crate reads, in README table order.
/// `Parallelism::auto` resolves the first four; `mor::policy::auto`
/// resolves `MOR_POLICY`; `faults::auto` and `coordinator::guard::auto`
/// resolve `MOR_FAULTS` / `MOR_GUARD`; `main` resolves `MOR_CKPT_KEEP`;
/// `coordinator::scheduler::auto_max_runs` (and `main`'s `--max-runs`)
/// resolve `MOR_MAX_RUNS`; `coordinator::supervisor::auto_retries` /
/// `auto_stall_after` (and `main`'s `--retries` / `--stall-after`)
/// resolve `MOR_RETRIES` / `MOR_STALL_AFTER`.
pub const KNOBS: &[Knob] = &[
    Knob {
        env: "MOR_THREADS",
        flag: Some("--threads N"),
        default_desc: "machine parallelism",
        meaning: "chunk runners (1 = serial)",
    },
    Knob {
        env: "MOR_PAR_MIN_BLOCK",
        flag: Some("--par-min-block N"),
        default_desc: "8192",
        meaning: "tensors below N elements stay serial",
    },
    Knob {
        env: "MOR_SCALAR_KERNELS",
        flag: None,
        default_desc: "0",
        meaning: "`1` forces the scalar reference kernels (parity oracle)",
    },
    Knob {
        env: "MOR_NO_SIMD",
        flag: None,
        default_desc: "0",
        meaning: "`1` pins the blocked-scalar kernels (SIMD-off oracle)",
    },
    Knob {
        env: "MOR_POLICY",
        flag: Some("--policy SPEC"),
        default_desc: "threshold",
        meaning: "decision policy: `threshold`, `metric[=BUDGET]` or \
                  `static[=INPUT,WEIGHT,GRAD]`",
    },
    Knob {
        env: "MOR_FAULTS",
        flag: Some("--faults SPEC"),
        default_desc: "unset",
        meaning: "deterministic fault schedule, e.g. \
                  `nan:grad@step=7;bitflip:block@p=1e-4` (host backend only)",
    },
    Knob {
        env: "MOR_GUARD",
        flag: Some("--guard SPEC"),
        default_desc: "off",
        meaning: "numeric guard: `on`, `off` or \
                  `skip=K,quarantine=N,rewinds=R,spike=F`",
    },
    Knob {
        env: "MOR_CKPT_KEEP",
        flag: Some("--ckpt-keep K"),
        default_desc: "keep all",
        meaning: "checkpoint ring retention: keep only the newest K files",
    },
    Knob {
        env: "MOR_MAX_RUNS",
        flag: Some("--max-runs N"),
        default_desc: "pool thread count",
        meaning: "fleet scheduler: max training runs resident per round",
    },
    Knob {
        env: "MOR_RETRIES",
        flag: Some("--retries N"),
        default_desc: "3",
        meaning: "fleet supervisor: retry budget per tenant per demotion rung",
    },
    Knob {
        env: "MOR_STALL_AFTER",
        flag: Some("--stall-after N"),
        default_desc: "3",
        meaning: "fleet supervisor: consecutive no-progress slices before the \
                  stall watchdog trips",
    },
];

/// The README knobs table, generated from [`KNOBS`]. A unit test (and
/// the doc itself) pins `README.md` to this exact rendering.
pub fn knobs_markdown() -> String {
    let mut out = String::from("| knob | default | meaning |\n|------|---------|---------|\n");
    for k in KNOBS {
        match k.flag {
            Some(flag) => out.push_str(&format!(
                "| `{}` / `{}` | {} | {} |\n",
                flag, k.env, k.default_desc, k.meaning
            )),
            None => {
                out.push_str(&format!("| `{}` | {} | {} |\n", k.env, k.default_desc, k.meaning))
            }
        }
    }
    out
}

/// Read a knob's raw value (`None` when unset). One chokepoint so the
/// registry is also the inventory of every `std::env::var` read.
pub fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// Strictly parse a positive-integer knob: `Ok(None)` when unset,
/// `Ok(Some(n))` for `n >= 1`, and a clear error for `0`, empty or
/// non-numeric values. `prefix` is prepended to every message (either
/// the knob name plus a space, or empty when the caller prefixes the
/// flag/env spelling itself); `unit` names what a valid value is;
/// `zero_advice` explains what to do instead of `0`.
pub fn parse_pos_int(
    raw: Option<&str>,
    prefix: &str,
    unit: &str,
    zero_advice: &str,
) -> Result<Option<usize>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err(format!("{prefix}is set but empty; use a {unit} or unset it"));
    }
    match trimmed.parse::<usize>() {
        Ok(0) => Err(format!("{prefix}must be >= 1 ({zero_advice})")),
        Ok(n) => Ok(Some(n)),
        Err(_) => Err(format!("{prefix}must be a {unit}, got {trimmed:?}")),
    }
}

/// Strictly parse a `0`/`1` oracle knob: `Ok(None)` when unset,
/// `Ok(Some(true/false))` for `1`/`0`, and a clear error naming both
/// states for anything else.
pub fn parse_bool01(
    raw: Option<&str>,
    name: &str,
    on_desc: &str,
    off_desc: &str,
) -> Result<Option<bool>, String> {
    let Some(raw) = raw else { return Ok(None) };
    match raw.trim() {
        "1" => Ok(Some(true)),
        "0" => Ok(Some(false)),
        other => {
            Err(format!("{name} must be 1 ({on_desc}) or 0 ({off_desc}), got {other:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pos_int_parser_accepts_and_rejects() {
        assert_eq!(parse_pos_int(None, "X ", "positive integer", "z"), Ok(None));
        assert_eq!(parse_pos_int(Some("4"), "X ", "positive integer", "z"), Ok(Some(4)));
        assert_eq!(parse_pos_int(Some(" 13 "), "X ", "positive integer", "z"), Ok(Some(13)));
        assert!(parse_pos_int(Some(""), "X ", "positive integer", "z").is_err());
        assert!(parse_pos_int(Some("0"), "X ", "positive integer", "z").is_err());
        assert!(parse_pos_int(Some("-2"), "X ", "positive integer", "z").is_err());
        assert!(parse_pos_int(Some("O8"), "X ", "positive integer", "z").is_err());
    }

    #[test]
    fn bool01_parser_accepts_and_rejects() {
        assert_eq!(parse_bool01(None, "X", "on", "off"), Ok(None));
        assert_eq!(parse_bool01(Some("1"), "X", "on", "off"), Ok(Some(true)));
        assert_eq!(parse_bool01(Some(" 0 "), "X", "on", "off"), Ok(Some(false)));
        let err = parse_bool01(Some("yes"), "X", "on", "off").unwrap_err();
        assert_eq!(err, "X must be 1 (on) or 0 (off), got \"yes\"");
    }

    #[test]
    fn registry_covers_the_known_knobs() {
        let names: Vec<&str> = KNOBS.iter().map(|k| k.env).collect();
        assert_eq!(
            names,
            [
                "MOR_THREADS",
                "MOR_PAR_MIN_BLOCK",
                "MOR_SCALAR_KERNELS",
                "MOR_NO_SIMD",
                "MOR_POLICY",
                "MOR_FAULTS",
                "MOR_GUARD",
                "MOR_CKPT_KEEP",
                "MOR_MAX_RUNS",
                "MOR_RETRIES",
                "MOR_STALL_AFTER"
            ]
        );
    }

    /// The README knobs table is a literal copy of `knobs_markdown()`:
    /// editing one without the other fails here.
    #[test]
    fn readme_knobs_table_matches_registry() {
        let readme = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("README.md");
        let text = std::fs::read_to_string(&readme).expect("README.md at the repo root");
        let table = knobs_markdown();
        assert!(
            text.contains(&table),
            "README.md knobs table is out of sync with util::env::KNOBS;\n\
             regenerate it from knobs_markdown():\n{table}"
        );
    }
}
