//! Tiny CLI argument parser (offline replacement for `clap`): positional
//! subcommand + `--key value` / `--flag` options, with typed getters and
//! an auto-generated usage line.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// First positional argument (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut it = args.into_iter().peekable();
        let mut out = Args {
            command: None,
            positional: Vec::new(),
            opts: BTreeMap::new(),
            flags: Vec::new(),
        };
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key) == Some("true")
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a u64, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a float, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --steps 100 --recipe mor_tensor_block --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.get("recipe"), Some("mor_tensor_block"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("report --figure=fig10 --threshold=0.045");
        assert_eq!(a.get("figure"), Some("fig10"));
        assert_eq!(a.f32("threshold", 0.0), 0.045);
    }

    #[test]
    fn positional_args() {
        let a = parse("eval ckpt1 ckpt2");
        assert_eq!(a.command.as_deref(), Some("eval"));
        assert_eq!(a.positional, vec!["ckpt1", "ckpt2"]);
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse("x --dry-run --steps 5");
        assert!(a.flag("dry-run"));
        assert_eq!(a.usize("steps", 0), 5);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize("steps", 7), 7);
        assert_eq!(a.f32("lr", 0.1), 0.1);
        assert_eq!(a.get_or("model", "tiny"), "tiny");
    }
}
