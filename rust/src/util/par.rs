//! Parallel chunked execution engine for the quantization/analysis
//! pipeline: a std-only **persistent worker pool** with deterministic
//! block-order chunking.
//!
//! Design contract, relied on by every caller and enforced by
//! `rust/tests/parallel_equivalence.rs`: results are **bit-identical to
//! the serial path** regardless of thread count. The primitives only
//! split *independent* work items (partition blocks, GEMM row panels,
//! tensors of a sweep) across threads; all reductions (error-accumulator
//! merges, MAC counters) happen on the caller side in canonical item
//! order after the parallel section. Floating-point evaluation order per
//! output element therefore never changes.
//!
//! Work *division* is static: item range `0..n` is cut into at most
//! `threads` contiguous chunks, and results always merge in canonical
//! chunk order. Work *placement* is dynamic on the default engine:
//! chunks land on per-worker deques and idle threads steal, so
//! scheduling never changes results, only who computes them.
//!
//! ## The worker pool
//!
//! A [`Parallelism`] handle owns (a shared reference to) one
//! [`WorkerPool`]: `threads - 1` lazily-spawned worker threads, with
//! the calling thread always executing the first chunk itself and then
//! helping drain runnable work until its call completes. The
//! help-while-waiting step is what makes *nested* parallel sections
//! (pipeline-level overlap via [`join2`] around chunk-parallel
//! quantizations) deadlock-free: a waiting caller never idles while
//! runnable chunks exist.
//!
//! Three dispatch engines share those workers:
//!
//! * [`Engine::Steal`] (default) — each worker owns a **bounded deque**;
//!   batch submissions spread chunks across the deques round-robin
//!   (largest work first for weighted submissions), overflow spills to
//!   the shared injector queue, and an idle thread **steals** from the
//!   back of victim deques in a randomized-but-seeded order (bounded
//!   attempts, then one deterministic sweep, then sleep). Owners pop
//!   their own deque front lock-locally, so the old single-mutex chunk
//!   queue is off the hot path at high thread counts.
//! * [`Engine::Pool`] — the previous scheduler: every chunk goes through
//!   the one shared injector queue. Retained for the pool-vs-steal
//!   bench comparison.
//! * [`Engine::Spawn`] — a scoped thread per chunk, spawned and joined
//!   inside every call; the original engine, the per-call-overhead
//!   baseline.
//!
//! Clones of a handle share the pool, so consecutive `par_map` /
//! `par_panels` calls reuse the same workers instead of paying a
//! spawn/join wave per call. Worker panics are caught, forwarded, and
//! re-raised on the calling thread — including panics in chunks that
//! were stolen — and dropping the last handle shuts the pool down and
//! joins every worker.

use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Elements below which tensor-granularity operations stay serial (the
/// "min-block-size cutoff": dispatching chunks for a 64x64 tensor costs
/// more than the quantization itself).
pub const DEFAULT_MIN_ITEMS: usize = 8192;

/// Which numeric kernel implementation the hot loops run: the
/// SIMD-dispatched kernel layer (`crate::kernels`, the default), the
/// same layer pinned to its scalar blocked path, or the original scalar
/// reference loops. All three are **bit-identical by contract** (the
/// kernel layer only reorders memory traffic, never the per-element
/// floating-point evaluation order — SIMD lanes perform the identical
/// IEEE mul/add sequence per output element); the non-default modes
/// survive as parity oracles for tests and the `scalar` / `kernel`
/// bench rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// LUT QDQ + packed blocked GEMM with runtime-dispatched AVX2
    /// vector microkernels (default). Falls back to the blocked scalar
    /// path — bit-identically — where the ISA is unavailable.
    #[default]
    Simd,
    /// LUT QDQ + packed cache-blocked GEMM microkernels, scalar lanes
    /// only (the `MOR_NO_SIMD=1` oracle).
    Blocked,
    /// The original per-element/naive-triple-loop reference kernels.
    Scalar,
}

/// Which execution engine a [`Parallelism`] dispatches chunks on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Persistent worker pool with per-worker bounded deques and
    /// seeded bounded work stealing (the default).
    Steal,
    /// Persistent worker pool fed through one shared chunk queue — the
    /// previous scheduler, kept for the pool-vs-steal bench comparison.
    Pool,
    /// Scoped thread per chunk, spawned and joined inside every call —
    /// the original engine, kept for the pool-vs-spawn bench comparison
    /// and as a reference implementation.
    Spawn,
}

/// Parallelism configuration **and** pool handle: worker count, the
/// serial cutoff, and a shared reference to the persistent worker pool
/// that executes chunks. Cheap to clone (clones share the pool); the
/// pool shuts down when the last handle drops.
///
/// One handle is owned per run (`TrainerOptions::parallelism`, the
/// `Runtime` default) and threaded through the session API down to
/// every `fake_quantize` / GEMM call, replacing the former process-wide
/// scoped override.
#[derive(Debug, Clone)]
pub struct Parallelism {
    /// Number of concurrent chunk runners (1 = serial). The pool itself
    /// holds `threads - 1` workers; the calling thread is the last one.
    pub threads: usize,
    /// Workloads smaller than this many items run serially even when
    /// `threads > 1`.
    pub min_items: usize,
    engine: Engine,
    kernel: KernelMode,
    pool: Option<Arc<WorkerPool>>,
}

impl PartialEq for Parallelism {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
            && self.min_items == other.min_items
            && self.engine == other.engine
            && self.kernel == other.kernel
    }
}

impl Eq for Parallelism {}

impl Parallelism {
    /// Strictly serial execution (no pool behind it).
    pub fn serial() -> Parallelism {
        Parallelism {
            threads: 1,
            min_items: usize::MAX,
            engine: Engine::Steal,
            kernel: KernelMode::default(),
            pool: None,
        }
    }

    /// `n` chunk runners with the default serial cutoff.
    pub fn with_threads(n: usize) -> Parallelism {
        Parallelism::pooled(n, DEFAULT_MIN_ITEMS)
    }

    /// `threads` chunk runners with an explicit serial cutoff — the
    /// constructor tests and benches use to force tiny workloads onto
    /// the parallel path.
    pub fn pooled(threads: usize, min_items: usize) -> Parallelism {
        let threads = threads.max(1);
        let pool = (threads > 1).then(|| Arc::new(WorkerPool::new(threads)));
        Parallelism {
            threads,
            min_items,
            engine: Engine::Steal,
            kernel: KernelMode::default(),
            pool,
        }
    }

    /// Autodetect: `MOR_THREADS` env override, else the machine's
    /// available parallelism; `MOR_PAR_MIN_BLOCK` overrides the serial
    /// cutoff (the CI-tuning twin of the `--par-min-block` flag).
    ///
    /// # Panics
    /// When `MOR_THREADS`, `MOR_PAR_MIN_BLOCK`, `MOR_SCALAR_KERNELS` or
    /// `MOR_NO_SIMD` is set but malformed. A silent fallback here used
    /// to hide typos (`MOR_THREADS=O8` ran serial); misconfiguring the
    /// determinism matrix should be loud.
    pub fn auto() -> Parallelism {
        let env = crate::util::env::var("MOR_THREADS");
        let threads = match parse_mor_threads(env.as_deref()) {
            Ok(Some(n)) => n,
            Ok(None) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            Err(msg) => panic!("{msg}"),
        };
        let mut p = Parallelism::with_threads(threads);
        if let Some(n) = env_min_items() {
            p.min_items = n;
        }
        // MOR_SCALAR_KERNELS outranks MOR_NO_SIMD: the reference loops
        // are the stronger oracle.
        if env_scalar_kernels() {
            p.kernel = KernelMode::Scalar;
        } else if env_no_simd() {
            p.kernel = KernelMode::Blocked;
        }
        p
    }

    /// This handle switched to `engine` (building the pool if the new
    /// engine needs one, dropping it for the spawn engine).
    pub fn with_engine(mut self, engine: Engine) -> Parallelism {
        self.engine = engine;
        match engine {
            Engine::Spawn => self.pool = None,
            Engine::Pool | Engine::Steal => {
                if self.threads > 1 && self.pool.is_none() {
                    self.pool = Some(Arc::new(WorkerPool::new(self.threads)));
                }
            }
        }
        self
    }

    /// The engine this handle dispatches on.
    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// This handle switched to `kernel` mode. Results are bit-identical
    /// either way; [`KernelMode::Scalar`] keeps the original reference
    /// loops reachable as the parity oracle / bench baseline.
    pub fn with_kernel(mut self, kernel: KernelMode) -> Parallelism {
        self.kernel = kernel;
        self
    }

    /// The kernel implementation the numeric hot loops run under this
    /// handle.
    pub fn kernel(&self) -> KernelMode {
        self.kernel
    }

    /// The pool behind this handle (`None` for serial / spawn configs).
    pub fn worker_pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    /// Whether a workload of `items` units is worth fanning out.
    pub fn should_parallelize(&self, items: usize) -> bool {
        self.threads > 1 && items >= self.min_items
    }

    /// This config with the serial cutoff applied for an `items`-sized
    /// workload: unchanged when large enough, serial otherwise. The
    /// kernel mode survives gating — a scalar-oracle run stays scalar
    /// below the cutoff too, so bench baselines are not polluted.
    pub fn gate(&self, items: usize) -> Parallelism {
        if self.should_parallelize(items) {
            self.clone()
        } else {
            let mut s = Parallelism::serial();
            s.kernel = self.kernel;
            s
        }
    }
}

/// Parse a `MOR_THREADS` value: `Ok(None)` when unset, `Ok(Some(n))`
/// for a positive integer, and a clear error for everything else —
/// `0` (no workers is not a thread count; use 1 for serial), empty,
/// negative or non-numeric strings. Delegates to the shared strict
/// parser in [`crate::util::env`]; the messages are unchanged.
pub fn parse_mor_threads(raw: Option<&str>) -> Result<Option<usize>, String> {
    crate::util::env::parse_pos_int(
        raw,
        "MOR_THREADS ",
        "positive integer",
        "use 1 for serial, unset for autodetect",
    )
}

/// Parse a `--par-min-block` / `MOR_PAR_MIN_BLOCK` value with the same
/// strictness as [`parse_mor_threads`]: `Ok(None)` when unset,
/// `Ok(Some(n))` for a positive element count, and a clear error for
/// `0` (use `1` to parallelize everything), empty, negative or
/// non-numeric strings. The caller prefixes the flag/env name.
pub fn parse_par_min_block(raw: Option<&str>) -> Result<Option<usize>, String> {
    crate::util::env::parse_pos_int(
        raw,
        "",
        "positive element count",
        "a cutoff of 1 element parallelizes everything; unset for the default",
    )
}

/// The `MOR_PAR_MIN_BLOCK` serial-cutoff override, strictly parsed.
///
/// # Panics
/// When the variable is set but not a positive integer — CI tuning
/// typos must fail loudly, exactly like `MOR_THREADS`.
pub fn env_min_items() -> Option<usize> {
    let env = crate::util::env::var("MOR_PAR_MIN_BLOCK");
    match parse_par_min_block(env.as_deref()) {
        Ok(v) => v,
        Err(msg) => panic!("MOR_PAR_MIN_BLOCK {msg}"),
    }
}

/// Parse a `MOR_SCALAR_KERNELS` value with the usual strictness:
/// `Ok(None)` when unset, `Ok(Some(true/false))` for `1`/`0`, and a
/// clear error for anything else — a typo must not silently select a
/// kernel implementation.
pub fn parse_scalar_kernels(raw: Option<&str>) -> Result<Option<bool>, String> {
    crate::util::env::parse_bool01(raw, "MOR_SCALAR_KERNELS", "scalar oracle", "blocked kernels")
}

/// The `MOR_SCALAR_KERNELS` oracle override ([`Parallelism::auto`]):
/// `true` forces [`KernelMode::Scalar`] on auto-configured handles.
///
/// # Panics
/// When the variable is set but not `0`/`1`.
pub fn env_scalar_kernels() -> bool {
    let env = crate::util::env::var("MOR_SCALAR_KERNELS");
    match parse_scalar_kernels(env.as_deref()) {
        Ok(v) => v.unwrap_or(false),
        Err(msg) => panic!("{msg}"),
    }
}

/// Parse a `MOR_NO_SIMD` value with the usual strictness: `Ok(None)`
/// when unset, `Ok(Some(true/false))` for `1`/`0`, and a clear error
/// for anything else.
pub fn parse_no_simd(raw: Option<&str>) -> Result<Option<bool>, String> {
    crate::util::env::parse_bool01(raw, "MOR_NO_SIMD", "blocked-scalar oracle", "SIMD kernels")
}

/// The `MOR_NO_SIMD` oracle override ([`Parallelism::auto`]): `true`
/// pins auto-configured handles to [`KernelMode::Blocked`] — the same
/// kernel layer with every vector path disabled — mirroring
/// `MOR_SCALAR_KERNELS` one rung up the implementation ladder.
///
/// # Panics
/// When the variable is set but not `0`/`1`.
pub fn env_no_simd() -> bool {
    let env = crate::util::env::var("MOR_NO_SIMD");
    match parse_no_simd(env.as_deref()) {
        Ok(v) => v.unwrap_or(false),
        Err(msg) => panic!("{msg}"),
    }
}

static GLOBAL: Mutex<Option<Parallelism>> = Mutex::new(None);

/// Process-wide default parallelism, used by the no-argument entry
/// points (`fake_quantize`, `matmul`, `Recipe::apply`, ...) and as the
/// default handle for new `Runtime`s. Lazily initialized to
/// [`Parallelism::auto`]; the handle (and its pool) lives for the rest
/// of the process once created.
pub fn global() -> Parallelism {
    GLOBAL.lock().unwrap().get_or_insert_with(Parallelism::auto).clone()
}

/// Override the process-wide default (CLI `--threads`). Per-run
/// configuration should prefer an owned [`Parallelism`] handle threaded
/// through the session API over mutating this.
pub fn set_global(p: Parallelism) {
    *GLOBAL.lock().unwrap() = Some(p);
}

/// Contiguous chunk boundaries covering `0..n` with at most `parts`
/// chunks, every chunk non-empty. Deterministic for given (n, parts).
pub fn chunk_bounds(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let chunk = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

// ---------------------------------------------------------------------------
// The worker pool
// ---------------------------------------------------------------------------

/// A lifetime-erased chunk of work on the pool queue.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// How often an idle helper re-checks the queue while parked on its
/// completion latch (new submissions signal the workers' condvar, not
/// the helper's, so the helper polls at this bounded cadence).
const HELPER_RECHECK: std::time::Duration = std::time::Duration::from_micros(500);

/// Per-worker deque capacity. A batch submission that overflows a
/// deque spills to the shared injector instead of blocking, so the
/// bound caps steal-scan cost without ever deadlocking a submit.
const DEQUE_CAP: usize = 8;

/// Steal-victim selection is randomized so idle threads don't convoy on
/// the same victim, but **seeded per thread** so a given pool shape
/// scans victims in a reproducible order (results never depend on it —
/// chunks merge canonically — this keeps scheduling *behavior*
/// reproducible for debugging).
fn steal_seed(thread_index: usize) -> u64 {
    (thread_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1
}

fn xorshift64(s: &mut u64) -> u64 {
    let mut x = *s;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *s = x;
    x
}

struct PoolQueue {
    /// The shared injector: every [`Engine::Pool`] task, plus
    /// [`Engine::Steal`] overflow past [`DEQUE_CAP`] and single-task
    /// submissions ([`join2`]).
    tasks: VecDeque<Task>,
    shutdown: bool,
    spawned: usize,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    /// Signals sleeping workers that a task arrived (or shutdown was
    /// requested).
    work_cv: Condvar,
    /// One bounded deque per worker thread ([`Engine::Steal`] batch
    /// placement). Owners pop the front; thieves and the helping
    /// caller pop the back.
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently queued anywhere (injector + all deques). Lets
    /// scanners and the sleep path check "is there runnable work?"
    /// without sweeping every queue under locks.
    available: AtomicUsize,
    /// Workers currently blocked on `work_cv`. Submitters skip the
    /// notify handshake entirely while this is zero — the common case
    /// under load, which is what keeps the injector mutex off the
    /// steady-state submit path.
    sleepers: AtomicUsize,
}

impl PoolShared {
    /// Queue `task` on the engine-appropriate queue. `slot` picks the
    /// target deque for steal placement (`None` = shared injector).
    fn push(&self, task: Task, slot: Option<usize>) {
        // Count the task before it becomes poppable: a scanner that
        // wins the race then decrements a counter that was already
        // incremented, so `available` can overshoot transiently (a
        // bounded wasted scan) but never underflow.
        self.available.fetch_add(1, Ordering::SeqCst);
        let spilled = match slot {
            Some(si) if !self.deques.is_empty() => {
                let mut dq = self.deques[si % self.deques.len()].lock().unwrap();
                if dq.len() < DEQUE_CAP {
                    dq.push_back(task);
                    None
                } else {
                    Some(task)
                }
            }
            _ => Some(task),
        };
        if let Some(task) = spilled {
            self.queue.lock().unwrap().tasks.push_back(task);
        }
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Lock-bridge: taking (and dropping) the condvar mutex
            // orders this notify after any in-flight check-then-wait,
            // so a sleeper that saw `available == 0` is guaranteed to
            // be parked — and woken — rather than missing the signal.
            drop(self.queue.lock().unwrap());
            self.work_cv.notify_one();
        }
    }

    fn pop_injector(&self) -> Option<Task> {
        let task = self.queue.lock().unwrap().tasks.pop_front();
        if task.is_some() {
            self.available.fetch_sub(1, Ordering::SeqCst);
        }
        task
    }

    fn pop_deque(&self, di: usize, back: bool) -> Option<Task> {
        let mut dq = self.deques[di].lock().unwrap();
        let task = if back { dq.pop_back() } else { dq.pop_front() };
        drop(dq);
        if task.is_some() {
            self.available.fetch_sub(1, Ordering::SeqCst);
        }
        task
    }

    /// One full scan for runnable work: own deque front (owners only),
    /// then the injector, then bounded randomized stealing from victim
    /// deque backs, then one deterministic sweep so a lone runnable
    /// task cannot hide from an unlucky victim sequence.
    fn find_task(&self, own: Option<usize>, rng: &mut u64) -> Option<Task> {
        if let Some(wi) = own {
            if let Some(task) = self.pop_deque(wi, false) {
                return Some(task);
            }
        }
        if let Some(task) = self.pop_injector() {
            return Some(task);
        }
        let n = self.deques.len();
        if n == 0 || self.available.load(Ordering::SeqCst) == 0 {
            return None;
        }
        for _ in 0..2 * n {
            let victim = (xorshift64(rng) as usize) % n;
            if Some(victim) == own {
                continue;
            }
            if let Some(task) = self.pop_deque(victim, true) {
                return Some(task);
            }
        }
        for victim in 0..n {
            if Some(victim) == own {
                continue;
            }
            if let Some(task) = self.pop_deque(victim, true) {
                return Some(task);
            }
        }
        None
    }

    /// Park until work exists or shutdown. Returns `false` on shutdown.
    fn wait_for_work(&self) -> bool {
        let mut q = self.queue.lock().unwrap();
        loop {
            if q.shutdown {
                return false;
            }
            // Register as a sleeper BEFORE the availability check: a
            // submitter that bumps `available` after our check will see
            // `sleepers > 0` and take the notify handshake.
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            if self.available.load(Ordering::SeqCst) > 0 {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return true;
            }
            q = self.work_cv.wait(q).unwrap();
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// The persistent worker set behind a [`Parallelism`] handle: lazily
/// spawned threads draining per-worker deques (with bounded stealing)
/// and a shared injector queue.
///
/// * **Lazy**: no thread exists until the first chunk is submitted.
/// * **Panic-safe**: chunks are run under `catch_unwind`; a panicking
///   chunk — including one another worker stole — poisons nothing, the
///   payload is re-raised on the caller and the worker survives to
///   serve the next call.
/// * **Clean shutdown**: dropping the pool (the last `Parallelism`
///   clone) flags shutdown, wakes every worker and joins them all — no
///   leaked threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Live worker count; each worker holds a guard that decrements on
    /// any exit path. Outlives the pool via [`WorkerPool::alive_probe`].
    alive: Arc<AtomicUsize>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Worker threads this pool spawns: the calling thread always runs
    /// chunks too, so a `threads`-way config needs `threads - 1`.
    workers: usize,
    /// Rotates the starting deque of each batch's round-robin
    /// placement, so concurrent nested batches spread across all
    /// deques instead of convoying on deque 0.
    rr_base: AtomicUsize,
    /// Lock-free fast path for [`WorkerPool::ensure_spawned`] once the
    /// one-time spawn has happened.
    started: std::sync::atomic::AtomicBool,
}

impl WorkerPool {
    /// A pool sized for `threads`-way parallelism (`threads - 1` worker
    /// threads + the calling thread). Workers spawn on first use; their
    /// deques exist up front so submission never races spawning.
    pub fn new(threads: usize) -> WorkerPool {
        let workers = threads.saturating_sub(1).max(1);
        WorkerPool {
            shared: Arc::new(PoolShared {
                queue: Mutex::new(PoolQueue {
                    tasks: VecDeque::new(),
                    shutdown: false,
                    spawned: 0,
                }),
                work_cv: Condvar::new(),
                deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
                available: AtomicUsize::new(0),
                sleepers: AtomicUsize::new(0),
            }),
            alive: Arc::new(AtomicUsize::new(0)),
            handles: Mutex::new(Vec::new()),
            workers,
            rr_base: AtomicUsize::new(0),
            started: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Worker threads spawned so far (0 until the first submit).
    pub fn spawned_workers(&self) -> usize {
        self.shared.queue.lock().unwrap().spawned
    }

    /// Worker threads currently alive.
    pub fn alive_workers(&self) -> usize {
        self.alive.load(Ordering::Acquire)
    }

    /// A counter handle that outlives the pool: reads 0 once every
    /// worker has exited. The shutdown-on-drop observability hook.
    pub fn alive_probe(&self) -> Arc<AtomicUsize> {
        self.alive.clone()
    }

    fn ensure_spawned(&self) {
        if self.started.load(Ordering::Acquire) {
            return;
        }
        let to_spawn = {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown || q.spawned >= self.workers {
                return;
            }
            let first = q.spawned;
            q.spawned = self.workers;
            first..self.workers
        };
        self.started.store(true, Ordering::Release);
        let mut handles = self.handles.lock().unwrap();
        for wi in to_spawn {
            self.alive.fetch_add(1, Ordering::AcqRel);
            let shared = self.shared.clone();
            let alive = self.alive.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("mor-pool-{wi}"))
                .spawn(move || worker_loop(shared, alive, wi));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(_) => {
                    // Must not unwind here: submit() runs inside
                    // run_all, whose queued tasks borrow the caller's
                    // frame. Fewer workers is always safe — the
                    // calling thread drains its own chunks regardless.
                    self.alive.fetch_sub(1, Ordering::AcqRel);
                    break;
                }
            }
        }
    }

    /// Queue one task. `slot` selects [`Engine::Steal`] deque placement
    /// (`None` = the shared injector, the [`Engine::Pool`] path).
    /// Callers dispatching a batch run [`WorkerPool::ensure_spawned`]
    /// once up front (`run_all`, `join2`) rather than paying the check
    /// per task.
    fn submit(&self, task: Task, slot: Option<usize>) {
        self.shared.push(task, slot);
    }

    /// Run runnable chunks on the calling thread until `comp`
    /// completes. This is what keeps nested parallel sections live: a
    /// caller waiting on its own chunks executes whatever work is
    /// runnable (its chunks, or chunks of the call it is nested
    /// inside), stealing from worker deques like any idle thread.
    fn help_until(&self, comp: &Completion) {
        // The caller is "thread index workers" for steal-seed purposes:
        // distinct from every worker, deterministic per pool shape.
        let mut rng = steal_seed(self.workers);
        loop {
            {
                let remaining = comp.remaining.lock().unwrap();
                if *remaining == 0 {
                    return;
                }
            }
            match self.shared.find_task(None, &mut rng) {
                Some(task) => task(),
                None => {
                    let remaining = comp.remaining.lock().unwrap();
                    if *remaining == 0 {
                        return;
                    }
                    // No runnable work + chunks outstanding: they are
                    // being executed by other threads. `finish_one`
                    // notifies under the `remaining` lock, so this
                    // check-then-wait cannot miss the last completion.
                    // The timeout bounds a second race this condvar
                    // cannot see: tasks *submitted* (by nested sections
                    // on other threads) while we sleep only signal
                    // `work_cv`, so re-scan the queues at a fixed
                    // cadence rather than idling until our own call
                    // completes.
                    let waited = comp
                        .done_cv
                        .wait_timeout(remaining, HELPER_RECHECK)
                        .unwrap();
                    drop(waited);
                }
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("spawned", &self.spawned_workers())
            .finish()
    }
}

fn worker_loop(shared: Arc<PoolShared>, alive: Arc<AtomicUsize>, wi: usize) {
    // Decrement the live count on every exit path. Tasks catch their
    // own panics, so an unwind out of `task()` should be impossible;
    // the guard makes the count right even if one slips through.
    struct AliveGuard(Arc<AtomicUsize>);
    impl Drop for AliveGuard {
        fn drop(&mut self) {
            self.0.fetch_sub(1, Ordering::AcqRel);
        }
    }
    let _guard = AliveGuard(alive);
    let mut rng = steal_seed(wi);
    loop {
        match shared.find_task(Some(wi), &mut rng) {
            Some(task) => task(),
            None => {
                if !shared.wait_for_work() {
                    return;
                }
            }
        }
    }
}

/// Completion latch for one parallel call: open when every chunk has
/// run, carrying the first panic payload if any chunk panicked.
struct Completion {
    remaining: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Completion {
    fn new(n: usize) -> Completion {
        Completion { remaining: Mutex::new(n), done_cv: Condvar::new(), panic: Mutex::new(None) }
    }

    fn finish_one(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.done_cv.notify_all();
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = self.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.panic.lock().unwrap().take()
    }
}

/// Erase a task's borrow lifetime so it can cross the pool's `'static`
/// queue.
///
/// # Safety
/// The caller must not return — normally or by unwinding — until the
/// task has finished running, so every borrow the task holds outlives
/// its execution. [`run_all`] enforces this with a completion latch.
unsafe fn erase<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(task) }
}

/// Drive `tasks` to completion on `pool`: every task but the first is
/// fed to the scheduler (round-robin across per-worker deques for
/// [`Engine::Steal`], the shared injector for [`Engine::Pool`]), the
/// first runs on the calling thread, then the caller helps drain
/// runnable work until the latch opens. `comp` must have been created
/// with `tasks.len()` pending counts and every task must call
/// `comp.finish_one()` exactly once (and never unwind — wrappers catch
/// panics into the latch).
fn run_all(
    pool: &WorkerPool,
    engine: Engine,
    mut tasks: Vec<Box<dyn FnOnce() + Send + '_>>,
    comp: &Completion,
) {
    pool.ensure_spawned();
    // Each batch starts its round-robin at a rotated base so
    // concurrent (nested) batches spread across all deques instead of
    // all hammering deque 0. Placement never affects results.
    let base = pool.rr_base.fetch_add(1, Ordering::Relaxed);
    let first = tasks.remove(0);
    for (i, task) in tasks.into_iter().enumerate() {
        let slot = match engine {
            Engine::Steal => Some(base.wrapping_add(i)),
            _ => None,
        };
        // Safety: `help_until` below blocks this frame until every
        // submitted task has run (the latch only opens after the last
        // `finish_one`), so the borrows inside `task` stay valid.
        pool.submit(unsafe { erase(task) }, slot);
    }
    first();
    pool.help_until(comp);
}

// ---------------------------------------------------------------------------
// Parallel primitives
// ---------------------------------------------------------------------------

/// Map `f` over `0..n`, returning results in index order. Chunks are
/// contiguous, so the concatenation order is independent of scheduling.
pub fn par_map<R, F>(cfg: &Parallelism, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if cfg.threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let bounds = chunk_bounds(n, cfg.threads);
    if bounds.len() <= 1 {
        return (0..n).map(f).collect();
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Spawn, _) | (_, None) => par_map_spawn(&bounds, &f),
        (engine, Some(pool)) => par_map_pool(pool, engine, &bounds, &f),
    }
}

fn par_map_pool<R, F>(pool: &WorkerPool, engine: Engine, bounds: &[(usize, usize)], f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let comp = Completion::new(bounds.len());
    let results: Vec<Mutex<Option<Vec<R>>>> = bounds.iter().map(|_| Mutex::new(None)).collect();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = bounds
        .iter()
        .enumerate()
        .map(|(ci, &(lo, hi))| {
            let (comp, results) = (&comp, &results);
            Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| {
                    (lo..hi).map(|i| f(i)).collect::<Vec<R>>()
                }));
                match out {
                    Ok(v) => *results[ci].lock().unwrap() = Some(v),
                    Err(payload) => comp.record_panic(payload),
                }
                comp.finish_one();
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_all(pool, engine, tasks, &comp);
    if let Some(payload) = comp.take_panic() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .flat_map(|slot| {
            slot.into_inner().unwrap().expect("pool chunk completed without a result")
        })
        .collect()
}

/// Map `f` over `0..weights.len()`, one pool task per item (no chunk
/// batching), **submitting heaviest items first**: the scheduler sees
/// item `i`'s cost estimate `weights[i]` and dispatches in descending
/// weight order (ties broken by index, so submission order is fully
/// deterministic). Results still come back in index order, and each
/// `f(i)` is an independent computation, so the output is bit-identical
/// to the serial loop for any thread count — only tail latency changes.
///
/// This is the sweep scheduler: a mixed-size batch no longer strands a
/// giant tensor behind a queue of tiny ones, and items may themselves
/// run chunk-parallel on the same pool (nested sections are
/// deadlock-free via help-while-waiting).
pub fn par_map_weighted<R, F>(cfg: &Parallelism, weights: &[usize], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let n = weights.len();
    if cfg.threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let order = weighted_order(weights);
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Spawn, _) | (_, None) => par_map_weighted_spawn(cfg.threads, &order, n, &f),
        (engine, Some(pool)) => par_map_weighted_pool(pool, engine, &order, n, &f),
    }
}

/// Largest-first submission order for a weighted batch: indices sorted
/// by descending weight, ties broken by ascending index so the order is
/// total and deterministic. This is the scheduling heart of
/// [`par_map_weighted`], exported so run-granularity clients (the fleet
/// scheduler in `coordinator::scheduler`) dispatch whole training runs
/// with exactly the same no-giant-stranded-behind-tinies rule the sweep
/// items get — without touching the deque/steal machinery.
pub fn weighted_order(weights: &[usize]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(weights[i]), i));
    order
}

fn par_map_weighted_pool<R, F>(
    pool: &WorkerPool,
    engine: Engine,
    order: &[usize],
    n: usize,
    f: &F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let comp = Completion::new(order.len());
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = order
        .iter()
        .map(|&i| {
            let (comp, results) = (&comp, &results);
            Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                match out {
                    Ok(v) => *results[i].lock().unwrap() = Some(v),
                    Err(payload) => comp.record_panic(payload),
                }
                comp.finish_one();
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_all(pool, engine, tasks, &comp);
    if let Some(payload) = comp.take_panic() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .map(|slot| {
            slot.into_inner().unwrap().expect("weighted item completed without a result")
        })
        .collect()
}

/// Spawn-engine weighted map: at most `threads` scoped threads (the
/// same cap `par_map_spawn` gets from its chunk count — never one
/// thread per item), pulling items off the descending-weight `order`
/// through a shared cursor so the heaviest items still start first.
fn par_map_weighted_spawn<R, F>(threads: usize, order: &[usize], n: usize, f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.max(1).min(order.len()) {
            let (results, cursor) = (&results, &cursor);
            s.spawn(move || loop {
                let k = cursor.fetch_add(1, Ordering::SeqCst);
                let Some(&i) = order.get(k) else { return };
                *results[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    results
        .into_iter()
        .map(|slot| {
            slot.into_inner().unwrap().expect("weighted item completed without a result")
        })
        .collect()
}

/// The original scoped-thread engine ([`Engine::Spawn`]): one thread
/// per chunk, spawned and joined inside the call.
fn par_map_spawn<R, F>(bounds: &[(usize, usize)], f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let chunks: Vec<Vec<R>> = std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(lo, hi)| s.spawn(move || (lo..hi).map(|i| f(i)).collect::<Vec<R>>()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    });
    chunks.into_iter().flatten().collect()
}

/// Run `f` once per panel over disjoint contiguous row-panels of `out`
/// (row-major, rows of `row_size` elements), returning the per-panel
/// results in panel order. `bounds` must be ascending, non-overlapping
/// and exactly cover `out.len() / row_size` rows. Panel `i` receives
/// `(i, (row_lo, row_hi), &mut out[row_lo*row_size .. row_hi*row_size])`.
pub fn par_panels<R, F>(
    cfg: &Parallelism,
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    debug_assert_eq!(
        bounds.last().map(|b| b.1 * row_size).unwrap_or(0),
        out.len(),
        "panel bounds must cover the output"
    );
    if bounds.len() <= 1 || cfg.threads <= 1 {
        return bounds
            .iter()
            .enumerate()
            .map(|(pi, &(r0, r1))| f(pi, (r0, r1), &mut out[r0 * row_size..r1 * row_size]))
            .collect();
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Spawn, _) | (_, None) => par_panels_spawn(bounds, row_size, out, &f),
        (engine, Some(pool)) => par_panels_pool(pool, engine, bounds, row_size, out, &f),
    }
}

fn par_panels_pool<R, F>(
    pool: &WorkerPool,
    engine: Engine,
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: &F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    let comp = Completion::new(bounds.len());
    let results: Vec<Mutex<Option<R>>> = bounds.iter().map(|_| Mutex::new(None)).collect();
    let mut panels = Vec::with_capacity(bounds.len());
    let mut rest: &mut [f32] = out;
    for &(r0, r1) in bounds {
        let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_size);
        panels.push(panel);
        rest = tail;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = panels
        .into_iter()
        .enumerate()
        .map(|(pi, panel)| {
            let (comp, results) = (&comp, &results);
            let (r0, r1) = bounds[pi];
            Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(pi, (r0, r1), panel)));
                match out {
                    Ok(v) => *results[pi].lock().unwrap() = Some(v),
                    Err(payload) => comp.record_panic(payload),
                }
                comp.finish_one();
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_all(pool, engine, tasks, &comp);
    if let Some(payload) = comp.take_panic() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("pool panel completed without a result"))
        .collect()
}

fn par_panels_spawn<R, F>(
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: &F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = out;
        let mut handles = Vec::with_capacity(bounds.len());
        for (pi, &(r0, r1)) in bounds.iter().enumerate() {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_size);
            rest = tail;
            handles.push(s.spawn(move || f(pi, (r0, r1), panel)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    })
}

/// Run two independent computations, `fb` on a pool worker (or a
/// scoped thread for the spawn engine) overlapped with `fa` on the
/// calling thread. The pipeline-level building block: overlapping whole
/// quantizations, transposes and GEMMs that share no data. Results come
/// back in argument order and each closure is an independent
/// computation, so callers stay bit-deterministic by construction.
pub fn join2<A, B, FA, FB>(cfg: &Parallelism, fa: FA, fb: FB) -> (A, B)
where
    B: Send,
    FA: FnOnce() -> A,
    FB: FnOnce() -> B + Send,
{
    // Deterministic fault injection: when the calling thread armed a
    // worker panic (`--faults panic:worker@step=N`), the offloaded
    // closure panics instead of computing — on the pool path this
    // exercises the real catch_unwind → record_panic → resume_unwind
    // machinery; serial and spawn paths panic in the equivalent place.
    if crate::faults::take_worker_panic() {
        let fb = move || -> B {
            let _keep = fb;
            panic!("{}", crate::faults::WORKER_PANIC_MSG);
        };
        return join2_impl(cfg, fa, fb);
    }
    join2_impl(cfg, fa, fb)
}

fn join2_impl<A, B, FA, FB>(cfg: &Parallelism, fa: FA, fb: FB) -> (A, B)
where
    B: Send,
    FA: FnOnce() -> A,
    FB: FnOnce() -> B + Send,
{
    if cfg.threads <= 1 {
        let a = fa();
        let b = fb();
        return (a, b);
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Spawn, _) | (_, None) => std::thread::scope(|s| {
            let hb = s.spawn(fb);
            let a = fa();
            let b = hb.join().unwrap_or_else(|payload| resume_unwind(payload));
            (a, b)
        }),
        (_, Some(pool)) => {
            pool.ensure_spawned();
            let comp = Completion::new(1);
            let slot: Mutex<Option<B>> = Mutex::new(None);
            {
                let (comp, slot) = (&comp, &slot);
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    match catch_unwind(AssertUnwindSafe(fb)) {
                        Ok(v) => *slot.lock().unwrap() = Some(v),
                        Err(payload) => comp.record_panic(payload),
                    }
                    comp.finish_one();
                });
                // A lone task gains nothing from deque placement; the
                // shared injector serves both pooled engines here.
                // Safety: `help_until` below blocks until the task ran.
                pool.submit(unsafe { erase(task) }, None);
            }
            let a = catch_unwind(AssertUnwindSafe(fa));
            pool.help_until(&comp);
            if let Some(payload) = comp.take_panic() {
                resume_unwind(payload);
            }
            let a = a.unwrap_or_else(|payload| resume_unwind(payload));
            let b = slot.into_inner().unwrap().expect("join2 task completed without a result");
            (a, b)
        }
    }
}

/// A shared view over a mutable slice for writes to **provably disjoint
/// index sets** from worker threads — the write sink for partition
/// blocks, whose regions interleave row fragments and cannot be split
/// into contiguous panels.
///
/// Safety contract (callers): no index is written by more than one
/// concurrent closure, and the slice is not read until the parallel
/// section completes. Partition disjointness is exactly the
/// `prop_blocks_tile_exactly` invariant in `quant::partition`.
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointWriter { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i < len`, and no concurrent write to the same `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v };
    }

    /// A mutable view of the contiguous range `start..start + len` —
    /// the slice-kernel entry point (`crate::kernels` QDQ segments
    /// write whole block-row fragments at once instead of per-element).
    ///
    /// # Safety
    /// `start + len <= self.len()`, and no concurrent access (read or
    /// write) to any index in the range for the lifetime of the
    /// returned slice. Partition-block disjointness gives exactly this.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start.checked_add(len).is_some_and(|end| end <= self.len));
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

/// Convenience: chunk boundaries in *row* space for panels aligned to
/// `unit` rows (GEMM block-row panels): units `0..n_units` are chunked,
/// then converted to row ranges capped at `rows`.
pub fn unit_panel_bounds(
    n_units: usize,
    unit: usize,
    rows: usize,
    parts: usize,
) -> Vec<(usize, usize)> {
    chunk_bounds(n_units, parts)
        .into_iter()
        .map(|(u0, u1)| (u0 * unit, (u1 * unit).min(rows)))
        .collect()
}

/// The four engine configurations the serial-vs-parallel benches
/// compare, in cost-model order: no parallelism, per-call thread
/// spawning, the shared-queue pool, and the stealing pool (default).
/// Fresh handles per call so each bench row owns (and drops) its own
/// pool.
pub fn engine_comparison_rows() -> Vec<(&'static str, Parallelism)> {
    vec![
        ("serial", Parallelism::serial()),
        ("spawn", Parallelism::auto().with_engine(Engine::Spawn)),
        ("pool", Parallelism::auto().with_engine(Engine::Pool)),
        ("steal", Parallelism::auto()),
    ]
}

/// The kernel-implementation rows the perf benches compare at the
/// default engine/thread configuration: the original scalar reference
/// loops, the table-driven/blocked kernel layer with scalar lanes, and
/// the runtime-dispatched SIMD layer. Bit-identical results by
/// contract — only the wall clock differs.
pub fn kernel_comparison_rows() -> Vec<(&'static str, Parallelism)> {
    vec![
        ("scalar", Parallelism::auto().with_kernel(KernelMode::Scalar)),
        ("kernel", Parallelism::auto().with_kernel(KernelMode::Blocked)),
        ("simd", Parallelism::auto().with_kernel(KernelMode::Simd)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_bounds_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 1000] {
            for parts in [1usize, 2, 3, 8, 64] {
                let b = chunk_bounds(n, parts);
                if n == 0 {
                    assert!(b.is_empty());
                    continue;
                }
                assert!(b.len() <= parts.max(1));
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                assert!(b.iter().all(|(lo, hi)| lo < hi));
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let cfg = Parallelism::pooled(4, 1);
        let out = par_map(&cfg, 100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        let serial = par_map(&Parallelism::serial(), 100, |i| i * i);
        assert_eq!(out, serial);
    }

    #[test]
    fn par_panels_writes_disjoint_rows() {
        let cfg = Parallelism::pooled(3, 1);
        let mut out = vec![0.0f32; 10 * 4];
        let bounds = chunk_bounds(10, 3);
        let sums = par_panels(&cfg, &bounds, 4, &mut out, |_pi, (r0, r1), panel| {
            for (ri, r) in (r0..r1).enumerate() {
                for c in 0..4 {
                    panel[ri * 4 + c] = (r * 4 + c) as f32;
                }
            }
            r1 - r0
        });
        assert_eq!(sums.iter().sum::<usize>(), 10);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn disjoint_writer_from_threads() {
        let mut data = vec![0u64; 1000];
        {
            let w = DisjointWriter::new(&mut data);
            let cfg = Parallelism::pooled(8, 1);
            par_map(&cfg, 1000, |i| unsafe { w.write(i, i as u64 + 1) });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn gate_applies_cutoff() {
        let cfg = Parallelism::pooled(8, 100);
        assert_eq!(cfg.gate(99), Parallelism::serial());
        assert_eq!(cfg.gate(100), cfg);
        assert!(!Parallelism::serial().should_parallelize(usize::MAX));
        assert!(Parallelism::with_threads(1).threads == 1);
        assert!(Parallelism::with_threads(1).worker_pool().is_none());
    }

    #[test]
    fn unit_panels_cap_ragged_rows() {
        // 3 block-rows of 4 rows each over 10 total rows.
        let b = unit_panel_bounds(3, 4, 10, 2);
        assert_eq!(b.last().unwrap().1, 10);
        assert_eq!(b[0].0, 0);
        let total: usize = b.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn global_settable() {
        // Note: global state; only assert set/get coherence.
        set_global(Parallelism::pooled(3, 7));
        assert_eq!(global().threads, 3);
        set_global(Parallelism::auto());
        assert!(global().threads >= 1);
    }

    #[test]
    fn mor_threads_parsing_is_strict() {
        assert_eq!(parse_mor_threads(None), Ok(None));
        assert_eq!(parse_mor_threads(Some("4")), Ok(Some(4)));
        assert_eq!(parse_mor_threads(Some(" 13 ")), Ok(Some(13)));
        assert!(parse_mor_threads(Some("0")).is_err());
        assert!(parse_mor_threads(Some("-2")).is_err());
        assert!(parse_mor_threads(Some("eight")).is_err());
        assert!(parse_mor_threads(Some("")).is_err());
        assert!(parse_mor_threads(Some("  ")).is_err());
    }

    #[test]
    fn kernel_mode_defaults_rides_gate_and_compares() {
        let cfg = Parallelism::pooled(4, 100);
        assert_eq!(cfg.kernel(), KernelMode::Simd);
        let scalar = cfg.clone().with_kernel(KernelMode::Scalar);
        assert_eq!(scalar.kernel(), KernelMode::Scalar);
        assert_ne!(scalar, cfg, "kernel mode must participate in Eq");
        // Gating below the cutoff keeps the oracle mode.
        assert_eq!(scalar.gate(1).kernel(), KernelMode::Scalar);
        assert_eq!(scalar.gate(1).threads, 1);
        assert_eq!(cfg.gate(1_000_000).kernel(), KernelMode::Simd);
        let blocked = cfg.clone().with_kernel(KernelMode::Blocked);
        assert_eq!(blocked.gate(1).kernel(), KernelMode::Blocked);
        // The bench rows cover all three modes.
        let rows = kernel_comparison_rows();
        let labels: Vec<&str> = rows.iter().map(|(l, _)| *l).collect();
        assert_eq!(labels, ["scalar", "kernel", "simd"]);
        assert_eq!(rows[0].1.kernel(), KernelMode::Scalar);
        assert_eq!(rows[1].1.kernel(), KernelMode::Blocked);
        assert_eq!(rows[2].1.kernel(), KernelMode::Simd);
    }

    #[test]
    fn scalar_kernels_parsing_is_strict() {
        assert_eq!(parse_scalar_kernels(None), Ok(None));
        assert_eq!(parse_scalar_kernels(Some("1")), Ok(Some(true)));
        assert_eq!(parse_scalar_kernels(Some(" 0 ")), Ok(Some(false)));
        assert!(parse_scalar_kernels(Some("yes")).is_err());
        assert!(parse_scalar_kernels(Some("")).is_err());
    }

    #[test]
    fn no_simd_parsing_is_strict() {
        assert_eq!(parse_no_simd(None), Ok(None));
        assert_eq!(parse_no_simd(Some("1")), Ok(Some(true)));
        assert_eq!(parse_no_simd(Some(" 0 ")), Ok(Some(false)));
        assert!(parse_no_simd(Some("true")).is_err());
        assert!(parse_no_simd(Some("")).is_err());
        assert!(parse_no_simd(Some("  ")).is_err());
    }

    #[test]
    fn disjoint_writer_slices_from_threads() {
        let mut data = vec![0f32; 64];
        {
            let w = DisjointWriter::new(&mut data);
            let cfg = Parallelism::pooled(4, 1);
            par_map(&cfg, 8, |i| {
                let seg = unsafe { w.slice_mut(i * 8, 8) };
                for (j, v) in seg.iter_mut().enumerate() {
                    *v = (i * 8 + j) as f32;
                }
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn par_min_block_parsing_is_strict() {
        assert_eq!(parse_par_min_block(None), Ok(None));
        assert_eq!(parse_par_min_block(Some("8192")), Ok(Some(8192)));
        assert_eq!(parse_par_min_block(Some(" 1 ")), Ok(Some(1)));
        assert!(parse_par_min_block(Some("0")).is_err());
        assert!(parse_par_min_block(Some("-1")).is_err());
        assert!(parse_par_min_block(Some("4k")).is_err());
        assert!(parse_par_min_block(Some("")).is_err());
        assert!(parse_par_min_block(Some("  ")).is_err());
    }

    #[test]
    fn default_engine_is_steal_and_rows_cover_all_engines() {
        assert_eq!(Parallelism::pooled(4, 1).engine(), Engine::Steal);
        assert_eq!(Parallelism::serial().engine(), Engine::Steal);
        let rows = engine_comparison_rows();
        assert_eq!(rows.len(), 4);
        let labels: Vec<&str> = rows.iter().map(|(l, _)| *l).collect();
        assert_eq!(labels, ["serial", "spawn", "pool", "steal"]);
        assert_eq!(rows[2].1.engine(), Engine::Pool);
        assert_eq!(rows[3].1.engine(), Engine::Steal);
    }

    #[test]
    fn steal_engine_matches_shared_queue_engine() {
        // Same chunking, different placement: results must be
        // bit-identical between the deque/steal scheduler and the
        // legacy shared-queue pool.
        for threads in [2, 3, 13] {
            let steal = Parallelism::pooled(threads, 1);
            let shared = Parallelism::pooled(threads, 1).with_engine(Engine::Pool);
            let a = par_map(&steal, 257, |i| (i as f32).sin());
            let b = par_map(&shared, 257, |i| (i as f32).sin());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn weighted_map_preserves_index_order() {
        let cfg = Parallelism::pooled(4, 1);
        // Ascending weights: submission order is exactly reversed from
        // index order, results must still come back by index.
        let weights: Vec<usize> = (1..=40).collect();
        let out = par_map_weighted(&cfg, &weights, |i| i * 3);
        assert_eq!(out, (0..40).map(|i| i * 3).collect::<Vec<_>>());
        // Serial path agrees.
        let serial = par_map_weighted(&Parallelism::serial(), &weights, |i| i * 3);
        assert_eq!(out, serial);
        // Spawn engine agrees.
        let spawn = Parallelism::pooled(4, 1).with_engine(Engine::Spawn);
        assert_eq!(out, par_map_weighted(&spawn, &weights, |i| i * 3));
        // Tied weights keep index order deterministically.
        let tied = vec![7usize; 9];
        assert_eq!(par_map_weighted(&cfg, &tied, |i| i), (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_order_is_descending_and_tie_stable() {
        assert_eq!(weighted_order(&[]), Vec::<usize>::new());
        assert_eq!(weighted_order(&[5]), vec![0]);
        // Heaviest first; equal weights keep ascending index order.
        assert_eq!(weighted_order(&[1, 9, 4, 9, 2]), vec![1, 3, 2, 4, 0]);
        let tied = weighted_order(&[7; 6]);
        assert_eq!(tied, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_map_overflows_deques_safely() {
        // Far more items than DEQUE_CAP * workers: the bounded deques
        // must spill to the injector, and every item must still run
        // exactly once.
        let cfg = Parallelism::pooled(2, 1);
        let weights: Vec<usize> = (0..200).map(|i| i % 13).collect();
        let out = par_map_weighted(&cfg, &weights, |i| i + 1);
        assert_eq!(out, (1..=200).collect::<Vec<_>>());
    }

    #[test]
    fn stolen_chunk_panic_propagates_and_pool_survives() {
        // With 3-way parallelism and many single-item tasks, the
        // panicking task is queued on a worker deque and may be run by
        // its owner, a stealing worker, or the helping caller — on
        // every path the payload must reach the caller.
        let cfg = Parallelism::pooled(3, 1);
        assert_eq!(cfg.engine(), Engine::Steal);
        let weights: Vec<usize> = vec![1; 48];
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_map_weighted(&cfg, &weights, |i| {
                if i == 47 {
                    panic!("intentional stolen-chunk panic at {i}");
                }
                i
            })
        }));
        assert!(r.is_err(), "stolen-chunk panic must reach the caller");
        // The pool stays serviceable afterwards.
        let v = par_map(&cfg, 64, |i| i * 2);
        assert_eq!(v, (0..64).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(cfg.worker_pool().unwrap().alive_workers(), 2);
    }

    #[test]
    fn nested_weighted_map_shares_the_pool() {
        // Sweep items that are themselves chunk-parallel on the same
        // pool: the help-while-waiting protocol must keep this live.
        let cfg = Parallelism::pooled(3, 1);
        let weights = [30usize, 2, 17, 1, 9];
        let out = par_map_weighted(&cfg, &weights, |i| {
            par_map(&cfg, weights[i], move |j| i * 100 + j).iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..weights.len()).map(|i| (0..weights[i]).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pool_is_reused_across_calls() {
        let cfg = Parallelism::pooled(4, 1);
        assert_eq!(cfg.worker_pool().unwrap().spawned_workers(), 0, "pool must be lazy");
        let a = par_map(&cfg, 64, |i| i + 1);
        let spawned = cfg.worker_pool().unwrap().spawned_workers();
        assert_eq!(spawned, 3, "4-way parallelism = caller + 3 workers");
        let b = par_map(&cfg, 64, |i| i + 1);
        assert_eq!(a, b);
        assert_eq!(
            cfg.worker_pool().unwrap().spawned_workers(),
            spawned,
            "second call must reuse the pool, not respawn"
        );
        assert_eq!(cfg.worker_pool().unwrap().alive_workers(), spawned);
        // Clones share the same pool.
        let clone = cfg.clone();
        let _ = par_map(&clone, 64, |i| i);
        assert_eq!(clone.worker_pool().unwrap().spawned_workers(), spawned);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let cfg = Parallelism::pooled(4, 1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_map(&cfg, 100, |i| {
                if i == 57 {
                    panic!("intentional test panic at {i}");
                }
                i
            })
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
        // The pool stays serviceable: same workers, correct results.
        let v = par_map(&cfg, 100, |i| i * 2);
        assert_eq!(v, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(cfg.worker_pool().unwrap().alive_workers(), 3);
        // Panel-path panics propagate too.
        let mut out = vec![0.0f32; 12];
        let bounds = chunk_bounds(12, 4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_panels(&cfg, &bounds, 1, &mut out, |pi, _b, _panel| {
                if pi == 2 {
                    panic!("intentional panel panic");
                }
                pi
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn pool_shuts_down_on_drop() {
        let cfg = Parallelism::pooled(4, 1);
        let probe = cfg.worker_pool().unwrap().alive_probe();
        let _ = par_map(&cfg, 64, |i| i);
        assert_eq!(probe.load(Ordering::Acquire), 3);
        let clone = cfg.clone();
        drop(cfg);
        assert_eq!(probe.load(Ordering::Acquire), 3, "clone keeps the pool alive");
        drop(clone);
        assert_eq!(probe.load(Ordering::Acquire), 0, "workers leaked past drop");
    }

    #[test]
    fn nested_par_map_does_not_deadlock() {
        let cfg = Parallelism::pooled(3, 1);
        let out = par_map(&cfg, 6, |i| {
            let inner = par_map(&cfg, 5, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..6).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn join2_overlaps_and_propagates() {
        let cfg = Parallelism::pooled(4, 1);
        let (a, b) = join2(&cfg, || 40 + 2, || "side".to_string());
        assert_eq!((a, b.as_str()), (42, "side"));
        let (a, b) = join2(&Parallelism::serial(), || 1, || 2);
        assert_eq!((a, b), (1, 2));
        let r = catch_unwind(AssertUnwindSafe(|| {
            join2(&cfg, || 7, || -> usize { panic!("intentional join2 panic") })
        }));
        assert!(r.is_err(), "side-branch panic must reach the caller");
        // Pool still fine afterwards.
        let (a, b) = join2(&cfg, || 1, || 2);
        assert_eq!((a, b), (1, 2));
    }

    #[test]
    fn spawn_engine_matches_pool_engine() {
        let pool_cfg = Parallelism::pooled(4, 1);
        let spawn_cfg = Parallelism::pooled(4, 1).with_engine(Engine::Spawn);
        assert!(spawn_cfg.worker_pool().is_none());
        let a = par_map(&pool_cfg, 257, |i| (i as f32).sin());
        let b = par_map(&spawn_cfg, 257, |i| (i as f32).sin());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
