//! Parallel chunked execution engine for the quantization/analysis
//! pipeline: a std-only **persistent worker pool** with deterministic
//! block-order chunking.
//!
//! Design contract, relied on by every caller and enforced by
//! `rust/tests/parallel_equivalence.rs`: results are **bit-identical to
//! the serial path** regardless of thread count. The primitives only
//! split *independent* work items (partition blocks, GEMM row panels,
//! tensors of a sweep) across threads; all reductions (error-accumulator
//! merges, MAC counters) happen on the caller side in canonical item
//! order after the parallel section. Floating-point evaluation order per
//! output element therefore never changes.
//!
//! Work distribution is static: item range `0..n` is cut into at most
//! `threads` contiguous chunks. No work stealing between chunks, no
//! locks on the hot path, no allocation inside workers beyond their own
//! result vectors.
//!
//! ## The worker pool
//!
//! A [`Parallelism`] handle owns (a shared reference to) one
//! [`WorkerPool`]: `threads - 1` lazily-spawned worker threads fed
//! through a chunk queue, with the calling thread always executing the
//! first chunk itself and then helping drain the queue until its call
//! completes. The help-while-waiting step is what makes *nested*
//! parallel sections (pipeline-level overlap via [`join2`] around
//! chunk-parallel quantizations) deadlock-free: a waiting caller never
//! idles while runnable chunks exist.
//!
//! Clones of a handle share the pool, so consecutive `par_map` /
//! `par_panels` calls reuse the same workers instead of paying a
//! spawn/join wave per call (the old scoped-thread engine is retained
//! behind [`Engine::Spawn`] for benchmark comparison). Worker panics
//! are caught, forwarded, and re-raised on the calling thread; dropping
//! the last handle shuts the pool down and joins every worker.

use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Elements below which tensor-granularity operations stay serial (the
/// "min-block-size cutoff": dispatching chunks for a 64x64 tensor costs
/// more than the quantization itself).
pub const DEFAULT_MIN_ITEMS: usize = 8192;

/// Which execution engine a [`Parallelism`] dispatches chunks on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Persistent worker pool (the default): chunks go through the
    /// pool's queue, workers are reused across calls.
    Pool,
    /// Scoped thread per chunk, spawned and joined inside every call —
    /// the original engine, kept for the pool-vs-spawn bench comparison
    /// and as a reference implementation.
    Spawn,
}

/// Parallelism configuration **and** pool handle: worker count, the
/// serial cutoff, and a shared reference to the persistent worker pool
/// that executes chunks. Cheap to clone (clones share the pool); the
/// pool shuts down when the last handle drops.
///
/// One handle is owned per run (`TrainerOptions::parallelism`, the
/// `Runtime` default) and threaded through the session API down to
/// every `fake_quantize` / GEMM call, replacing the former process-wide
/// scoped override.
#[derive(Debug, Clone)]
pub struct Parallelism {
    /// Number of concurrent chunk runners (1 = serial). The pool itself
    /// holds `threads - 1` workers; the calling thread is the last one.
    pub threads: usize,
    /// Workloads smaller than this many items run serially even when
    /// `threads > 1`.
    pub min_items: usize,
    engine: Engine,
    pool: Option<Arc<WorkerPool>>,
}

impl PartialEq for Parallelism {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
            && self.min_items == other.min_items
            && self.engine == other.engine
    }
}

impl Eq for Parallelism {}

impl Parallelism {
    /// Strictly serial execution (no pool behind it).
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1, min_items: usize::MAX, engine: Engine::Pool, pool: None }
    }

    /// `n` chunk runners with the default serial cutoff.
    pub fn with_threads(n: usize) -> Parallelism {
        Parallelism::pooled(n, DEFAULT_MIN_ITEMS)
    }

    /// `threads` chunk runners with an explicit serial cutoff — the
    /// constructor tests and benches use to force tiny workloads onto
    /// the parallel path.
    pub fn pooled(threads: usize, min_items: usize) -> Parallelism {
        let threads = threads.max(1);
        let pool = (threads > 1).then(|| Arc::new(WorkerPool::new(threads)));
        Parallelism { threads, min_items, engine: Engine::Pool, pool }
    }

    /// Autodetect: `MOR_THREADS` env override, else the machine's
    /// available parallelism.
    ///
    /// # Panics
    /// When `MOR_THREADS` is set but not a positive integer. A silent
    /// fallback here used to hide typos (`MOR_THREADS=O8` ran serial);
    /// misconfiguring the determinism matrix should be loud.
    pub fn auto() -> Parallelism {
        let env = std::env::var("MOR_THREADS").ok();
        let threads = match parse_mor_threads(env.as_deref()) {
            Ok(Some(n)) => n,
            Ok(None) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            Err(msg) => panic!("{msg}"),
        };
        Parallelism::with_threads(threads)
    }

    /// This handle switched to `engine` (building the pool if the pool
    /// engine now needs one, dropping it for the spawn engine).
    pub fn with_engine(mut self, engine: Engine) -> Parallelism {
        self.engine = engine;
        match engine {
            Engine::Spawn => self.pool = None,
            Engine::Pool => {
                if self.threads > 1 && self.pool.is_none() {
                    self.pool = Some(Arc::new(WorkerPool::new(self.threads)));
                }
            }
        }
        self
    }

    /// The engine this handle dispatches on.
    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// The pool behind this handle (`None` for serial / spawn configs).
    pub fn worker_pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    /// Whether a workload of `items` units is worth fanning out.
    pub fn should_parallelize(&self, items: usize) -> bool {
        self.threads > 1 && items >= self.min_items
    }

    /// This config with the serial cutoff applied for an `items`-sized
    /// workload: unchanged when large enough, serial otherwise.
    pub fn gate(&self, items: usize) -> Parallelism {
        if self.should_parallelize(items) {
            self.clone()
        } else {
            Parallelism::serial()
        }
    }
}

/// Parse a `MOR_THREADS` value: `Ok(None)` when unset, `Ok(Some(n))`
/// for a positive integer, and a clear error for everything else —
/// `0` (no workers is not a thread count; use 1 for serial), empty,
/// negative or non-numeric strings.
pub fn parse_mor_threads(raw: Option<&str>) -> Result<Option<usize>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err(
            "MOR_THREADS is set but empty; use a positive integer or unset it".to_string()
        );
    }
    match trimmed.parse::<usize>() {
        Ok(0) => Err(
            "MOR_THREADS must be >= 1 (use 1 for serial, unset for autodetect)".to_string()
        ),
        Ok(n) => Ok(Some(n)),
        Err(_) => Err(format!("MOR_THREADS must be a positive integer, got {trimmed:?}")),
    }
}

static GLOBAL: Mutex<Option<Parallelism>> = Mutex::new(None);

/// Process-wide default parallelism, used by the no-argument entry
/// points (`fake_quantize`, `matmul`, `Recipe::apply`, ...) and as the
/// default handle for new `Runtime`s. Lazily initialized to
/// [`Parallelism::auto`]; the handle (and its pool) lives for the rest
/// of the process once created.
pub fn global() -> Parallelism {
    GLOBAL.lock().unwrap().get_or_insert_with(Parallelism::auto).clone()
}

/// Override the process-wide default (CLI `--threads`). Per-run
/// configuration should prefer an owned [`Parallelism`] handle threaded
/// through the session API over mutating this.
pub fn set_global(p: Parallelism) {
    *GLOBAL.lock().unwrap() = Some(p);
}

/// Contiguous chunk boundaries covering `0..n` with at most `parts`
/// chunks, every chunk non-empty. Deterministic for given (n, parts).
pub fn chunk_bounds(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let chunk = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

// ---------------------------------------------------------------------------
// The worker pool
// ---------------------------------------------------------------------------

/// A lifetime-erased chunk of work on the pool queue.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// How often an idle helper re-checks the queue while parked on its
/// completion latch (new submissions signal the workers' condvar, not
/// the helper's, so the helper polls at this bounded cadence).
const HELPER_RECHECK: std::time::Duration = std::time::Duration::from_micros(500);

struct PoolQueue {
    tasks: VecDeque<Task>,
    shutdown: bool,
    spawned: usize,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    /// Signals workers that a task arrived (or shutdown was requested).
    work_cv: Condvar,
}

/// The persistent worker set behind a [`Parallelism`] handle: lazily
/// spawned threads draining a shared chunk queue.
///
/// * **Lazy**: no thread exists until the first chunk is submitted.
/// * **Panic-safe**: chunks are run under `catch_unwind`; a panicking
///   chunk poisons nothing, the payload is re-raised on the caller and
///   the worker survives to serve the next call.
/// * **Clean shutdown**: dropping the pool (the last `Parallelism`
///   clone) flags shutdown, wakes every worker and joins them all — no
///   leaked threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Live worker count; each worker holds a guard that decrements on
    /// any exit path. Outlives the pool via [`WorkerPool::alive_probe`].
    alive: Arc<AtomicUsize>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Worker threads this pool spawns: the calling thread always runs
    /// chunks too, so a `threads`-way config needs `threads - 1`.
    workers: usize,
    /// Lock-free fast path for [`WorkerPool::ensure_spawned`] once the
    /// one-time spawn has happened.
    started: std::sync::atomic::AtomicBool,
}

impl WorkerPool {
    /// A pool sized for `threads`-way parallelism (`threads - 1` worker
    /// threads + the calling thread). Workers spawn on first use.
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool {
            shared: Arc::new(PoolShared {
                queue: Mutex::new(PoolQueue {
                    tasks: VecDeque::new(),
                    shutdown: false,
                    spawned: 0,
                }),
                work_cv: Condvar::new(),
            }),
            alive: Arc::new(AtomicUsize::new(0)),
            handles: Mutex::new(Vec::new()),
            workers: threads.saturating_sub(1).max(1),
            started: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Worker threads spawned so far (0 until the first submit).
    pub fn spawned_workers(&self) -> usize {
        self.shared.queue.lock().unwrap().spawned
    }

    /// Worker threads currently alive.
    pub fn alive_workers(&self) -> usize {
        self.alive.load(Ordering::Acquire)
    }

    /// A counter handle that outlives the pool: reads 0 once every
    /// worker has exited. The shutdown-on-drop observability hook.
    pub fn alive_probe(&self) -> Arc<AtomicUsize> {
        self.alive.clone()
    }

    fn ensure_spawned(&self) {
        if self.started.load(Ordering::Acquire) {
            return;
        }
        let to_spawn = {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown || q.spawned >= self.workers {
                return;
            }
            let first = q.spawned;
            q.spawned = self.workers;
            first..self.workers
        };
        self.started.store(true, Ordering::Release);
        let mut handles = self.handles.lock().unwrap();
        for wi in to_spawn {
            self.alive.fetch_add(1, Ordering::AcqRel);
            let shared = self.shared.clone();
            let alive = self.alive.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("mor-pool-{wi}"))
                .spawn(move || worker_loop(shared, alive));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(_) => {
                    // Must not unwind here: submit() runs inside
                    // run_all, whose queued tasks borrow the caller's
                    // frame. Fewer workers is always safe — the
                    // calling thread drains its own chunks regardless.
                    self.alive.fetch_sub(1, Ordering::AcqRel);
                    break;
                }
            }
        }
    }

    /// Queue one task. Callers dispatching a batch run
    /// [`WorkerPool::ensure_spawned`] once up front (`run_all`,
    /// `join2`) rather than paying the check per task.
    fn submit(&self, task: Task) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.tasks.push_back(task);
        }
        self.shared.work_cv.notify_one();
    }

    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().tasks.pop_front()
    }

    /// Run queued chunks on the calling thread until `comp` completes.
    /// This is what keeps nested parallel sections live: a caller
    /// waiting on its own chunks executes whatever work is runnable
    /// (its chunks, or chunks of the call it is nested inside).
    fn help_until(&self, comp: &Completion) {
        loop {
            {
                let remaining = comp.remaining.lock().unwrap();
                if *remaining == 0 {
                    return;
                }
            }
            match self.try_pop() {
                Some(task) => task(),
                None => {
                    let remaining = comp.remaining.lock().unwrap();
                    if *remaining == 0 {
                        return;
                    }
                    // Queue empty + chunks outstanding: they are being
                    // executed by other threads. `finish_one` notifies
                    // under the `remaining` lock, so this check-then-
                    // wait cannot miss the last completion. The timeout
                    // bounds a second race this condvar cannot see:
                    // tasks *submitted* (by nested sections on other
                    // threads) while we sleep only signal `work_cv`, so
                    // re-check the queue at a fixed cadence rather than
                    // idling until our own call completes.
                    let waited = comp
                        .done_cv
                        .wait_timeout(remaining, HELPER_RECHECK)
                        .unwrap();
                    drop(waited);
                }
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("spawned", &self.spawned_workers())
            .finish()
    }
}

fn worker_loop(shared: Arc<PoolShared>, alive: Arc<AtomicUsize>) {
    // Decrement the live count on every exit path. Tasks catch their
    // own panics, so an unwind out of `task()` should be impossible;
    // the guard makes the count right even if one slips through.
    struct AliveGuard(Arc<AtomicUsize>);
    impl Drop for AliveGuard {
        fn drop(&mut self) {
            self.0.fetch_sub(1, Ordering::AcqRel);
        }
    }
    let _guard = AliveGuard(alive);
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(task) = q.tasks.pop_front() {
                    break Some(task);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        match task {
            Some(task) => task(),
            None => return,
        }
    }
}

/// Completion latch for one parallel call: open when every chunk has
/// run, carrying the first panic payload if any chunk panicked.
struct Completion {
    remaining: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Completion {
    fn new(n: usize) -> Completion {
        Completion { remaining: Mutex::new(n), done_cv: Condvar::new(), panic: Mutex::new(None) }
    }

    fn finish_one(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.done_cv.notify_all();
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = self.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.panic.lock().unwrap().take()
    }
}

/// Erase a task's borrow lifetime so it can cross the pool's `'static`
/// queue.
///
/// # Safety
/// The caller must not return — normally or by unwinding — until the
/// task has finished running, so every borrow the task holds outlives
/// its execution. [`run_all`] enforces this with a completion latch.
unsafe fn erase<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(task) }
}

/// Drive `tasks` to completion on `pool`: every task but the first is
/// fed to the chunk queue, the first runs on the calling thread, then
/// the caller helps drain the queue until the latch opens. `comp` must
/// have been created with `tasks.len()` pending counts and every task
/// must call `comp.finish_one()` exactly once (and never unwind —
/// wrappers catch panics into the latch).
fn run_all(pool: &WorkerPool, mut tasks: Vec<Box<dyn FnOnce() + Send + '_>>, comp: &Completion) {
    pool.ensure_spawned();
    let first = tasks.remove(0);
    for task in tasks {
        // Safety: `help_until` below blocks this frame until every
        // submitted task has run (the latch only opens after the last
        // `finish_one`), so the borrows inside `task` stay valid.
        pool.submit(unsafe { erase(task) });
    }
    first();
    pool.help_until(comp);
}

// ---------------------------------------------------------------------------
// Parallel primitives
// ---------------------------------------------------------------------------

/// Map `f` over `0..n`, returning results in index order. Chunks are
/// contiguous, so the concatenation order is independent of scheduling.
pub fn par_map<R, F>(cfg: &Parallelism, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if cfg.threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let bounds = chunk_bounds(n, cfg.threads);
    if bounds.len() <= 1 {
        return (0..n).map(f).collect();
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Pool, Some(pool)) => par_map_pool(pool, &bounds, &f),
        _ => par_map_spawn(&bounds, &f),
    }
}

fn par_map_pool<R, F>(pool: &WorkerPool, bounds: &[(usize, usize)], f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let comp = Completion::new(bounds.len());
    let results: Vec<Mutex<Option<Vec<R>>>> = bounds.iter().map(|_| Mutex::new(None)).collect();
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = bounds
        .iter()
        .enumerate()
        .map(|(ci, &(lo, hi))| {
            let (comp, results) = (&comp, &results);
            Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| {
                    (lo..hi).map(|i| f(i)).collect::<Vec<R>>()
                }));
                match out {
                    Ok(v) => *results[ci].lock().unwrap() = Some(v),
                    Err(payload) => comp.record_panic(payload),
                }
                comp.finish_one();
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_all(pool, tasks, &comp);
    if let Some(payload) = comp.take_panic() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .flat_map(|slot| {
            slot.into_inner().unwrap().expect("pool chunk completed without a result")
        })
        .collect()
}

/// The original scoped-thread engine ([`Engine::Spawn`]): one thread
/// per chunk, spawned and joined inside the call.
fn par_map_spawn<R, F>(bounds: &[(usize, usize)], f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let chunks: Vec<Vec<R>> = std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(lo, hi)| s.spawn(move || (lo..hi).map(|i| f(i)).collect::<Vec<R>>()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    });
    chunks.into_iter().flatten().collect()
}

/// Run `f` once per panel over disjoint contiguous row-panels of `out`
/// (row-major, rows of `row_size` elements), returning the per-panel
/// results in panel order. `bounds` must be ascending, non-overlapping
/// and exactly cover `out.len() / row_size` rows. Panel `i` receives
/// `(i, (row_lo, row_hi), &mut out[row_lo*row_size .. row_hi*row_size])`.
pub fn par_panels<R, F>(
    cfg: &Parallelism,
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    debug_assert_eq!(
        bounds.last().map(|b| b.1 * row_size).unwrap_or(0),
        out.len(),
        "panel bounds must cover the output"
    );
    if bounds.len() <= 1 || cfg.threads <= 1 {
        return bounds
            .iter()
            .enumerate()
            .map(|(pi, &(r0, r1))| f(pi, (r0, r1), &mut out[r0 * row_size..r1 * row_size]))
            .collect();
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Pool, Some(pool)) => par_panels_pool(pool, bounds, row_size, out, &f),
        _ => par_panels_spawn(bounds, row_size, out, &f),
    }
}

fn par_panels_pool<R, F>(
    pool: &WorkerPool,
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: &F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    let comp = Completion::new(bounds.len());
    let results: Vec<Mutex<Option<R>>> = bounds.iter().map(|_| Mutex::new(None)).collect();
    let mut panels = Vec::with_capacity(bounds.len());
    let mut rest: &mut [f32] = out;
    for &(r0, r1) in bounds {
        let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_size);
        panels.push(panel);
        rest = tail;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = panels
        .into_iter()
        .enumerate()
        .map(|(pi, panel)| {
            let (comp, results) = (&comp, &results);
            let (r0, r1) = bounds[pi];
            Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(pi, (r0, r1), panel)));
                match out {
                    Ok(v) => *results[pi].lock().unwrap() = Some(v),
                    Err(payload) => comp.record_panic(payload),
                }
                comp.finish_one();
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_all(pool, tasks, &comp);
    if let Some(payload) = comp.take_panic() {
        resume_unwind(payload);
    }
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("pool panel completed without a result"))
        .collect()
}

fn par_panels_spawn<R, F>(
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: &F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = out;
        let mut handles = Vec::with_capacity(bounds.len());
        for (pi, &(r0, r1)) in bounds.iter().enumerate() {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_size);
            rest = tail;
            handles.push(s.spawn(move || f(pi, (r0, r1), panel)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    })
}

/// Run two independent computations, `fb` on a pool worker (or a
/// scoped thread for the spawn engine) overlapped with `fa` on the
/// calling thread. The pipeline-level building block: overlapping whole
/// quantizations, transposes and GEMMs that share no data. Results come
/// back in argument order and each closure is an independent
/// computation, so callers stay bit-deterministic by construction.
pub fn join2<A, B, FA, FB>(cfg: &Parallelism, fa: FA, fb: FB) -> (A, B)
where
    B: Send,
    FA: FnOnce() -> A,
    FB: FnOnce() -> B + Send,
{
    if cfg.threads <= 1 {
        let a = fa();
        let b = fb();
        return (a, b);
    }
    match (cfg.engine, cfg.pool.as_deref()) {
        (Engine::Pool, Some(pool)) => {
            pool.ensure_spawned();
            let comp = Completion::new(1);
            let slot: Mutex<Option<B>> = Mutex::new(None);
            {
                let (comp, slot) = (&comp, &slot);
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    match catch_unwind(AssertUnwindSafe(fb)) {
                        Ok(v) => *slot.lock().unwrap() = Some(v),
                        Err(payload) => comp.record_panic(payload),
                    }
                    comp.finish_one();
                });
                // Safety: `help_until` below blocks until the task ran.
                pool.submit(unsafe { erase(task) });
            }
            let a = catch_unwind(AssertUnwindSafe(fa));
            pool.help_until(&comp);
            if let Some(payload) = comp.take_panic() {
                resume_unwind(payload);
            }
            let a = a.unwrap_or_else(|payload| resume_unwind(payload));
            let b = slot.into_inner().unwrap().expect("join2 task completed without a result");
            (a, b)
        }
        _ => std::thread::scope(|s| {
            let hb = s.spawn(fb);
            let a = fa();
            let b = hb.join().unwrap_or_else(|payload| resume_unwind(payload));
            (a, b)
        }),
    }
}

/// A shared view over a mutable slice for writes to **provably disjoint
/// index sets** from worker threads — the write sink for partition
/// blocks, whose regions interleave row fragments and cannot be split
/// into contiguous panels.
///
/// Safety contract (callers): no index is written by more than one
/// concurrent closure, and the slice is not read until the parallel
/// section completes. Partition disjointness is exactly the
/// `prop_blocks_tile_exactly` invariant in `quant::partition`.
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointWriter { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i < len`, and no concurrent write to the same `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v };
    }
}

/// Convenience: chunk boundaries in *row* space for panels aligned to
/// `unit` rows (GEMM block-row panels): units `0..n_units` are chunked,
/// then converted to row ranges capped at `rows`.
pub fn unit_panel_bounds(
    n_units: usize,
    unit: usize,
    rows: usize,
    parts: usize,
) -> Vec<(usize, usize)> {
    chunk_bounds(n_units, parts)
        .into_iter()
        .map(|(u0, u1)| (u0 * unit, (u1 * unit).min(rows)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_bounds_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 1000] {
            for parts in [1usize, 2, 3, 8, 64] {
                let b = chunk_bounds(n, parts);
                if n == 0 {
                    assert!(b.is_empty());
                    continue;
                }
                assert!(b.len() <= parts.max(1));
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                assert!(b.iter().all(|(lo, hi)| lo < hi));
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let cfg = Parallelism::pooled(4, 1);
        let out = par_map(&cfg, 100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        let serial = par_map(&Parallelism::serial(), 100, |i| i * i);
        assert_eq!(out, serial);
    }

    #[test]
    fn par_panels_writes_disjoint_rows() {
        let cfg = Parallelism::pooled(3, 1);
        let mut out = vec![0.0f32; 10 * 4];
        let bounds = chunk_bounds(10, 3);
        let sums = par_panels(&cfg, &bounds, 4, &mut out, |_pi, (r0, r1), panel| {
            for (ri, r) in (r0..r1).enumerate() {
                for c in 0..4 {
                    panel[ri * 4 + c] = (r * 4 + c) as f32;
                }
            }
            r1 - r0
        });
        assert_eq!(sums.iter().sum::<usize>(), 10);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn disjoint_writer_from_threads() {
        let mut data = vec![0u64; 1000];
        {
            let w = DisjointWriter::new(&mut data);
            let cfg = Parallelism::pooled(8, 1);
            par_map(&cfg, 1000, |i| unsafe { w.write(i, i as u64 + 1) });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn gate_applies_cutoff() {
        let cfg = Parallelism::pooled(8, 100);
        assert_eq!(cfg.gate(99), Parallelism::serial());
        assert_eq!(cfg.gate(100), cfg);
        assert!(!Parallelism::serial().should_parallelize(usize::MAX));
        assert!(Parallelism::with_threads(1).threads == 1);
        assert!(Parallelism::with_threads(1).worker_pool().is_none());
    }

    #[test]
    fn unit_panels_cap_ragged_rows() {
        // 3 block-rows of 4 rows each over 10 total rows.
        let b = unit_panel_bounds(3, 4, 10, 2);
        assert_eq!(b.last().unwrap().1, 10);
        assert_eq!(b[0].0, 0);
        let total: usize = b.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn global_settable() {
        // Note: global state; only assert set/get coherence.
        set_global(Parallelism::pooled(3, 7));
        assert_eq!(global().threads, 3);
        set_global(Parallelism::auto());
        assert!(global().threads >= 1);
    }

    #[test]
    fn mor_threads_parsing_is_strict() {
        assert_eq!(parse_mor_threads(None), Ok(None));
        assert_eq!(parse_mor_threads(Some("4")), Ok(Some(4)));
        assert_eq!(parse_mor_threads(Some(" 13 ")), Ok(Some(13)));
        assert!(parse_mor_threads(Some("0")).is_err());
        assert!(parse_mor_threads(Some("-2")).is_err());
        assert!(parse_mor_threads(Some("eight")).is_err());
        assert!(parse_mor_threads(Some("")).is_err());
        assert!(parse_mor_threads(Some("  ")).is_err());
    }

    #[test]
    fn pool_is_reused_across_calls() {
        let cfg = Parallelism::pooled(4, 1);
        assert_eq!(cfg.worker_pool().unwrap().spawned_workers(), 0, "pool must be lazy");
        let a = par_map(&cfg, 64, |i| i + 1);
        let spawned = cfg.worker_pool().unwrap().spawned_workers();
        assert_eq!(spawned, 3, "4-way parallelism = caller + 3 workers");
        let b = par_map(&cfg, 64, |i| i + 1);
        assert_eq!(a, b);
        assert_eq!(
            cfg.worker_pool().unwrap().spawned_workers(),
            spawned,
            "second call must reuse the pool, not respawn"
        );
        assert_eq!(cfg.worker_pool().unwrap().alive_workers(), spawned);
        // Clones share the same pool.
        let clone = cfg.clone();
        let _ = par_map(&clone, 64, |i| i);
        assert_eq!(clone.worker_pool().unwrap().spawned_workers(), spawned);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let cfg = Parallelism::pooled(4, 1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_map(&cfg, 100, |i| {
                if i == 57 {
                    panic!("intentional test panic at {i}");
                }
                i
            })
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
        // The pool stays serviceable: same workers, correct results.
        let v = par_map(&cfg, 100, |i| i * 2);
        assert_eq!(v, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(cfg.worker_pool().unwrap().alive_workers(), 3);
        // Panel-path panics propagate too.
        let mut out = vec![0.0f32; 12];
        let bounds = chunk_bounds(12, 4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_panels(&cfg, &bounds, 1, &mut out, |pi, _b, _panel| {
                if pi == 2 {
                    panic!("intentional panel panic");
                }
                pi
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn pool_shuts_down_on_drop() {
        let cfg = Parallelism::pooled(4, 1);
        let probe = cfg.worker_pool().unwrap().alive_probe();
        let _ = par_map(&cfg, 64, |i| i);
        assert_eq!(probe.load(Ordering::Acquire), 3);
        let clone = cfg.clone();
        drop(cfg);
        assert_eq!(probe.load(Ordering::Acquire), 3, "clone keeps the pool alive");
        drop(clone);
        assert_eq!(probe.load(Ordering::Acquire), 0, "workers leaked past drop");
    }

    #[test]
    fn nested_par_map_does_not_deadlock() {
        let cfg = Parallelism::pooled(3, 1);
        let out = par_map(&cfg, 6, |i| {
            let inner = par_map(&cfg, 5, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..6).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn join2_overlaps_and_propagates() {
        let cfg = Parallelism::pooled(4, 1);
        let (a, b) = join2(&cfg, || 40 + 2, || "side".to_string());
        assert_eq!((a, b.as_str()), (42, "side"));
        let (a, b) = join2(&Parallelism::serial(), || 1, || 2);
        assert_eq!((a, b), (1, 2));
        let r = catch_unwind(AssertUnwindSafe(|| {
            join2(&cfg, || 7, || -> usize { panic!("intentional join2 panic") })
        }));
        assert!(r.is_err(), "side-branch panic must reach the caller");
        // Pool still fine afterwards.
        let (a, b) = join2(&cfg, || 1, || 2);
        assert_eq!((a, b), (1, 2));
    }

    #[test]
    fn spawn_engine_matches_pool_engine() {
        let pool_cfg = Parallelism::pooled(4, 1);
        let spawn_cfg = Parallelism::pooled(4, 1).with_engine(Engine::Spawn);
        assert!(spawn_cfg.worker_pool().is_none());
        let a = par_map(&pool_cfg, 257, |i| (i as f32).sin());
        let b = par_map(&spawn_cfg, 257, |i| (i as f32).sin());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
