//! Parallel chunked execution engine for the quantization/analysis
//! pipeline: a std-only scoped-thread worker layer with **deterministic
//! block-order chunking**.
//!
//! Design contract, relied on by every caller and enforced by
//! `rust/tests/parallel_equivalence.rs`: results are **bit-identical to
//! the serial path** regardless of thread count. The primitives only
//! split *independent* work items (partition blocks, GEMM row panels,
//! tensors of a sweep) across threads; all reductions (error-accumulator
//! merges, MAC counters) happen on the caller side in canonical item
//! order after the parallel section. Floating-point evaluation order per
//! output element therefore never changes.
//!
//! Work distribution is static: item range `0..n` is cut into at most
//! `threads` contiguous chunks. No work stealing, no locks on the hot
//! path, no allocation inside workers beyond their own result vectors.

use std::marker::PhantomData;
use std::sync::Mutex;

/// Elements below which tensor-granularity operations stay serial (the
/// "min-block-size cutoff": spawning threads for a 64x64 tensor costs
/// more than the quantization itself).
pub const DEFAULT_MIN_ITEMS: usize = 8192;

/// Parallelism configuration: worker count plus the serial cutoff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Number of worker threads (1 = serial).
    pub threads: usize,
    /// Workloads smaller than this many items run serially even when
    /// `threads > 1`.
    pub min_items: usize,
}

impl Parallelism {
    /// Strictly serial execution.
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1, min_items: usize::MAX }
    }

    /// `n` worker threads with the default serial cutoff.
    pub fn with_threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1), min_items: DEFAULT_MIN_ITEMS }
    }

    /// Autodetect: `MOR_THREADS` env override, else the machine's
    /// available parallelism.
    pub fn auto() -> Parallelism {
        let threads = std::env::var("MOR_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|n| *n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Parallelism::with_threads(threads)
    }

    /// Whether a workload of `items` units is worth fanning out.
    pub fn should_parallelize(&self, items: usize) -> bool {
        self.threads > 1 && items >= self.min_items
    }

    /// This config with the serial cutoff applied for an `items`-sized
    /// workload: unchanged when large enough, serial otherwise.
    pub fn gate(&self, items: usize) -> Parallelism {
        if self.should_parallelize(items) {
            *self
        } else {
            Parallelism::serial()
        }
    }
}

static GLOBAL: Mutex<Option<Parallelism>> = Mutex::new(None);

/// Process-wide default parallelism, used by the public hot-path entry
/// points (`fake_quantize`, `matmul`, `Recipe::apply`, ...). Lazily
/// initialized to [`Parallelism::auto`].
pub fn global() -> Parallelism {
    let mut g = GLOBAL.lock().unwrap();
    *g.get_or_insert_with(Parallelism::auto)
}

/// Override the process-wide default (CLI `--threads`, benches, tests).
pub fn set_global(p: Parallelism) {
    *GLOBAL.lock().unwrap() = Some(p);
}

/// Contiguous chunk boundaries covering `0..n` with at most `parts`
/// chunks, every chunk non-empty. Deterministic for given (n, parts).
pub fn chunk_bounds(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let chunk = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Map `f` over `0..n`, returning results in index order. Chunks are
/// contiguous, so the concatenation order is independent of scheduling.
pub fn par_map<R, F>(cfg: Parallelism, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if cfg.threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let bounds = chunk_bounds(n, cfg.threads);
    let chunks: Vec<Vec<R>> = std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(lo, hi)| s.spawn(move || (lo..hi).map(f).collect::<Vec<R>>()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    });
    chunks.into_iter().flatten().collect()
}

/// Run `f` once per panel over disjoint contiguous row-panels of `out`
/// (row-major, rows of `row_size` elements), returning the per-panel
/// results in panel order. `bounds` must be ascending, non-overlapping
/// and exactly cover `out.len() / row_size` rows. Panel `i` receives
/// `(i, (row_lo, row_hi), &mut out[row_lo*row_size .. row_hi*row_size])`.
pub fn par_panels<R, F>(
    bounds: &[(usize, usize)],
    row_size: usize,
    out: &mut [f32],
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, (usize, usize), &mut [f32]) -> R + Sync,
{
    debug_assert_eq!(
        bounds.last().map(|b| b.1 * row_size).unwrap_or(0),
        out.len(),
        "panel bounds must cover the output"
    );
    if bounds.len() <= 1 {
        return bounds
            .iter()
            .map(|&(r0, r1)| f(0, (r0, r1), &mut out[r0 * row_size..r1 * row_size]))
            .collect();
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest: &mut [f32] = out;
        let mut handles = Vec::with_capacity(bounds.len());
        for (pi, &(r0, r1)) in bounds.iter().enumerate() {
            let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_size);
            rest = tail;
            handles.push(s.spawn(move || f(pi, (r0, r1), panel)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("mor worker thread panicked"))
            .collect()
    })
}

/// A shared view over a mutable slice for writes to **provably disjoint
/// index sets** from worker threads — the write sink for partition
/// blocks, whose regions interleave row fragments and cannot be split
/// into contiguous panels.
///
/// Safety contract (callers): no index is written by more than one
/// concurrent closure, and the slice is not read until the parallel
/// section completes. Partition disjointness is exactly the
/// `prop_blocks_tile_exactly` invariant in `quant::partition`.
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointWriter { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i < len`, and no concurrent write to the same `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v };
    }
}

/// Convenience: chunk boundaries in *row* space for panels aligned to
/// `unit` rows (GEMM block-row panels): units `0..n_units` are chunked,
/// then converted to row ranges capped at `rows`.
pub fn unit_panel_bounds(n_units: usize, unit: usize, rows: usize, parts: usize) -> Vec<(usize, usize)> {
    chunk_bounds(n_units, parts)
        .into_iter()
        .map(|(u0, u1)| (u0 * unit, (u1 * unit).min(rows)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_bounds_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 1000] {
            for parts in [1usize, 2, 3, 8, 64] {
                let b = chunk_bounds(n, parts);
                if n == 0 {
                    assert!(b.is_empty());
                    continue;
                }
                assert!(b.len() <= parts.max(1));
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                assert!(b.iter().all(|(lo, hi)| lo < hi));
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let cfg = Parallelism { threads: 4, min_items: 1 };
        let out = par_map(cfg, 100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        let serial = par_map(Parallelism::serial(), 100, |i| i * i);
        assert_eq!(out, serial);
    }

    #[test]
    fn par_panels_writes_disjoint_rows() {
        let mut out = vec![0.0f32; 10 * 4];
        let bounds = chunk_bounds(10, 3);
        let sums = par_panels(&bounds, 4, &mut out, |_pi, (r0, r1), panel| {
            for (ri, r) in (r0..r1).enumerate() {
                for c in 0..4 {
                    panel[ri * 4 + c] = (r * 4 + c) as f32;
                }
            }
            r1 - r0
        });
        assert_eq!(sums.iter().sum::<usize>(), 10);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn disjoint_writer_from_threads() {
        let mut data = vec![0u64; 1000];
        {
            let w = DisjointWriter::new(&mut data);
            let cfg = Parallelism { threads: 8, min_items: 1 };
            par_map(cfg, 1000, |i| unsafe { w.write(i, i as u64 + 1) });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn gate_applies_cutoff() {
        let cfg = Parallelism { threads: 8, min_items: 100 };
        assert_eq!(cfg.gate(99), Parallelism::serial());
        assert_eq!(cfg.gate(100), cfg);
        assert!(!Parallelism::serial().should_parallelize(usize::MAX));
        assert!(Parallelism::with_threads(1).threads == 1);
    }

    #[test]
    fn unit_panels_cap_ragged_rows() {
        // 3 block-rows of 4 rows each over 10 total rows.
        let b = unit_panel_bounds(3, 4, 10, 2);
        assert_eq!(b.last().unwrap().1, 10);
        assert_eq!(b[0].0, 0);
        let total: usize = b.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn global_settable() {
        // Note: global state; only assert set/get coherence.
        set_global(Parallelism { threads: 3, min_items: 7 });
        assert_eq!(global().threads, 3);
        set_global(Parallelism::auto());
        assert!(global().threads >= 1);
    }
}
