//! xorshift64* PRNG — deterministic, seedable, dependency-free. Used by
//! the data pipeline, property tests, and synthetic workload generators.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; splash the seed.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// The raw stream state — the checkpointable identity of this
    /// stream. A stream restored with [`Rng::set_state`] continues the
    /// exact bit sequence from where `state()` was read.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Restore a stream to a state previously read with [`Rng::state`].
    /// (0 is not a reachable xorshift state; it is mapped to 1 so a
    /// corrupt checkpoint cannot wedge the generator at the fixed
    /// point.)
    pub fn set_state(&mut self, state: u64) {
        self.state = if state == 0 { 1 } else { state };
    }

    /// A stream resumed directly from a raw state.
    pub fn from_state(state: u64) -> Self {
        let mut r = Rng { state: 1 };
        r.set_state(state);
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Log-uniform in [lo, hi) — magnitudes spanning many binades, the
    /// natural distribution for quantization-range tests.
    pub fn f32_log_uniform(&mut self, lo: f32, hi: f32) -> f32 {
        debug_assert!(lo > 0.0 && hi > lo);
        (lo.ln() as f64 + (hi.ln() - lo.ln()) as f64 * self.f64()).exp() as f32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(77);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        let mut c = Rng::new(77);
        c.set_state(snap);
        for _ in 0..50 {
            let v = a.next_u64();
            assert_eq!(v, b.next_u64());
            assert_eq!(v, c.next_u64());
        }
        // Zero state is defused rather than wedging the generator.
        let mut z = Rng::from_state(0);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f32_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let u = r.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let l = r.f32_log_uniform(1e-6, 1e6);
            assert!((1e-7..1e7).contains(&l));
        }
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::new(9);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
