//! Miniature property-testing harness (offline replacement for the
//! `proptest` crate). Deterministic by default with per-case seeds, so a
//! failure message pinpoints the reproducing seed; set
//! `MOR_PROPTEST_SEED` to re-run a single case and `MOR_PROPTEST_CASES`
//! to change the case count.

pub use super::rng::Rng as Gen;

/// Run `cases` property checks. The property returns `true` on success;
/// `false` or a panic fails the test with the case seed in the message.
pub fn prop<F: Fn(&mut Gen) -> bool + std::panic::RefUnwindSafe>(cases: u32, property: F) {
    let cases = std::env::var("MOR_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    if let Ok(seed) = std::env::var("MOR_PROPTEST_SEED") {
        let seed: u64 = seed.parse().expect("MOR_PROPTEST_SEED must be a u64");
        let mut g = Gen::new(seed);
        assert!(property(&mut g), "property failed for seed {seed}");
        return;
    }
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        match result {
            Ok(true) => {}
            Ok(false) => panic!(
                "property returned false on case {case}; rerun with MOR_PROPTEST_SEED={seed}"
            ),
            Err(e) => {
                let msg = e
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property panicked on case {case}: {msg}; rerun with MOR_PROPTEST_SEED={seed}"
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop(50, |g| g.f32() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "MOR_PROPTEST_SEED")]
    fn failing_property_reports_seed() {
        prop(50, |g| g.f32() < 0.5); // fails with ~certainty over 50 cases
    }

    #[test]
    #[should_panic(expected = "property panicked")]
    fn panicking_property_reports_seed() {
        prop(10, |g| {
            assert!(g.f32() < 0.5, "too big");
            true
        });
    }
}
